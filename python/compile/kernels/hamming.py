"""Layer-1 Pallas kernel: vertical-format batched Hamming scan.

The accelerator-side counterpart of the engine's verification / linear-scan
path (§V-C of the paper): the database is stored as ``b`` bit-planes of
``W = ceil(L/32)`` int32 words per sketch; the distance to a query is

    popcount( OR_k ( plane[k] XOR q[k] ) )

summed over the W words. One grid step loads a ``(BN, W)`` tile per plane,
XORs against the broadcast query words, OR-folds the planes, popcounts.
Pure VPU work; tiles sized for VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 4096  # sketches per tile


def _hamming_kernel(planes_ref, q_ref, out_ref):
    """planes_ref: (b, BN, W) i32; q_ref: (b, W) i32; out_ref: (BN,) i32."""
    planes = planes_ref[...]
    q = q_ref[...]
    x = planes ^ q[:, None, :]  # (b, BN, W)
    folded = jnp.bitwise_or.reduce(x, axis=0)  # (BN, W)
    counts = jax.lax.population_count(folded)  # (BN, W)
    out_ref[...] = jnp.sum(counts, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hamming_scan(planes, q, *, interpret=True):
    """Distances of every sketch to the query.

    planes: i32[b, N, W] (vertical database), q: i32[b, W] → i32[N].
    """
    b, n, w = planes.shape
    assert q.shape == (b, w), (q.shape, (b, w))
    bn = min(BN, n)
    rem = (-n) % bn
    if rem:
        planes = jnp.pad(planes, ((0, 0), (0, rem), (0, 0)))
    np_ = planes.shape[1]
    out = pl.pallas_call(
        _hamming_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((b, bn, w), lambda i: (0, i, 0)),
            pl.BlockSpec((b, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        interpret=interpret,
    )(planes, q)
    return out[:n]
