"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract: pytest asserts kernel == ref on randomized inputs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

I32_INF = jnp.int32(2**31 - 1)


def minhash_min_ref(x, h):
    """x: f32[N, D] (0/1), h: i32[L, D] → i32[N, L]."""
    active = x > 0.0  # (N, D)
    scores = jnp.where(active[:, None, :], h[None, :, :], I32_INF)
    return jnp.min(scores, axis=2)


def cws_argmin_ref(x, r, logc, beta):
    """x: f32[N, D] (>=0), params f32[L, D] → argmin index i32[N, L]."""
    active = x > 0.0
    lnx = jnp.log(jnp.where(active, x, 1.0))
    t = jnp.floor(lnx[:, None, :] / r[None, :, :] + beta[None, :, :])
    ln_a = logc[None, :, :] - r[None, :, :] * (t + 1.0 - beta[None, :, :])
    scores = jnp.where(active[:, None, :], ln_a, jnp.inf)
    return jnp.argmin(scores, axis=2).astype(jnp.int32)


def hamming_scan_ref(planes, q):
    """planes: i32[b, N, W], q: i32[b, W] → i32[N]."""
    x = planes ^ q[:, None, :]
    folded = x[0]
    for k in range(1, planes.shape[0]):
        folded = folded | x[k]
    return jnp.sum(jax.lax.population_count(folded), axis=1, dtype=jnp.int32)


def minhash_sketch_ref(x, h, b):
    """Full b-bit minhash: low b bits of the min hash value."""
    return minhash_min_ref(x, h) & jnp.int32((1 << b) - 1)


def cws_sketch_ref(x, r, logc, beta, b):
    """Full 0-bit CWS: argmin index mod 2^b."""
    return cws_argmin_ref(x, r, logc, beta) & jnp.int32((1 << b) - 1)
