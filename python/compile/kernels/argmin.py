"""Layer-1 Pallas kernel: tiled masked (arg)min reduction.

The compute hot-spot of both similarity-preserving hashes:

* b-bit minhash — ``min_j  H[l, j]``  over active set elements ``j``;
* 0-bit CWS    — ``argmin_j a[l, j]`` over active dimensions ``j``,
  with the CWS score prelude fused into the kernel.

Kernel shape: for a batch ``X`` of ``N`` items over ``D`` dimensions and
``L`` independent hashes, the grid is ``(N/bn, L/bl, D/bd)`` with the
reduction axis ``D`` innermost. Each step loads an ``(bn, bd)`` tile of
item data and a ``(bl, bd)`` tile of hash parameters into VMEM, forms the
``(bn, bl, bd)`` score block, and folds it into running ``(bn, bl)``
min / argmin carried in the output refs across grid steps (grid-carried
accumulation — the standard Pallas reduction pattern).

TPU adaptation (DESIGN.md §4): tiles are sized for VMEM (default blocks
use ~2 MiB); the work is VPU-elementwise + reduction (no MXU); the
HBM→VMEM schedule that a CUDA implementation would express with
threadblocks is the BlockSpec index maps below. On this testbed kernels
run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes (see DESIGN.md §Perf for the VMEM budget).
BN = 256  # items per tile
BL = 8  # hashes per tile
BD = 512  # reduction-axis tile

# int32 "+inf" for the minhash domain (hash values are in [0, 2^31)).
# Plain Python values: Pallas kernels may not capture traced constants.
I32_INF = 2**31 - 1
F32_INF = float("inf")


def _minhash_kernel(x_ref, h_ref, min_ref):
    """One grid step of the minhash reduction.

    x_ref:   (BN, BD) f32   — 0/1 activity of the item's set elements
    h_ref:   (BL, BD) i32   — hash values for BL hash functions
    min_ref: (BN, BL) i32   — running minima (grid-carried)
    """
    first = pl.program_id(2) == 0

    x = x_ref[...]  # (BN, BD)
    h = h_ref[...]  # (BL, BD)
    active = x > 0.0
    # scores (BN, BL, BD): hash value where active, +inf otherwise
    scores = jnp.where(active[:, None, :], h[None, :, :], jnp.int32(I32_INF))
    tile_min = jnp.min(scores, axis=2)  # (BN, BL)

    prev = jnp.where(first, jnp.int32(I32_INF), min_ref[...])
    min_ref[...] = jnp.minimum(prev, tile_min)


def _cws_kernel(lnx_ref, active_ref, r_ref, logc_ref, beta_ref, min_ref, arg_ref):
    """One grid step of the fused CWS score + argmin reduction.

    lnx_ref:    (BN, BD) f32 — ln(x) (0 where inactive)
    active_ref: (BN, BD) f32 — 1.0 where x > 0
    r/logc/beta:(BL, BD) f32 — CWS parameter tiles
    min_ref:    (BN, BL) f32 — running min scores (carried)
    arg_ref:    (BN, BL) i32 — running argmin global indices (carried)
    """
    d_step = pl.program_id(2)
    first = d_step == 0

    lnx = lnx_ref[...]
    active = active_ref[...] > 0.0
    r = r_ref[...]
    logc = logc_ref[...]
    beta = beta_ref[...]

    # CWS prelude (fused — never materialized at (N, L, D) in HBM):
    #   t    = floor(ln x / r + beta)
    #   ln a = ln c - r * (t + 1 - beta)
    t = jnp.floor(lnx[:, None, :] / r[None, :, :] + beta[None, :, :])
    ln_a = logc[None, :, :] - r[None, :, :] * (t + 1.0 - beta[None, :, :])
    scores = jnp.where(active[:, None, :], ln_a, jnp.float32(F32_INF))  # (BN, BL, BD)

    local_arg = jnp.argmin(scores, axis=2).astype(jnp.int32)  # first on ties
    local_min = jnp.min(scores, axis=2)
    global_arg = local_arg + d_step * scores.shape[2]

    prev_min = jnp.where(first, jnp.float32(F32_INF), min_ref[...])
    prev_arg = jnp.where(first, jnp.int32(0), arg_ref[...])
    better = local_min < prev_min  # strict: earlier d-tile wins ties
    min_ref[...] = jnp.where(better, local_min, prev_min)
    arg_ref[...] = jnp.where(better, global_arg, prev_arg)


def _pad_to(x, axis, multiple, value):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minhash_min(x, h, *, interpret=True):
    """Masked min of ``h`` over active elements of each row of ``x``.

    x: f32[N, D] (0/1), h: i32[L, D] → i32[N, L]; rows with no active
    element yield ``I32_INF`` (callers mask to the all-ones character).
    """
    n, d = x.shape
    l, d2 = h.shape
    assert d == d2, (d, d2)
    bn, bl, bd = min(BN, n), min(BL, l), min(BD, d)
    xp = _pad_to(_pad_to(x, 0, bn, 0.0), 1, bd, 0.0)
    hp = _pad_to(_pad_to(h, 0, bl, I32_INF), 1, bd, I32_INF)
    np_, dp = xp.shape
    lp = hp.shape[0]
    grid = (np_ // bn, lp // bl, dp // bd)
    out = pl.pallas_call(
        _minhash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bl), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, lp), jnp.int32),
        interpret=interpret,
    )(xp, hp)
    return out[:n, :l]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cws_argmin(x, r, logc, beta, *, interpret=True):
    """Fused 0-bit-CWS score + argmin over active dimensions.

    x: f32[N, D] (weights >= 0); r/logc/beta: f32[L, D] → i32[N, L]
    (argmin index; all-zero rows yield 0).
    """
    n, d = x.shape
    l, d2 = r.shape
    assert d == d2
    bn, bl, bd = min(BN, n), min(BL, l), min(BD, d)

    active = (x > 0.0).astype(jnp.float32)
    lnx = jnp.log(jnp.where(x > 0.0, x, 1.0))

    xp = _pad_to(_pad_to(lnx, 0, bn, 0.0), 1, bd, 0.0)
    ap = _pad_to(_pad_to(active, 0, bn, 0.0), 1, bd, 0.0)
    rp = _pad_to(_pad_to(r, 0, bl, 1.0), 1, bd, 1.0)
    cp = _pad_to(_pad_to(logc, 0, bl, 0.0), 1, bd, 0.0)
    bp = _pad_to(_pad_to(beta, 0, bl, 0.0), 1, bd, 0.0)
    np_, dp = xp.shape
    lp = rp.shape[0]
    grid = (np_ // bn, lp // bl, dp // bd)
    _, arg = pl.pallas_call(
        _cws_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, lp), jnp.float32),
            jax.ShapeDtypeStruct((np_, lp), jnp.int32),
        ],
        interpret=interpret,
    )(xp, ap, rp, cp, bp)
    return arg[:n, :l]
