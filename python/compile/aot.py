"""AOT lowering: JAX/Pallas models → HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.

Artifacts (one per dataset configuration, shapes static):

    sketch_<dataset>.hlo.txt        — the hashing pipeline
    hamming_<dataset>.hlo.txt       — the vertical Hamming scan
    meta.json                       — shape/dtype registry for the runtime

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Dataset configurations (Table I of the paper; D is the synthetic
# generator dimensionality — see DESIGN.md §5).
DATASETS = {
    "review": dict(b=2, l=16, d=4096, kind="minhash"),
    "cp": dict(b=2, l=32, d=4096, kind="minhash"),
    "sift": dict(b=4, l=32, d=128, kind="cws"),
    "gist": dict(b=8, l=64, d=384, kind="cws"),
}

# Static batch sizes: the runtime pads the final batch.
SKETCH_BATCH = 2048
SCAN_BATCH = 65536


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_sketch(name: str, cfg: dict) -> tuple[str, dict]:
    n, d, l, b = SKETCH_BATCH, cfg["d"], cfg["l"], cfg["b"]
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    if cfg["kind"] == "minhash":
        h = jax.ShapeDtypeStruct((l, d), jnp.int32)
        fn = functools.partial(model.minhash_sketch, b=b)
        lowered = jax.jit(fn).lower(x, h)
        params = ["x:f32", "h:i32"]
    else:
        p = jax.ShapeDtypeStruct((l, d), jnp.float32)
        fn = functools.partial(model.cws_sketch, b=b)
        lowered = jax.jit(fn).lower(x, p, p, p)
        params = ["x:f32", "r:f32", "logc:f32", "beta:f32"]
    meta = dict(
        name=f"sketch_{name}",
        kind=f"sketch_{cfg['kind']}",
        dataset=name,
        batch=n,
        d=d,
        l=l,
        b=b,
        params=params,
        out=f"i32[{n},{l}]",
    )
    return to_hlo_text(lowered), meta


def lower_hamming(name: str, cfg: dict) -> tuple[str, dict]:
    n, l, b = SCAN_BATCH, cfg["l"], cfg["b"]
    w = (l + 31) // 32
    planes = jax.ShapeDtypeStruct((b, n, w), jnp.int32)
    q = jax.ShapeDtypeStruct((b, w), jnp.int32)
    lowered = jax.jit(model.hamming_scan_model).lower(planes, q)
    meta = dict(
        name=f"hamming_{name}",
        kind="hamming_scan",
        dataset=name,
        batch=n,
        l=l,
        b=b,
        w=w,
        params=["planes:i32", "q:i32"],
        out=f"i32[{n}]",
    )
    return to_hlo_text(lowered), meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated dataset subset (debug)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(DATASETS) if args.only is None else args.only.split(",")
    artifacts = []
    for name in names:
        cfg = DATASETS[name]
        for lower in (lower_sketch, lower_hamming):
            text, meta = lower(name, cfg)
            path = os.path.join(args.out, f"{meta['name']}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            meta["file"] = f"{meta['name']}.hlo.txt"
            artifacts.append(meta)
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump({"artifacts": artifacts, "sketch_batch": SKETCH_BATCH,
                   "scan_batch": SCAN_BATCH}, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'meta.json')}")


if __name__ == "__main__":
    main()
