"""Layer-2 JAX models: the similarity-preserving hashing pipelines.

These are the computations the Rust runtime executes through PJRT after
``aot.py`` lowers them to HLO text. Each composes a Pallas kernel
(`kernels/`) with the cheap surrounding arithmetic that XLA fuses:

* ``minhash_sketch``  — b-bit minwise hashing: masked min (kernel) + low-b
  bits. Bit-identical to ``rust/src/sketch/minhash.rs`` given the same
  `h` tensor (integer min has no rounding).
* ``cws_sketch``      — 0-bit CWS: fused score+argmin (kernel) + mod 2^b.
  Matches the native implementation up to f32 `ln` ulp differences
  (<0.5% of characters; see the cross-implementation test).
* ``hamming_scan_model`` — vertical Hamming distances of a database batch
  against one query (the XLA linear-scan baseline / remote verifier).

Python never runs at serving time: these functions exist to be lowered
once by ``aot.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.argmin import cws_argmin, minhash_min
from .kernels.hamming import hamming_scan


def minhash_sketch(x, h, *, b: int, interpret: bool = True):
    """x: f32[N, D] 0/1 fingerprints; h: i32[L, D] hashes → i32[N, L]
    characters in [0, 2^b)."""
    return minhash_min(x, h, interpret=interpret) & jnp.int32((1 << b) - 1)


def cws_sketch(x, r, logc, beta, *, b: int, interpret: bool = True):
    """x: f32[N, D] non-negative weights; CWS params f32[L, D] →
    i32[N, L] characters in [0, 2^b)."""
    arg = cws_argmin(x, r, logc, beta, interpret=interpret)
    return arg & jnp.int32((1 << b) - 1)


def hamming_scan_model(planes, q, *, interpret: bool = True):
    """planes: i32[b, N, W]; q: i32[b, W] → i32[N] distances."""
    return hamming_scan(planes, q, interpret=interpret)
