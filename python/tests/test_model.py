"""Layer-2 model tests: full sketch pipelines vs oracles, alphabet
containment, and statistical sanity of the hashes themselves."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(777)


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 2, 4, 8]), n=st.integers(1, 30), d=st.integers(2, 300))
def test_minhash_sketch_matches_ref(b, n, d):
    l = 8
    x = (RNG.random((n, d)) < 0.3).astype(np.float32)
    h = RNG.integers(0, 2**31 - 1, size=(l, d), dtype=np.int32)
    got = model.minhash_sketch(jnp.asarray(x), jnp.asarray(h), b=b)
    expect = ref.minhash_sketch_ref(jnp.asarray(x), jnp.asarray(h), b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    assert np.asarray(got).max() < (1 << b)


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([2, 4, 8]), n=st.integers(1, 20), d=st.integers(2, 200))
def test_cws_sketch_matches_ref(b, n, d):
    l = 6
    x = np.where(RNG.random((n, d)) < 0.7, RNG.random((n, d)), 0.0).astype(np.float32)
    r = RNG.gamma(2.0, 1.0, size=(l, d)).astype(np.float32)
    logc = np.log(RNG.gamma(2.0, 1.0, size=(l, d))).astype(np.float32)
    beta = RNG.random((l, d)).astype(np.float32)
    got = model.cws_sketch(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(logc), jnp.asarray(beta), b=b
    )
    expect = ref.cws_sketch_ref(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(logc), jnp.asarray(beta), b
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_minhash_collision_tracks_jaccard():
    """The sketch must actually approximate Jaccard similarity —
    the end-to-end statistical contract of the hashing layer."""
    d, l, b = 1000, 512, 2
    h = RNG.integers(0, 2**31 - 1, size=(l, d), dtype=np.int32)
    base = RNG.permutation(d)[:400]
    a_idx, b_idx = base[:300], base[100:400]  # |∩|=200, |∪|=400 → J=0.5
    xa = np.zeros((1, d), np.float32)
    xb = np.zeros((1, d), np.float32)
    xa[0, a_idx] = 1
    xb[0, b_idx] = 1
    sa = np.asarray(model.minhash_sketch(jnp.asarray(xa), jnp.asarray(h), b=b))[0]
    sb = np.asarray(model.minhash_sketch(jnp.asarray(xb), jnp.asarray(h), b=b))[0]
    coll = float((sa == sb).mean())
    expect = 0.5 + 0.5 / (1 << b)  # J + (1-J)/2^b
    assert abs(coll - expect) < 0.08, (coll, expect)


def test_hamming_model_self_distance():
    planes = jnp.asarray(
        RNG.integers(0, 2**31 - 1, size=(4, 50, 1), dtype=np.int64).astype(np.int32)
    )
    d = np.asarray(model.hamming_scan_model(planes, planes[:, 3, :]))
    assert d[3] == 0
    assert (d >= 0).all()
