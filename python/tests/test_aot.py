"""AOT lowering tests: every artifact lowers to valid HLO *text* (the
interchange format the Rust runtime's XLA 0.5.1 can parse) with the
expected parameter shapes, and `meta.json` is consistent."""

import json
import os

from compile import aot


def test_all_datasets_lower(tmp_path):
    for name, cfg in aot.DATASETS.items():
        text, meta = aot.lower_sketch(name, cfg)
        # HLO text essentials: a module with an entry computation and the
        # expected batch dimension in a parameter shape.
        assert text.startswith("HloModule"), name
        assert f"{aot.SKETCH_BATCH},{cfg['d']}" in text.replace(" ", ""), name
        assert meta["b"] == cfg["b"] and meta["l"] == cfg["l"]

        text, meta = aot.lower_hamming(name, cfg)
        assert text.startswith("HloModule"), name
        assert meta["w"] == (cfg["l"] + 31) // 32


def test_hlo_text_has_no_serialized_proto_markers():
    # the 64-bit-id proto issue only affects .serialize(); text must be
    # plain ASCII HLO.
    text, _ = aot.lower_sketch("review", aot.DATASETS["review"])
    assert text.isascii()
    assert "ROOT" in text


def test_meta_json_written(tmp_path):
    out = tmp_path / "artifacts"
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", str(out), "--only", "review"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    meta = json.loads((out / "meta.json").read_text())
    names = {a["name"] for a in meta["artifacts"]}
    assert names == {"sketch_review", "hamming_review"}
    for a in meta["artifacts"]:
        assert os.path.exists(out / a["file"])
        assert a["batch"] > 0
