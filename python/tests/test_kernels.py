"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, alphabet widths and sparsity; fixed-seed numpy
generates the payloads (hypothesis drives the *configuration* space so
shrinking stays fast)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.argmin import cws_argmin, minhash_min
from compile.kernels.hamming import hamming_scan

RNG = np.random.default_rng(12345)


def random_minhash_inputs(n, d, l, density):
    x = (RNG.random((n, d)) < density).astype(np.float32)
    h = RNG.integers(0, 2**31 - 1, size=(l, d), dtype=np.int32)
    return jnp.asarray(x), jnp.asarray(h)


def random_cws_inputs(n, d, l, density):
    x = np.where(RNG.random((n, d)) < density, RNG.random((n, d)), 0.0)
    x = x.astype(np.float32)
    r = RNG.gamma(2.0, 1.0, size=(l, d)).astype(np.float32)
    logc = np.log(RNG.gamma(2.0, 1.0, size=(l, d))).astype(np.float32)
    beta = RNG.random((l, d)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(r), jnp.asarray(logc), jnp.asarray(beta)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    d=st.integers(1, 600),
    l=st.integers(1, 20),
    density=st.floats(0.0, 1.0),
)
def test_minhash_matches_ref(n, d, l, density):
    x, h = random_minhash_inputs(n, d, l, density)
    got = minhash_min(x, h)
    expect = ref.minhash_min_ref(x, h)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 24),
    d=st.integers(1, 400),
    l=st.integers(1, 12),
    density=st.floats(0.0, 1.0),
)
def test_cws_matches_ref(n, d, l, density):
    x, r, logc, beta = random_cws_inputs(n, d, l, density)
    got = cws_argmin(x, r, logc, beta)
    expect = ref.cws_argmin_ref(x, r, logc, beta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    n=st.integers(1, 300),
    w=st.integers(1, 2),
)
def test_hamming_matches_ref(b, n, w):
    planes = jnp.asarray(
        RNG.integers(-(2**31), 2**31 - 1, size=(b, n, w), dtype=np.int64).astype(
            np.int32
        )
    )
    q = jnp.asarray(
        RNG.integers(-(2**31), 2**31 - 1, size=(b, w), dtype=np.int64).astype(np.int32)
    )
    got = hamming_scan(planes, q)
    expect = ref.hamming_scan_ref(planes, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_minhash_crosses_tile_boundaries():
    # shapes straddling BN/BL/BD multiples
    for (n, d, l) in [(257, 513, 9), (256, 512, 8), (1, 1, 1), (300, 1100, 17)]:
        x, h = random_minhash_inputs(n, d, l, 0.3)
        got = minhash_min(x, h)
        expect = ref.minhash_min_ref(x, h)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_cws_tie_break_is_first_index():
    # Identical params in all dims → score identical → argmin must be the
    # first active dimension.
    n, d, l = 4, 50, 6
    x = np.zeros((n, d), np.float32)
    x[:, 10] = 2.0
    x[:, 30] = 2.0
    r = np.full((l, d), 1.5, np.float32)
    logc = np.zeros((l, d), np.float32)
    beta = np.full((l, d), 0.25, np.float32)
    got = np.asarray(cws_argmin(jnp.asarray(x), jnp.asarray(r), jnp.asarray(logc), jnp.asarray(beta)))
    assert (got == 10).all()


def test_minhash_empty_rows_yield_inf():
    x = np.zeros((3, 64), np.float32)
    h = RNG.integers(0, 2**31 - 1, size=(4, 64), dtype=np.int32)
    got = np.asarray(minhash_min(jnp.asarray(x), jnp.asarray(h)))
    assert (got == 2**31 - 1).all()


def test_hamming_zero_distance_to_self():
    planes = jnp.asarray(RNG.integers(0, 2**31 - 1, size=(4, 100, 2), dtype=np.int64).astype(np.int32))
    q = planes[:, 17, :]
    got = np.asarray(hamming_scan(planes, q))
    assert got[17] == 0


def test_sketch_chars_in_alphabet():
    from compile import model

    for b in (2, 4):
        x, h = random_minhash_inputs(10, 128, 8, 0.2)
        s = np.asarray(model.minhash_sketch(x, h, b=b))
        assert s.min() >= 0 and s.max() < (1 << b)
    x, r, logc, beta = random_cws_inputs(10, 64, 8, 0.8)
    s = np.asarray(model.cws_sketch(x, r, logc, beta, b=4))
    assert s.min() >= 0 and s.max() < 16


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
