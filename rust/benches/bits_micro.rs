//! Micro-benchmarks of the succinct substrate: rank, select, in-window
//! bit scans — the inner loops of every `children()` call.
//!
//! Run: `cargo bench --bench bits_micro`

use bst::bits::rsvec::SelectMode;
use bst::bits::{BitVec, RsBitVec};
use bst::util::timer::{measure, sink};
use bst::util::Rng;
use std::time::Duration;

fn bench(name: &str, iters: usize, f: impl FnMut()) {
    let mut stats = measure(iters, Duration::from_millis(300), f);
    println!(
        "{name:40} mean {:>10.1} ns   p50 {:>10.1} ns   (n={})",
        stats.mean() * 1000.0,
        stats.p50() * 1000.0,
        stats.len()
    );
}

fn main() {
    println!("# bits_micro — rank/select substrate");
    let n = 8 << 20; // 8 Mi bits
    let mut rng = Rng::new(1);
    let bv: BitVec = (0..n).map(|_| rng.f64() < 0.5).collect();
    let rs = RsBitVec::new(bv, SelectMode::Both);
    let ones = rs.count_ones();

    // batches of 1024 queries per iteration to dominate loop overhead
    let positions: Vec<usize> = (0..1024).map(|_| rng.below_usize(n)).collect();
    let ks: Vec<usize> = (0..1024).map(|_| rng.below_usize(ones)).collect();

    bench("rank1 x1024 (random)", 50, || {
        let mut acc = 0usize;
        for &p in &positions {
            acc = acc.wrapping_add(rs.rank1(p));
        }
        sink(acc);
    });

    bench("select1 x1024 (random)", 50, || {
        let mut acc = 0usize;
        for &k in &ks {
            acc = acc.wrapping_add(rs.select1(k));
        }
        sink(acc);
    });

    bench("select0 x1024 (random)", 50, || {
        let mut acc = 0usize;
        for &k in &ks {
            acc = acc.wrapping_add(rs.select0(k.min(n - ones - 1)));
        }
        sink(acc);
    });

    // TABLE-window style: rank + scan of an aligned 16-bit window
    bench("table children() x1024 (b=4)", 50, || {
        let mut acc = 0usize;
        for &p in &positions {
            let start = p & !15;
            let base = rs.rank1(start);
            let mut w = rs.get_bits(start, 16);
            let mut child = base;
            while w != 0 {
                acc = acc.wrapping_add(child + w.trailing_zeros() as usize);
                child += 1;
                w &= w - 1;
            }
        }
        sink(acc);
    });

    // sparse-density select (every ~4096th bit set)
    let mut sparse = BitVec::zeros(n);
    let mut i = 0usize;
    while i < n {
        sparse.set(i);
        i += 4096;
    }
    let rs_sparse = RsBitVec::new(sparse, SelectMode::Ones);
    let sk: Vec<usize> = (0..1024)
        .map(|_| rng.below_usize(rs_sparse.count_ones()))
        .collect();
    bench("select1 x1024 (sparse 1/4096)", 50, || {
        let mut acc = 0usize;
        for &k in &sk {
            acc = acc.wrapping_add(rs_sparse.select1(k));
        }
        sink(acc);
    });
}
