//! Fig. 7 end-to-end bench: the five similarity-search methods on one
//! dataset (default: review at 0.25 scale; env `BST_DATASET`/`BST_SCALE`).
//!
//! Run: `cargo bench --bench fig7_methods`

use bst::data::{generate_workload, Dataset, GenConfig};
use bst::eval::tables;
use bst::eval::EvalOpts;

fn main() {
    let ds = std::env::var("BST_DATASET")
        .ok()
        .and_then(|s| Dataset::parse(&s))
        .unwrap_or(Dataset::Review);
    let scale: f64 = std::env::var("BST_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let opts = EvalOpts {
        scale,
        queries: 100,
        sih_cap_secs: 1.0,
        ..Default::default()
    };
    // sanity: workload generates
    let cfg = GenConfig::for_dataset(ds, scale, opts.seed, opts.threads);
    let w = generate_workload(ds, &cfg);
    println!(
        "# fig7_methods — {} n={} queries={}",
        ds.name(),
        w.sketches.n(),
        opts.queries
    );
    print!("{}", tables::fig7(&opts, &[ds]));
}
