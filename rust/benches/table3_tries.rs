//! Table III end-to-end bench: bST vs LOUDS vs FST search time and space
//! on the synthetic Review and CP workloads (the two the paper runs all
//! three tries on).
//!
//! Run: `cargo bench --bench table3_tries` (env `BST_SCALE` to resize).

use bst::data::{generate_workload, Dataset, GenConfig};
use bst::index::{SearchIndex, SingleBst, SingleFst, SingleLouds};
use bst::trie::bst::BstConfig;
use bst::trie::SketchTrie;
use bst::util::timer::{sink, Timer};

fn main() {
    let scale: f64 = std::env::var("BST_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    println!("# table3_tries — succinct-trie comparison (scale={scale})");
    for ds in [Dataset::Review, Dataset::Cp] {
        let cfg = GenConfig::for_dataset(ds, scale, 42, 8);
        let w = generate_workload(ds, &cfg);
        let n_q = 100.min(w.queries.len());

        let build = Timer::start();
        let bst = SingleBst::build(&w.sketches, BstConfig::default());
        let bst_build = build.elapsed_ms();
        let build = Timer::start();
        let louds = SingleLouds::build(&w.sketches);
        let louds_build = build.elapsed_ms();
        let build = Timer::start();
        let fst = SingleFst::build(&w.sketches);
        let fst_build = build.elapsed_ms();

        println!(
            "\n## {} n={} ({}; build bst {:.0} ms / louds {:.0} ms / fst {:.0} ms)",
            ds.name(),
            w.sketches.n(),
            bst.trie().describe(),
            bst_build,
            louds_build,
            fst_build
        );
        println!(
            "{:8} {:>9} {:>9} {:>9} {:>9} {:>9} {:>11}",
            "trie", "tau=1", "tau=2", "tau=3", "tau=4", "tau=5", "space(MiB)"
        );
        let run = |name: &str, search: &dyn Fn(&[u8], usize) -> Vec<u32>, bytes: usize| {
            print!("{name:8}");
            for tau in 1..=5usize {
                let t = Timer::start();
                let mut acc = 0usize;
                for q in w.queries.iter().take(n_q) {
                    acc += search(q, tau).len();
                }
                sink(acc);
                print!(" {:>8.3}", t.elapsed_ms() / n_q as f64);
            }
            println!("   {:>9.1}", bytes as f64 / (1024.0 * 1024.0));
        };
        run("bST", &|q, tau| bst.search(q, tau), bst.heap_bytes());
        run("LOUDS", &|q, tau| louds.search(q, tau), louds.heap_bytes());
        run("FST", &|q, tau| fst.search(q, tau), fst.heap_bytes());
    }
}
