//! Ablation of bST's design choices (DESIGN.md §1):
//!
//! * the **dense layer** (implicit complete trie) on/off;
//! * the **sparse layer** position: λ sweep + no-collapse (`ls = L`);
//! * the adaptive **TABLE/LIST** middle selection vs forcing either.
//!
//! Each variant reports search time across τ and structure size — showing
//! *why* each layer earns its place (the paper argues this qualitatively;
//! this bench quantifies it on the CP-like workload).
//!
//! Run: `cargo bench --bench ablation_bst` (env `BST_SCALE`, default 0.1).

use bst::data::{generate_workload, Dataset, GenConfig};
use bst::trie::bst::{BstConfig, BstTrie, MiddleRepr};
use bst::trie::{SketchTrie, SortedSketches};
use bst::util::timer::{sink, Timer};

fn main() {
    let scale: f64 = std::env::var("BST_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let ds = Dataset::Cp;
    let cfg = GenConfig::for_dataset(ds, scale, 42, 8);
    let w = generate_workload(ds, &cfg);
    let ss = SortedSketches::build(&w.sketches);
    let n_q = 100.min(w.queries.len());
    println!(
        "# ablation_bst — {} n={} distinct={} (scale={scale})",
        ds.name(),
        w.sketches.n(),
        ss.n_distinct()
    );

    let variants: Vec<(String, BstConfig)> = vec![
        ("default (λ=0.5, adaptive)".into(), BstConfig::default()),
        (
            "no dense layer (lm=0)".into(),
            BstConfig { lm: Some(0), ..Default::default() },
        ),
        (
            "no sparse collapse (ls=L)".into(),
            BstConfig { ls: Some(w.sketches.l()), ..Default::default() },
        ),
        (
            "all-TABLE middle".into(),
            BstConfig { force_repr: Some(MiddleRepr::Table), ..Default::default() },
        ),
        (
            "all-LIST middle".into(),
            BstConfig { force_repr: Some(MiddleRepr::List), ..Default::default() },
        ),
        ("λ=0.1 (early collapse)".into(), BstConfig { lambda: 0.1, ..Default::default() }),
        ("λ=0.9 (late collapse)".into(), BstConfig { lambda: 0.9, ..Default::default() }),
    ];

    println!(
        "\n{:28} {:>8} {:>8} {:>8} {:>10} {:>6}",
        "variant", "tau=1", "tau=3", "tau=5", "space KiB", "layers"
    );
    // correctness pin: all variants must agree with the default
    let default_trie = BstTrie::build(&ss, BstConfig::default());
    let mut reference: Vec<Vec<u32>> = Vec::new();
    for q in w.queries.iter().take(n_q) {
        let mut r = default_trie.search(q, 3);
        r.sort();
        reference.push(r);
    }

    for (name, cfg) in variants {
        let trie = BstTrie::build(&ss, cfg);
        for (qi, q) in w.queries.iter().take(n_q).enumerate() {
            let mut r = trie.search(q, 3);
            r.sort();
            assert_eq!(r, reference[qi], "variant '{name}' diverges");
        }
        let mut times = Vec::new();
        for tau in [1usize, 3, 5] {
            let t = Timer::start();
            let mut acc = 0usize;
            for q in w.queries.iter().take(n_q) {
                acc += trie.search(q, tau).len();
            }
            sink(acc);
            times.push(t.elapsed_ms() / n_q as f64);
        }
        println!(
            "{:28} {:>8.3} {:>8.3} {:>8.3} {:>10.0} {:>6}",
            name,
            times[0],
            times[1],
            times[2],
            trie.heap_bytes() as f64 / 1024.0,
            trie.layer_string().len()
        );
    }
}
