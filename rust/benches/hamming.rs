//! §V-C preliminary experiment: naive vs horizontal-SWAR vs vertical
//! Hamming distance. The paper reports the vertical format "more than an
//! order of magnitude faster" than naive for 32-dim 4-bit sketches —
//! this bench regenerates that comparison (plus every dataset config).
//!
//! Run: `cargo bench --bench hamming`

use bst::sketch::{hamming, SketchSet, VerticalSet};
use bst::util::timer::{measure, sink};
use bst::util::Rng;
use std::time::Duration;

fn main() {
    println!("# hamming — naive vs horizontal vs vertical (§V-C)");
    for &(b, l, label) in &[
        (2usize, 16usize, "review (b=2, L=16)"),
        (2, 32, "cp     (b=2, L=32)"),
        (4, 32, "sift   (b=4, L=32)  <- paper's preliminary config"),
        (8, 64, "gist   (b=8, L=64)"),
    ] {
        let n = 100_000;
        let mut rng = Rng::new((b * l) as u64);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let vert = VerticalSet::from_horizontal(&set);
        let q = rows[0].clone();
        let q_packed = set.pack_row(&q);
        let q_planes = vert.pack_query(&q);

        let naive = measure(10, Duration::from_millis(400), || {
            let mut acc = 0usize;
            for row in &rows {
                acc += hamming::ham_chars(row, &q);
            }
            sink(acc);
        })
        .mean();
        let horizontal = measure(10, Duration::from_millis(400), || {
            let mut acc = 0usize;
            for i in 0..n {
                acc += set.ham_packed(i, &q_packed);
            }
            sink(acc);
        })
        .mean();
        let vertical = measure(10, Duration::from_millis(400), || {
            let mut acc = 0usize;
            for i in 0..n {
                acc += vert.ham(i, &q_planes);
            }
            sink(acc);
        })
        .mean();

        println!("\n## {label} — {n} distances");
        println!("naive      {naive:>10.1} us   1.0x");
        println!(
            "horizontal {horizontal:>10.1} us   {:.1}x",
            naive / horizontal
        );
        println!("vertical   {vertical:>10.1} us   {:.1}x", naive / vertical);
    }
}
