//! §V-C preliminary experiment: naive vs horizontal-SWAR vs vertical
//! Hamming distance. The paper reports the vertical format "more than an
//! order of magnitude faster" than naive for 32-dim 4-bit sketches —
//! this bench regenerates that comparison (plus every dataset config),
//! then compares the *verification kernels*: per-item `ham()` extraction
//! vs the streaming range kernel (`ham_range_leq`) vs the batched
//! candidate kernel (`ham_many_leq`) at a selective threshold — the
//! regime every verifier (bST sparse scan, linear, MI-bST, SIH,
//! HmSearch) actually runs in.
//!
//! Run: `cargo bench --bench hamming`

use bst::sketch::{hamming, SketchSet, VerticalSet};
use bst::util::timer::{measure, sink};
use bst::util::Rng;
use std::time::Duration;

fn main() {
    println!("# hamming — naive vs horizontal vs vertical (§V-C)");
    for &(b, l, label) in &[
        (2usize, 16usize, "review (b=2, L=16)"),
        (2, 32, "cp     (b=2, L=32)"),
        (4, 32, "sift   (b=4, L=32)  <- paper's preliminary config"),
        (8, 64, "gist   (b=8, L=64)"),
    ] {
        let n = 100_000;
        let mut rng = Rng::new((b * l) as u64);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let vert = VerticalSet::from_horizontal(&set);
        let q = rows[0].clone();
        let q_packed = set.pack_row(&q);
        let q_planes = vert.pack_query(&q);

        let naive = measure(10, Duration::from_millis(400), || {
            let mut acc = 0usize;
            for row in &rows {
                acc += hamming::ham_chars(row, &q);
            }
            sink(acc);
        })
        .mean();
        let horizontal = measure(10, Duration::from_millis(400), || {
            let mut acc = 0usize;
            for i in 0..n {
                acc += set.ham_packed(i, &q_packed);
            }
            sink(acc);
        })
        .mean();
        let vertical = measure(10, Duration::from_millis(400), || {
            let mut acc = 0usize;
            for i in 0..n {
                acc += vert.ham(i, &q_planes);
            }
            sink(acc);
        })
        .mean();

        println!("\n## {label} — {n} distances");
        println!("naive      {naive:>10.1} us   1.0x");
        println!(
            "horizontal {horizontal:>10.1} us   {:.1}x",
            naive / horizontal
        );
        println!("vertical   {vertical:>10.1} us   {:.1}x", naive / vertical);

        // --- verification kernels at a selective threshold (the
        // verifiers' operating point: most items are over-threshold).
        let tau = (l / 8).max(1);
        let per_item = measure(10, Duration::from_millis(400), || {
            // the pre-kernel verification loop: full per-item fold,
            // threshold applied after the fact
            let mut hits = 0usize;
            for i in 0..n {
                if vert.ham(i, &q_planes) <= tau {
                    hits += 1;
                }
            }
            sink(hits);
        })
        .mean();
        let per_item_leq = measure(10, Duration::from_millis(400), || {
            // per-item with the between-plane early exit (ham_leq),
            // still one dispatch per item
            let mut hits = 0usize;
            for i in 0..n {
                if vert.ham_leq(i, &q_planes, tau).is_some() {
                    hits += 1;
                }
            }
            sink(hits);
        })
        .mean();
        let range = measure(10, Duration::from_millis(400), || {
            let mut hits = 0usize;
            vert.ham_range_leq(0, n, &q_planes, tau, |_, verdict| {
                hits += usize::from(verdict.is_some());
                Some(tau)
            });
            sink(hits);
        })
        .mean();
        // near-sorted candidate list (every 3rd item), as postings are
        let ids: Vec<u32> = (0..n as u32).step_by(3).collect();
        let batch = measure(10, Duration::from_millis(400), || {
            let mut hits = 0usize;
            vert.ham_many_leq(&ids, &q_planes, tau, |_, verdict| {
                hits += usize::from(verdict.is_some());
                Some(tau)
            });
            sink(hits);
        })
        .mean();
        let per_ns = |us: f64, items: usize| us * 1000.0 / items as f64;
        println!("-- verification kernels, tau={tau} --");
        println!(
            "per-item ham        {per_item:>10.1} us   {:>6.2} ns/item   1.0x",
            per_ns(per_item, n)
        );
        println!(
            "per-item ham_leq    {per_item_leq:>10.1} us   {:>6.2} ns/item   {:.1}x",
            per_ns(per_item_leq, n),
            per_item / per_item_leq
        );
        println!(
            "range kernel        {range:>10.1} us   {:>6.2} ns/item   {:.1}x",
            per_ns(range, n),
            per_item / range
        );
        println!(
            "batch kernel        {batch:>10.1} us   {:>6.2} ns/item   {:.1}x vs per-item on same ids",
            per_ns(batch, ids.len()),
            per_item * (ids.len() as f64 / n as f64) / batch
        );
    }
}
