//! Ingestion-path bench: native Rust sketching vs the XLA (AOT
//! JAX/Pallas) artifact, items/second. Documents the L1/L2 cost on this
//! CPU testbed (interpret-mode Pallas; see DESIGN.md §4 for the TPU
//! roofline estimate).
//!
//! Run: `cargo bench --bench sketching` (needs `make artifacts`).

use bst::data::{generate_dense, generate_sets, Dataset, GenConfig};
use bst::runtime::Runtime;
use bst::sketch::{CwsParams, MinhashParams};
use bst::util::timer::Timer;
use std::path::Path;

fn main() {
    let n = 20_000usize;
    println!("# sketching — native vs XLA artifact ({n} items)");

    let rt = Runtime::load(Path::new("artifacts")).ok();
    if rt.is_none() {
        println!("artifacts not built — native path only (run `make artifacts`)");
    }

    // minhash (review config)
    {
        let ds = Dataset::Review;
        let cfg = GenConfig { n, seed: 1, threads: 8, cluster_size: 24, background: 0.1 };
        let sets = generate_sets(ds, &cfg);
        let params = MinhashParams::generate(ds.l(), ds.b(), ds.dim(), 1);

        let t = Timer::start();
        let native = params.sketch_batch(&sets, 8);
        let native_s = t.elapsed_ms() / 1000.0;
        println!(
            "\nminhash native : {:>10.0} items/s ({:.2}s)",
            n as f64 / native_s,
            native_s
        );

        if let Some(rt) = &rt {
            let sk = rt.sketcher("review").expect("sketcher");
            let d = ds.dim();
            let mut x = vec![0f32; n * d];
            for (i, s) in sets.iter().enumerate() {
                for &j in s {
                    x[i * d + j as usize] = 1.0;
                }
            }
            let t = Timer::start();
            let via_xla = sk.sketch_minhash(&x, n, &params).expect("sketch");
            let xla_s = t.elapsed_ms() / 1000.0;
            println!(
                "minhash xla    : {:>10.0} items/s ({:.2}s, interpret-mode pallas)",
                n as f64 / xla_s,
                xla_s
            );
            assert_eq!(native.row(0), via_xla.row(0), "paths must agree");
        }
    }

    // CWS (sift config)
    {
        let ds = Dataset::Sift;
        let cfg = GenConfig { n, seed: 2, threads: 8, cluster_size: 24, background: 0.1 };
        let x = generate_dense(ds, &cfg);
        let params = CwsParams::generate(ds.l(), ds.b(), ds.dim(), 2);

        let t = Timer::start();
        let _native = params.sketch_batch(&x, n, 8);
        let native_s = t.elapsed_ms() / 1000.0;
        println!(
            "\ncws native     : {:>10.0} items/s ({:.2}s)",
            n as f64 / native_s,
            native_s
        );

        if let Some(rt) = &rt {
            let sk = rt.sketcher("sift").expect("sketcher");
            let t = Timer::start();
            let _via = sk.sketch_cws(&x, n, &params).expect("sketch");
            let xla_s = t.elapsed_ms() / 1000.0;
            println!(
                "cws xla        : {:>10.0} items/s ({:.2}s, interpret-mode pallas)",
                n as f64 / xla_s,
                xla_s
            );
        }
    }

    // XLA hamming scan vs native vertical scan
    if let Some(rt) = &rt {
        use bst::sketch::{SketchSet, VerticalSet};
        use bst::util::Rng;
        let (b, l, n) = (2usize, 32usize, 200_000usize);
        let mut rng = Rng::new(3);
        let mut set = SketchSet::zeros(b, l, n);
        for i in 0..n {
            for p in 0..l {
                set.set_char(i, p, rng.below(4) as u8);
            }
        }
        let vert = VerticalSet::from_horizontal(&set);
        let q = set.row(0);

        let t = Timer::start();
        let native_hits = vert.scan(&q, 3).len();
        let native_ms = t.elapsed_ms();

        let scan = rt.scanner("cp").expect("scanner");
        let t = Timer::start();
        let xla_hits = scan.search(&vert, &q, 3).expect("scan").len();
        let xla_ms = t.elapsed_ms();
        assert_eq!(native_hits, xla_hits);
        println!(
            "\nhamming scan ({n} sketches): native {native_ms:.1} ms, xla {xla_ms:.1} ms"
        );
    }
}
