//! End-to-end replication tests: a primary and a follower as real TCP
//! servers in one process. The follower bootstraps over the wire
//! (`snapshot.fetch`), tails the primary's WAL (`wal.fetch`), and must
//! answer reads identically to the primary — including across a WAL
//! rotation gap (re-bootstrap) and a primary stop/restart (reconnect).

use bst::coordinator::engine::{Engine, ShardIndexKind};
use bst::coordinator::{replica, server, ServeConfig};
use bst::sketch::SketchSet;
use bst::store::WalSync;
use bst::trie::bst::BstConfig;
use bst::util::json::Json;
use bst::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const L: usize = 12;

fn make_rows(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..L).map(|_| rng.below(4) as u8).collect())
        .collect();
    (0..n)
        .map(|_| {
            let mut r = centers[rng.below_usize(6)].clone();
            for _ in 0..rng.below_usize(3) {
                let p = rng.below_usize(L);
                r[p] = rng.below(4) as u8;
            }
            r
        })
        .collect()
}

fn make_engine(rows: &[Vec<u8>]) -> Engine {
    let set = SketchSet::from_rows(2, L, rows);
    Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bst_repl_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        let _ = stream.set_nodelay(true);
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn call(&mut self, req: &str) -> Json {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).expect("valid json response")
    }
}

fn enc(r: &[u8]) -> String {
    r.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
}

fn search_ids(client: &mut Client, q: &[u8], tau: usize) -> Vec<u32> {
    let resp = client.call(&format!(r#"{{"op":"search","q":[{}],"tau":{tau}}}"#, enc(q)));
    let mut ids: Vec<u32> = resp
        .get("ids")
        .and_then(|a| a.as_arr())
        .unwrap_or_else(|| panic!("search reply: {resp:?}"))
        .iter()
        .map(|x| x.as_f64().unwrap() as u32)
        .collect();
    ids.sort_unstable();
    ids
}

fn topk_pairs(client: &mut Client, q: &[u8], k: usize) -> Vec<(u32, usize)> {
    let resp = client.call(&format!(r#"{{"op":"topk","q":[{}],"k":{k},"tau":{L}}}"#, enc(q)));
    let ids = resp.get("ids").and_then(|a| a.as_arr()).unwrap();
    let dists = resp.get("dists").and_then(|a| a.as_arr()).unwrap();
    let mut pairs: Vec<(u32, usize)> = ids
        .iter()
        .zip(dists.iter())
        .map(|(i, d)| (i.as_f64().unwrap() as u32, d.as_usize().unwrap()))
        .collect();
    pairs.sort_unstable();
    pairs
}

/// Polls the follower's `repl.status` until `applied_id` reaches `want`
/// (records apply in log order, so earlier deletes have landed too).
fn wait_applied(follower: &mut Client, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let st = follower.call(r#"{"op":"repl.status","v":1}"#);
        assert_eq!(st.get("role").and_then(|r| r.as_str()), Some("follower"), "{st:?}");
        let applied = st.get("applied_id").and_then(|x| x.as_usize()).unwrap_or(0);
        if applied >= want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "follower stuck at applied_id={applied}, want {want}: {st:?}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Asserts read parity between primary and follower: id search at
/// τ ∈ {0, 2, 4} plus top-k, over a handful of probe rows.
fn assert_parity(primary: &mut Client, follower: &mut Client, rows: &[Vec<u8>]) {
    for qi in [0usize, 57, 190] {
        let q = &rows[qi % rows.len()];
        for tau in [0usize, 2, 4] {
            let p = search_ids(primary, q, tau);
            let f = search_ids(follower, q, tau);
            assert_eq!(p, f, "search parity qi={qi} tau={tau}");
        }
        let p = topk_pairs(primary, q, 5);
        let f = topk_pairs(follower, q, 5);
        assert_eq!(p, f, "topk parity qi={qi}");
    }
}

/// Boots a follower off a running primary and returns its server handle
/// plus a connected client.
fn start_follower(
    primary_addr: std::net::SocketAddr,
    local_snap: &std::path::Path,
    poll_ms: u64,
) -> (server::ServerHandle, Client) {
    let boot = replica::bootstrap(&primary_addr.to_string(), local_snap, false)
        .expect("follower bootstrap");
    let cursor = boot.cursor.expect("primary runs with --wal");
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        follow: Some(primary_addr.to_string()),
        follow_poll_ms: poll_ms,
        follow_cursor: Some(cursor),
        ..Default::default()
    };
    let handle = server::serve(Arc::new(boot.engine), cfg).expect("serve follower");
    let client = Client::connect(handle.addr);
    (handle, client)
}

#[test]
fn follower_mirrors_primary_and_rejects_writes() {
    let dir = tmp_dir("mirror");
    let rows = make_rows(300, 0xf01);
    let n0 = rows.len();
    let engine = make_engine(&rows);
    engine.attach_wal(&dir.join("wal"), WalSync::Always).unwrap();
    let p_cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let p_handle = server::serve(Arc::new(engine), p_cfg).expect("serve primary");
    let mut primary = Client::connect(p_handle.addr);

    let (f_handle, mut follower) = start_follower(p_handle.addr, &dir.join("boot.snap"), 10);

    // Versioned envelope over the wire: v:1 echoes, v:99 is refused
    // with a structured error, legacy stays unstamped.
    let pong = follower.call(r#"{"op":"ping","v":1}"#);
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));
    assert_eq!(pong.get("v").and_then(|v| v.as_usize()), Some(1));
    let err = follower.call(r#"{"op":"ping","v":99}"#);
    let code = err.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str());
    assert_eq!(code, Some("unsupported_version"), "{err:?}");
    let pong = follower.call(r#"{"op":"ping"}"#);
    assert!(pong.get("v").is_none(), "legacy replies carry no 'v': {pong:?}");

    // Write burst on the primary: re-insert a slice, delete two ids,
    // merge, then one more insert so applied_id moves past the deletes.
    let burst: Vec<String> = rows[..40].iter().map(|r| format!("[{}]", enc(r))).collect();
    let resp = primary.call(&format!(r#"{{"op":"insert","rows":[{}]}}"#, burst.join(",")));
    assert_eq!(resp.get("first_id").and_then(|x| x.as_usize()), Some(n0), "{resp:?}");
    assert_eq!(
        primary
            .call(&format!(r#"{{"op":"delete","id":{}}}"#, n0 + 1))
            .get("deleted")
            .and_then(|b| b.as_bool()),
        Some(true)
    );
    assert_eq!(
        primary
            .call(r#"{"op":"delete","id":7}"#)
            .get("deleted")
            .and_then(|b| b.as_bool()),
        Some(true)
    );
    primary.call(r#"{"op":"merge"}"#);
    primary.call(&format!(r#"{{"op":"insert","rows":[[{}]]}}"#, enc(&rows[5])));

    wait_applied(&mut follower, n0 + 41);
    assert_parity(&mut primary, &mut follower, &rows);
    // The tombstones shipped too.
    assert!(!search_ids(&mut follower, &rows[1], 0).contains(&((n0 + 1) as u32)));
    assert!(!search_ids(&mut follower, &rows[7], 0).contains(&7u32));

    // Followers are read-only: legacy clients get the bare-string
    // error, versioned clients get the structured read_only code.
    let err = follower.call(&format!(r#"{{"op":"insert","rows":[[{}]]}}"#, enc(&rows[0])));
    assert!(err.get("error").and_then(|e| e.as_str()).is_some(), "{err:?}");
    for req in [
        r#"{"op":"delete","id":0,"v":1}"#,
        r#"{"op":"merge","v":1}"#,
        r#"{"op":"save","path":"/tmp/x.snap","v":1}"#,
        r#"{"op":"snapshot.fetch","v":1}"#,
        r#"{"op":"wal.fetch","from_seq":0,"from_off":0,"v":1}"#,
    ] {
        let err = follower.call(req);
        let code = err.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str());
        assert_eq!(code, Some("read_only"), "{req} → {err:?}");
    }

    // Roles report correctly; a rotated-away cursor is a wal_gap.
    let st = primary.call(r#"{"op":"repl.status","v":1}"#);
    assert_eq!(st.get("role").and_then(|r| r.as_str()), Some("primary"));
    let st = follower.call(r#"{"op":"repl.status","v":1}"#);
    assert!(st.get("last_contact_ms").and_then(|x| x.as_usize()).is_some(), "{st:?}");
    let err = primary.call(r#"{"op":"wal.fetch","from_seq":0,"from_off":0,"v":1}"#);
    let code = err.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str());
    assert_eq!(code, Some("wal_gap"), "segment 0 rotated at bootstrap: {err:?}");

    f_handle.stop();
    p_handle.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn follower_rebootstraps_across_rotation_gap() {
    let dir = tmp_dir("gap");
    let rows = make_rows(250, 0xf02);
    let n0 = rows.len();
    let engine = make_engine(&rows);
    engine.attach_wal(&dir.join("wal"), WalSync::Always).unwrap();
    let p_cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let p_handle = server::serve(Arc::new(engine), p_cfg).expect("serve primary");
    let mut primary = Client::connect(p_handle.addr);

    // Take the bootstrap cursor BEFORE the writes, then let a save op
    // rotate those segments away — the cursor becomes unservable and
    // the follower must recover by re-bootstrapping, not by error-loop.
    let boot = replica::bootstrap(&p_handle.addr.to_string(), &dir.join("boot.snap"), false)
        .expect("bootstrap");
    let stale_cursor = boot.cursor.expect("primary runs with --wal");

    let burst: Vec<String> = rows[..30].iter().map(|r| format!("[{}]", enc(r))).collect();
    primary.call(&format!(r#"{{"op":"insert","rows":[{}]}}"#, burst.join(",")));
    primary.call(&format!(r#"{{"op":"delete","id":{}}}"#, n0 + 2));
    let saved = primary.call(&format!(
        r#"{{"op":"save","path":"{}"}}"#,
        dir.join("rotate.snap").display()
    ));
    assert_eq!(saved.get("ok").and_then(|b| b.as_bool()), Some(true), "{saved:?}");
    let burst2: Vec<String> = rows[30..45].iter().map(|r| format!("[{}]", enc(r))).collect();
    primary.call(&format!(r#"{{"op":"insert","rows":[{}]}}"#, burst2.join(",")));

    let f_cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        follow: Some(p_handle.addr.to_string()),
        follow_poll_ms: 10,
        follow_cursor: Some(stale_cursor),
        ..Default::default()
    };
    let f_handle = server::serve(Arc::new(boot.engine), f_cfg).expect("serve follower");
    let mut follower = Client::connect(f_handle.addr);

    wait_applied(&mut follower, n0 + 45);
    assert_parity(&mut primary, &mut follower, &rows);
    assert!(!search_ids(&mut follower, &rows[2], 0).contains(&((n0 + 2) as u32)));

    f_handle.stop();
    p_handle.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn follower_reconnects_after_primary_restart() {
    let dir = tmp_dir("restart");
    let rows = make_rows(200, 0xf03);
    let n0 = rows.len();
    let wal = dir.join("wal");
    let snap = dir.join("cold.snap");
    make_engine(&rows).save(&snap).unwrap();

    // Pick a fixed port so the restarted primary comes back at the same
    // address the follower keeps polling.
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let p_addr = probe.local_addr().unwrap();
    drop(probe);

    let engine_a = Engine::load(&snap).unwrap();
    engine_a.attach_wal(&wal, WalSync::Always).unwrap();
    let p_cfg = ServeConfig { addr: p_addr.to_string(), ..Default::default() };
    let p_handle = server::serve(Arc::new(engine_a), p_cfg).expect("serve primary");
    let mut primary = Client::connect(p_addr);

    let (f_handle, mut follower) = start_follower(p_addr, &dir.join("boot.snap"), 10);

    let burst: Vec<String> = rows[..25].iter().map(|r| format!("[{}]", enc(r))).collect();
    primary.call(&format!(r#"{{"op":"insert","rows":[{}]}}"#, burst.join(",")));
    wait_applied(&mut follower, n0 + 25);

    // Primary goes away mid-stream; the follower keeps serving reads.
    drop(primary);
    p_handle.stop();
    let during = search_ids(&mut follower, &rows[0], 2);
    assert!(!during.is_empty(), "follower serves while the primary is down");

    // Restart: cold snapshot + WAL replay restores the acknowledged
    // writes; the follower's cursor is still valid (same segments) so
    // it reconnects and resumes tailing without a re-bootstrap.
    let engine_b = Engine::load(&snap).unwrap();
    let rep = engine_b.attach_wal(&wal, WalSync::Always).unwrap();
    assert_eq!(rep.replayed_inserts, 25, "restart replays the burst");
    let p_cfg = ServeConfig { addr: p_addr.to_string(), ..Default::default() };
    let p_handle = server::serve(Arc::new(engine_b), p_cfg).expect("re-serve primary");
    let mut primary = Client::connect(p_addr);

    let burst2: Vec<String> = rows[25..40].iter().map(|r| format!("[{}]", enc(r))).collect();
    primary.call(&format!(r#"{{"op":"insert","rows":[{}]}}"#, burst2.join(",")));
    primary.call(&format!(r#"{{"op":"delete","id":{}}}"#, n0));
    primary.call(&format!(r#"{{"op":"insert","rows":[[{}]]}}"#, enc(&rows[9])));

    wait_applied(&mut follower, n0 + 41);
    assert_parity(&mut primary, &mut follower, &rows);
    assert!(!search_ids(&mut follower, &rows[0], 0).contains(&(n0 as u32)));

    f_handle.stop();
    p_handle.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
