//! Cross-method agreement for the collector-based query path: all four
//! tries and all six indexes must report identical id sets via
//! `CollectIds`, identical counts via `CountOnly`, and `TopK(k)` must
//! equal the brute-force distances sorted by `(dist, id)` — fuzzed over
//! b ∈ {1,2,4,8}, τ ∈ 0..=6 and duplicate-heavy databases.

use bst::index::signature::count_signatures;
use bst::index::{HmSearch, LinearScan, Mih, MultiBst, SearchIndex, Sih, SingleBst};
use bst::query::{CollectIds, CountOnly, QueryCtx, StatsObserver, TopK};
use bst::sketch::hamming::ham_chars;
use bst::sketch::SketchSet;
use bst::trie::bst::{BstConfig, BstTrie};
use bst::trie::fst::FstTrie;
use bst::trie::louds::LoudsTrie;
use bst::trie::pointer::PointerTrie;
use bst::trie::{SketchTrie, SortedSketches};
use bst::util::Rng;

/// Duplicate-heavy database: a few centers, light edits, plus exact
/// duplicates of the first rows.
fn dup_heavy_rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let mut rows: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            let mut r = centers[rng.below_usize(6)].clone();
            for _ in 0..rng.below_usize(3) {
                let p = rng.below_usize(l);
                r[p] = rng.below(1 << b) as u8;
            }
            r
        })
        .collect();
    // exact duplicates — posting groups with several ids
    for i in 0..12.min(n) {
        rows.push(rows[i].clone());
    }
    rows
}

fn brute_ids(rows: &[Vec<u8>], q: &[u8], tau: usize) -> Vec<u32> {
    (0..rows.len())
        .filter(|&i| ham_chars(&rows[i], q) <= tau)
        .map(|i| i as u32)
        .collect()
}

fn brute_topk(rows: &[Vec<u8>], q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
    let mut all: Vec<(usize, u32)> = (0..rows.len())
        .map(|i| (ham_chars(&rows[i], q), i as u32))
        .filter(|&(d, _)| d <= tau)
        .collect();
    all.sort_unstable();
    all.truncate(k);
    all.into_iter().map(|(d, id)| (id, d)).collect()
}

fn check_trie<T: SketchTrie>(
    trie: &T,
    ctx: &mut QueryCtx,
    q: &[u8],
    tau: usize,
    expect: &[u32],
    label: &str,
) {
    let mut got = Vec::new();
    let mut coll = CollectIds::new(tau, &mut got);
    trie.run(q, ctx, &mut coll);
    got.sort();
    got.dedup();
    assert_eq!(got, expect, "{label} ids tau={tau}");

    let mut cnt = CountOnly::new(tau);
    trie.run(q, ctx, &mut cnt);
    assert_eq!(cnt.count(), expect.len(), "{label} count tau={tau}");
}

#[test]
fn prop_tries_and_indexes_agree_across_collectors() {
    for &(b, l, seed) in &[(1usize, 16usize, 11u64), (2, 12, 12), (4, 8, 13), (8, 6, 14)] {
        let rows = dup_heavy_rows(b, l, 180, seed);
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let pt = PointerTrie::build(&ss);
        let louds = LoudsTrie::build(&ss);
        let fst = FstTrie::build(&ss);

        let linear = LinearScan::build(&set);
        let si = SingleBst::build(&set, BstConfig::default());
        let mi = MultiBst::build(&set, 2);
        let mih = Mih::build(&set, 2);
        let sih = Sih::build(&set);

        // HmSearch serves thresholds up to its bucket: one build per τ.
        let hms: Vec<HmSearch> = (0..=6usize.min(l))
            .map(|tau| HmSearch::build(&set, tau.max(1)))
            .collect();

        let mut rng = Rng::new(seed ^ 0xF00D);
        let mut ctx = QueryCtx::new();
        for case in 0..6 {
            let q: Vec<u8> = if case % 2 == 0 {
                rows[rng.below_usize(rows.len())].clone()
            } else {
                (0..l).map(|_| rng.below(1 << b) as u8).collect()
            };
            for tau in 0..=6usize.min(l) {
                let expect = brute_ids(&rows, &q, tau);

                // all four tries, ids + counts, sharing one QueryCtx
                check_trie(&bst, &mut ctx, &q, tau, &expect, "bst");
                check_trie(&pt, &mut ctx, &q, tau, &expect, "pointer");
                check_trie(&louds, &mut ctx, &q, tau, &expect, "louds");
                check_trie(&fst, &mut ctx, &q, tau, &expect, "fst");

                // indexes: SIH only where its signature ball is tractable;
                // HmSearch is built per-τ below.
                let mut indexes: Vec<(&str, &dyn SearchIndex)> = vec![
                    ("linear", &linear),
                    ("si-bst", &si),
                    ("mi-bst", &mi),
                    ("mih", &mih),
                ];
                if count_signatures(b, l, tau) < 60_000 {
                    indexes.push(("sih", &sih));
                }
                for (label, idx) in &indexes {
                    let mut got = idx.search(&q, tau);
                    got.sort();
                    got.dedup();
                    assert_eq!(got, expect, "{label} ids b={b} tau={tau}");
                    assert_eq!(
                        idx.count(&q, tau),
                        expect.len(),
                        "{label} count b={b} tau={tau}"
                    );
                    for k in [1usize, 7, 64] {
                        let got = idx.top_k(&q, k, tau);
                        let expect_k = brute_topk(&rows, &q, k, tau);
                        assert_eq!(got, expect_k, "{label} topk b={b} tau={tau} k={k}");
                    }
                }

                let hm = &hms[tau];
                let mut got = hm.search(&q, tau);
                got.sort();
                got.dedup();
                assert_eq!(got, expect, "hmsearch ids b={b} tau={tau}");
                assert_eq!(hm.count(&q, tau), expect.len(), "hmsearch count b={b} tau={tau}");
            }
        }
    }
}

#[test]
fn prop_topk_unbounded_radius_equals_brute_force() {
    for &(b, l, seed) in &[(2usize, 10usize, 21u64), (4, 8, 22)] {
        let rows = dup_heavy_rows(b, l, 150, seed);
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let mut ctx = QueryCtx::new();
        for _ in 0..5 {
            let q: Vec<u8> = (0..l).map(|_| rng.below(1 << b) as u8).collect();
            for k in [1usize, 5, 40, 1000] {
                let mut coll = TopK::new(k, l);
                bst.run(&q, &mut ctx, &mut coll);
                let got = coll.finish();
                assert_eq!(got, brute_topk(&rows, &q, k, l), "b={b} k={k}");
            }
        }
    }
}

#[test]
fn prop_stats_observer_counts_traversal_work() {
    let rows = dup_heavy_rows(2, 12, 200, 31);
    let set = SketchSet::from_rows(2, 12, &rows);
    let ss = SortedSketches::build(&set);
    let bst = BstTrie::build(&ss, BstConfig::default());
    let mut ctx = QueryCtx::new();
    let q = rows[0].clone();
    let mut prev_visited = 0usize;
    for tau in 0..=4usize {
        let mut obs = StatsObserver::new(CountOnly::new(tau));
        bst.run(&q, &mut ctx, &mut obs);
        assert_eq!(obs.stats.emitted, brute_ids(&rows, &q, tau).len(), "tau={tau}");
        assert!(
            obs.stats.visited >= prev_visited,
            "looser budgets must visit at least as many nodes (tau={tau})"
        );
        prev_visited = obs.stats.visited;
    }
}
