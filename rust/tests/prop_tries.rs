//! Property-based trie invariants: for random databases, every trie
//! representation must (a) report identical topology statistics, (b)
//! prune soundly (search results invariant under τ monotonicity), and
//! (c) agree with the pointer-trie oracle under random layer overrides.

use bst::sketch::SketchSet;
use bst::trie::bst::{BstConfig, BstTrie, MiddleRepr};
use bst::trie::fst::FstTrie;
use bst::trie::louds::LoudsTrie;
use bst::trie::pointer::PointerTrie;
use bst::trie::{SketchTrie, SortedSketches};
use bst::util::Rng;

fn random_db(rng: &mut Rng) -> (usize, usize, SketchSet) {
    let b = *[1usize, 2, 4, 8].iter().nth(rng.below_usize(4)).unwrap();
    let l = 2 + rng.below_usize(15.min(64 / b * 4));
    let n = 50 + rng.below_usize(800);
    let clustered = rng.f64() < 0.5;
    let rows: Vec<Vec<u8>> = if clustered {
        let centers: Vec<Vec<u8>> = (0..5)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut r = centers[rng.below_usize(5)].clone();
                for _ in 0..rng.below_usize(3) {
                    let p = rng.below_usize(l);
                    r[p] = rng.below(1 << b) as u8;
                }
                r
            })
            .collect()
    } else {
        (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect()
    };
    (b, l, SketchSet::from_rows(b, l, &rows))
}

#[test]
fn prop_node_counts_agree_across_representations() {
    let mut rng = Rng::new(0x7219);
    for _ in 0..25 {
        let (_b, _l, set) = random_db(&mut rng);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let louds = LoudsTrie::build(&ss);
        let fst = FstTrie::build(&ss);
        assert_eq!(pt.node_count(), ss.total_nodes());
        assert_eq!(bst.node_count(), ss.total_nodes());
        assert_eq!(louds.node_count(), ss.total_nodes());
        assert_eq!(fst.node_count(), ss.total_nodes());
    }
}

#[test]
fn prop_search_monotone_in_tau() {
    let mut rng = Rng::new(0x7220);
    for _ in 0..20 {
        let (b, l, set) = random_db(&mut rng);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let q: Vec<u8> = (0..l).map(|_| rng.below(1 << b) as u8).collect();
        let mut prev: Vec<u32> = Vec::new();
        for tau in 0..=l {
            let mut cur = bst.search(&q, tau);
            cur.sort();
            // result set grows monotonically with tau
            assert!(prev.iter().all(|id| cur.binary_search(id).is_ok()), "tau={tau}");
            prev = cur;
        }
        // tau = L returns everything
        assert_eq!(prev.len(), set.n());
    }
}

#[test]
fn prop_random_layer_configs_match_oracle() {
    let mut rng = Rng::new(0x7221);
    for case in 0..30 {
        let (b, l, set) = random_db(&mut rng);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        // random (lm, ls, repr) override
        let lm = rng.below_usize(l + 1);
        let ls = lm + rng.below_usize(l - lm + 1);
        let repr = match rng.below_usize(3) {
            0 => Some(MiddleRepr::Table),
            1 => Some(MiddleRepr::List),
            _ => None,
        };
        let cfg = BstConfig { lm: Some(lm), ls: Some(ls), force_repr: repr, ..Default::default() };
        let bst = BstTrie::build(&ss, cfg);
        for _ in 0..6 {
            let q: Vec<u8> = (0..l).map(|_| rng.below(1 << b) as u8).collect();
            let tau = rng.below_usize(4);
            let mut a = pt.search(&q, tau);
            let mut c = bst.search(&q, tau);
            a.sort();
            c.sort();
            assert_eq!(a, c, "case={case} b={b} l={l} lm={lm} ls={ls} {repr:?} tau={tau}");
        }
    }
}

#[test]
fn prop_exact_lookup_returns_posting_group() {
    let mut rng = Rng::new(0x7222);
    for _ in 0..20 {
        let (_b, _l, set) = random_db(&mut rng);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        // tau = 0 on a database row returns exactly the ids with equal rows
        let probe = rng.below_usize(set.n());
        let q = set.row(probe);
        let mut got = bst.search(&q, 0);
        got.sort();
        let expect: Vec<u32> = (0..set.n())
            .filter(|&i| set.row(i) == q)
            .map(|i| i as u32)
            .collect();
        assert_eq!(got, expect);
    }
}

#[test]
fn prop_space_ordering_bst_smallest() {
    // On databases large enough for the asymptotics to show, bST must not
    // exceed LOUDS or FST (Table III's space column).
    let mut rng = Rng::new(0x7223);
    let mut wins = 0usize;
    let mut total = 0usize;
    for _ in 0..10 {
        let b = 2usize;
        let l = 16usize;
        let n = 4000;
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let louds = LoudsTrie::build(&ss);
        let fst = FstTrie::build(&ss);
        total += 1;
        if bst.heap_bytes() <= louds.heap_bytes() && bst.heap_bytes() <= fst.heap_bytes() {
            wins += 1;
        }
    }
    assert_eq!(wins, total, "bST must be smallest on all runs");
}
