//! Property suite for blocked execution (PR 6): the blocked batch path
//! (`Engine::run_batch_blocked`) must be result-identical to
//! one-at-a-time execution across b ∈ {1, 2, 4, 8}, mixed-τ batches,
//! all three query modes and dynamic shards (post-insert / delete /
//! merge state, so the blocked path crosses base, sealed and active
//! delta segments plus tombstones).
//!
//! Also the save-under-writes epoch fence: a snapshot taken while
//! insert threads are hammering the engine must be *exactly*
//! consistent — it loads cleanly and answers queries identically to a
//! from-scratch oracle over precisely the first `n` rows of the
//! serialized write stream.

use bst::coordinator::engine::{Engine, QueryMode, QueryResult, ShardIndexKind};
use bst::sketch::hamming::ham_chars;
use bst::sketch::SketchSet;
use bst::trie::bst::BstConfig;
use bst::util::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Shapes exercising every alphabet width.
const SHAPES: &[(usize, usize)] = &[(1, 16), (2, 12), (4, 8), (8, 6)];

/// Widths swept against the serial baseline (1 delegates to serial; 64
/// is the kernel live-mask cap, so every batch fits in one block).
const WIDTHS: &[usize] = &[2, 4, 8, 64];

fn random_row(rng: &mut Rng, b: usize, l: usize, centers: &[Vec<u8>]) -> Vec<u8> {
    let mut row = centers[rng.below_usize(centers.len())].clone();
    for _ in 0..rng.below_usize(3) {
        let p = rng.below_usize(l);
        row[p] = rng.below(1 << b) as u8;
    }
    row
}

/// Id order inside `Ids` results is shard-arrival order (racy); sort
/// before comparing. Count and top-k are exact as-is — top-k order by
/// `(dist, id)` is part of the blocked-execution contract.
fn canon(r: QueryResult) -> QueryResult {
    match r {
        QueryResult::Ids(mut v) => {
            v.sort_unstable();
            QueryResult::Ids(v)
        }
        other => other,
    }
}

#[test]
fn blocked_execution_matches_serial_across_shapes_and_widths() {
    for &(b, l) in SHAPES {
        let mut rng = Rng::new((0xB10C + b * 257 + l) as u64);
        let centers: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let initial: Vec<Vec<u8>> = (0..220)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        let set = SketchSet::from_rows(b, l, &initial);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        engine.set_merge_threshold(usize::MAX);

        // Dynamic shard state: a merged delta, tombstones, and a live
        // active delta — the blocked scan must cross all of them.
        let grown: Vec<Vec<u8>> = (0..60).map(|_| random_row(&mut rng, b, l, &centers)).collect();
        engine.insert_batch(&grown).unwrap();
        for id in [3u32, 100, 221, 250, 279] {
            assert!(engine.delete(id), "id {id} exists and is alive");
        }
        engine.merge();
        let tail: Vec<Vec<u8>> = (0..25).map(|_| random_row(&mut rng, b, l, &centers)).collect();
        engine.insert_batch(&tail).unwrap();

        // Mixed batch: every mode, mixed taus (grouping must split and
        // re-scatter to request order), queries biased toward real rows.
        let batch: Vec<(Arc<[u8]>, usize, QueryMode)> = (0..24)
            .map(|i| {
                let q: Vec<u8> = if i % 2 == 0 {
                    initial[rng.below_usize(initial.len())].clone()
                } else {
                    (0..l).map(|_| rng.below(1 << b) as u8).collect()
                };
                let tau = [0usize, 1, 2, 4][i % 4];
                let mode = match i % 3 {
                    0 => QueryMode::Ids,
                    1 => QueryMode::Count,
                    _ => QueryMode::TopK(1 + i % 5),
                };
                (Arc::from(q.as_slice()), tau, mode)
            })
            .collect();

        let serial: Vec<QueryResult> = engine.run_batch(&batch).into_iter().map(canon).collect();
        for &width in WIDTHS {
            let blocked: Vec<QueryResult> = engine
                .run_batch_blocked(&batch, width)
                .into_iter()
                .map(canon)
                .collect();
            assert_eq!(blocked, serial, "b={b} width={width}");
        }
    }
}

/// Satellite: the save-under-writes fence. Writer threads insert
/// batches while the main thread snapshots repeatedly; every snapshot
/// must load cleanly (no id-accounting corruption) and answer exactly
/// like an oracle over the first `loaded.n()` rows of the write stream
/// (ids are assigned and enqueued under the same lock the save fences
/// on, so id order *is* stream order).
#[test]
fn save_under_concurrent_inserts_is_exactly_consistent() {
    let (b, l) = (2usize, 12usize);
    let mut rng = Rng::new(0xFE11CE);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let n0 = 150usize;
    let initial: Vec<Vec<u8>> = (0..n0).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    let set = SketchSet::from_rows(b, l, &initial);
    let engine = Arc::new(Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default())));
    engine.set_merge_threshold(40); // background merges race the saves too

    let dir = std::env::temp_dir().join("bst_prop_block");
    std::fs::create_dir_all(&dir).unwrap();

    let writers: Vec<_> = (0..3u64)
        .map(|t| {
            let eng = Arc::clone(&engine);
            let mut trng = Rng::new(0x5EED ^ (t * 0x9E37_79B9));
            let centers = centers.clone();
            std::thread::spawn(move || {
                let mut placed: Vec<(u32, Vec<Vec<u8>>)> = Vec::new();
                for _ in 0..10 {
                    let m = 1 + trng.below_usize(12);
                    let batch: Vec<Vec<u8>> = (0..m)
                        .map(|_| random_row(&mut trng, b, l, &centers))
                        .collect();
                    let range = eng.insert_batch(&batch).unwrap();
                    placed.push((range.start, batch));
                }
                placed
            })
        })
        .collect();

    let mut snaps = Vec::new();
    for i in 0..6 {
        std::thread::sleep(Duration::from_millis(2));
        let path = dir.join(format!("under_writes_{i}.snap"));
        engine.save(&path).unwrap();
        snaps.push(path);
    }

    // Reconstruct the id-ordered write stream from what the writers
    // actually placed. The id space must come out contiguous and
    // uniquely assigned — the insert lock's own contract.
    let mut rows_by_id: Vec<Option<Vec<u8>>> = initial.iter().cloned().map(Some).collect();
    for h in writers {
        for (start, batch) in h.join().unwrap() {
            let start = start as usize;
            if rows_by_id.len() < start + batch.len() {
                rows_by_id.resize(start + batch.len(), None);
            }
            for (k, row) in batch.into_iter().enumerate() {
                assert!(
                    rows_by_id[start + k].replace(row).is_none(),
                    "id {} assigned twice",
                    start + k
                );
            }
        }
    }
    let rows: Vec<Vec<u8>> = rows_by_id
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("hole in the id space at {i}")))
        .collect();

    // One more snapshot after the writers joined: covers the full stream.
    let final_path = dir.join("under_writes_final.snap");
    engine.save(&final_path).unwrap();
    snaps.push(final_path);

    for (si, path) in snaps.iter().enumerate() {
        let loaded = Engine::load(path)
            .unwrap_or_else(|e| panic!("mid-traffic snapshot {si} corrupt: {e:?}"));
        let n = loaded.n();
        assert!(n >= n0 && n <= rows.len(), "snapshot {si}: n={n}");
        if si + 1 == snaps.len() {
            assert_eq!(n, rows.len(), "post-join snapshot holds everything");
        }
        for probe in 0..4usize {
            let q: Vec<u8> = if probe % 2 == 0 {
                rows[(probe * 37) % n].clone()
            } else {
                (0..l).map(|_| rng.below(1 << b) as u8).collect()
            };
            for tau in [0usize, 2] {
                let mut got = loaded.search(&q, tau);
                got.sort_unstable();
                let expect: Vec<u32> = (0..n)
                    .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, expect, "snapshot {si}: search n={n} tau={tau}");
                assert_eq!(
                    loaded.count(&q, tau),
                    expect.len(),
                    "snapshot {si}: count n={n} tau={tau}"
                );
            }
        }
        std::fs::remove_file(path).unwrap();
    }
}
