//! Cross-method agreement: every index must return exactly the linear-scan
//! ground truth on randomized clustered databases — the paper's core
//! correctness contract, checked across (b, L, τ, m) configurations and
//! many seeds.

use bst::index::{
    HmSearch, LinearScan, Mih, MultiBst, SearchIndex, Sih, SingleBst, SingleFst, SingleLouds,
};
use bst::sketch::SketchSet;
use bst::trie::bst::BstConfig;
use bst::util::Rng;

/// Clustered random database (near-duplicates + background noise).
fn make_db(b: usize, l: usize, n: usize, seed: u64) -> SketchSet {
    let mut rng = Rng::new(seed);
    let n_centers = 12;
    let centers: Vec<Vec<u8>> = (0..n_centers)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            if rng.f64() < 0.15 {
                (0..l).map(|_| rng.below(1 << b) as u8).collect()
            } else {
                let mut r = centers[rng.below_usize(n_centers)].clone();
                let edits = rng.below_usize(l / 2 + 1);
                for _ in 0..edits {
                    let p = rng.below_usize(l);
                    r[p] = rng.below(1 << b) as u8;
                }
                r
            }
        })
        .collect();
    SketchSet::from_rows(b, l, &rows)
}

fn queries(set: &SketchSet, k: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed ^ 0x71);
    let mut qs: Vec<Vec<u8>> = (0..k / 2)
        .map(|_| set.row(rng.below_usize(set.n())))
        .collect();
    // plus pure-random queries (not necessarily in the database)
    for _ in 0..k - qs.len() {
        qs.push((0..set.l()).map(|_| rng.below(set.sigma() as u64) as u8).collect());
    }
    qs
}

fn sorted(mut v: Vec<u32>) -> Vec<u32> {
    v.sort();
    v.dedup();
    v
}

#[test]
fn all_methods_agree_b2() {
    for seed in [1u64, 2, 3] {
        let set = make_db(2, 16, 1500, seed);
        let truth = LinearScan::build(&set);
        let si = SingleBst::build(&set, BstConfig::default());
        let louds = SingleLouds::build(&set);
        let fst = SingleFst::build(&set);
        let mi2 = MultiBst::build(&set, 2);
        let mi3 = MultiBst::build(&set, 3);
        let sih = Sih::build(&set);
        let mih2 = Mih::build(&set, 2);
        let hm = HmSearch::build(&set, 5);
        for q in queries(&set, 12, seed) {
            for tau in [0usize, 1, 2, 3, 5] {
                let expect = sorted(truth.search(&q, tau));
                assert_eq!(sorted(si.search(&q, tau)), expect, "SI-bST seed={seed} tau={tau}");
                assert_eq!(sorted(louds.search(&q, tau)), expect, "LOUDS");
                assert_eq!(sorted(fst.search(&q, tau)), expect, "FST");
                assert_eq!(sorted(mi2.search(&q, tau)), expect, "MI-bST m=2");
                assert_eq!(sorted(mi3.search(&q, tau)), expect, "MI-bST m=3");
                if tau <= 2 {
                    assert_eq!(sorted(sih.search(&q, tau)), expect, "SIH");
                }
                assert_eq!(sorted(mih2.search(&q, tau)), expect, "MIH m=2");
                assert_eq!(sorted(hm.search(&q, tau)), expect, "HmSearch");
            }
        }
    }
}

#[test]
fn all_methods_agree_b4_and_b8() {
    for &(b, l, n) in &[(4usize, 12usize, 900usize), (8, 8, 700)] {
        let set = make_db(b, l, n, (b + l) as u64);
        let truth = LinearScan::build(&set);
        let si = SingleBst::build(&set, BstConfig::default());
        let mi2 = MultiBst::build(&set, 2);
        let mih3 = Mih::build(&set, 3);
        let hm = HmSearch::build(&set, 4);
        for q in queries(&set, 8, b as u64) {
            for tau in [0usize, 1, 3, 4] {
                let expect = sorted(truth.search(&q, tau));
                assert_eq!(sorted(si.search(&q, tau)), expect, "SI-bST b={b} tau={tau}");
                assert_eq!(sorted(mi2.search(&q, tau)), expect, "MI-bST b={b}");
                assert_eq!(sorted(mih3.search(&q, tau)), expect, "MIH b={b}");
                assert_eq!(sorted(hm.search(&q, tau)), expect, "HmSearch b={b}");
            }
        }
    }
}

#[test]
fn b1_binary_sketches_work() {
    // the b=1 degenerate case (classic binary sketches)
    let set = make_db(1, 32, 1200, 77);
    let truth = LinearScan::build(&set);
    let si = SingleBst::build(&set, BstConfig::default());
    let mi = MultiBst::build(&set, 4);
    for q in queries(&set, 8, 78) {
        for tau in [0usize, 2, 5] {
            let expect = sorted(truth.search(&q, tau));
            assert_eq!(sorted(si.search(&q, tau)), expect);
            assert_eq!(sorted(mi.search(&q, tau)), expect);
        }
    }
}

#[test]
fn big_tau_returns_whole_db() {
    let set = make_db(2, 8, 400, 99);
    let si = SingleBst::build(&set, BstConfig::default());
    let q = set.row(0);
    let hits = sorted(si.search(&q, 8));
    assert_eq!(hits, (0..400u32).collect::<Vec<_>>());
}

#[test]
fn generated_workloads_agree() {
    // end-to-end over the actual synthetic pipelines (minhash + CWS)
    use bst::data::{generate_workload, Dataset, GenConfig};
    for ds in [Dataset::Review, Dataset::Sift] {
        let cfg = GenConfig { n: 3000, seed: 5, threads: 4, cluster_size: 16, background: 0.1 };
        let w = generate_workload(ds, &cfg);
        let truth = LinearScan::build(&w.sketches);
        let si = SingleBst::build(&w.sketches, BstConfig::default());
        let mi = MultiBst::build(&w.sketches, 2);
        for q in w.queries.iter().take(15) {
            for tau in [1usize, 3] {
                let expect = sorted(truth.search(q, tau));
                assert_eq!(sorted(si.search(q, tau)), expect, "{ds:?}");
                assert_eq!(sorted(mi.search(q, tau)), expect, "{ds:?}");
            }
        }
    }
}
