//! Property suite for the write-ahead log (PR 8): across b ∈ {1, 2, 4,
//! 8} and random insert / delete / merge interleavings, *every*
//! byte-prefix of the WAL — each one a possible power-loss outcome —
//! must parse to a record-boundary prefix of the full log, and a fresh
//! engine replaying it must answer exactly like a linear-scan oracle of
//! the writes that survived the cut. Recovered logs must also stay
//! appendable: writes after a replay are themselves replayed by the
//! next recovery.
//!
//! Fault-injected tears (short appends, fsync failures, worker panics)
//! live in the unit suites (`store::wal`, `coordinator::engine`), which
//! build with the failpoint registry; this integration suite tears the
//! log byte-by-byte instead, which needs no injection hooks.

use bst::coordinator::engine::{Engine, ShardIndexKind};
use bst::sketch::hamming::ham_chars;
use bst::sketch::SketchSet;
use bst::store::wal::{self, WalRecord, WalSync};
use bst::trie::bst::BstConfig;
use bst::util::Rng;
use std::path::{Path, PathBuf};

/// Shapes exercising every alphabet width.
const SHAPES: &[(usize, usize)] = &[(1, 16), (2, 12), (4, 8), (8, 6)];

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bst_prop_wal_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The oracle: base rows plus a WAL record sequence applied in order.
/// Inserts are contiguous by construction (the engine reserves id
/// ranges under the insert lock, in log order), so any prefix of the
/// log extends the base without gaps.
struct Oracle {
    rows: Vec<Vec<u8>>,
    alive: Vec<bool>,
}

impl Oracle {
    fn new(base: &[Vec<u8>], records: &[WalRecord], l: usize) -> Oracle {
        let mut o = Oracle { rows: base.to_vec(), alive: vec![true; base.len()] };
        for rec in records {
            match rec {
                WalRecord::Insert { start_id, n, chars } => {
                    assert_eq!(*start_id as usize, o.rows.len(), "log ids are contiguous");
                    assert_eq!(chars.len(), *n as usize * l);
                    for row in chars.chunks_exact(l) {
                        o.rows.push(row.to_vec());
                        o.alive.push(true);
                    }
                }
                WalRecord::Delete { id } => {
                    if (*id as usize) < o.rows.len() {
                        o.alive[*id as usize] = false;
                    }
                }
                WalRecord::MergeMarker => {}
            }
        }
        o
    }

    fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        (0..self.rows.len())
            .filter(|&i| self.alive[i] && ham_chars(&self.rows[i], q) <= tau)
            .map(|i| i as u32)
            .collect()
    }

    fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        let mut all: Vec<(usize, u32)> = (0..self.rows.len())
            .filter(|&i| self.alive[i])
            .map(|i| (ham_chars(&self.rows[i], q), i as u32))
            .filter(|&(d, _)| d <= tau)
            .collect();
        all.sort_unstable();
        all.truncate(k);
        all.into_iter().map(|(d, id)| (id, d)).collect()
    }
}

fn random_row(rng: &mut Rng, b: usize, l: usize, centers: &[Vec<u8>]) -> Vec<u8> {
    let mut row = centers[rng.below_usize(centers.len())].clone();
    for _ in 0..rng.below_usize(3) {
        let p = rng.below_usize(l);
        row[p] = rng.below(1 << b) as u8;
    }
    row
}

fn check_engine(engine: &Engine, oracle: &Oracle, rng: &mut Rng, b: usize, l: usize, tag: &str) {
    assert_eq!(engine.n(), oracle.rows.len(), "{tag}: id high-water mark");
    for _ in 0..2 {
        let q: Vec<u8> = if oracle.rows.is_empty() || rng.below(2) == 0 {
            (0..l).map(|_| rng.below(1 << b) as u8).collect()
        } else {
            oracle.rows[rng.below_usize(oracle.rows.len())].clone()
        };
        for tau in [0usize, 2, 4] {
            let mut got = engine.search(&q, tau);
            got.sort_unstable();
            assert_eq!(got, oracle.search(&q, tau), "{tag}: search b={b} tau={tau}");
            assert_eq!(engine.count(&q, tau), got.len(), "{tag}: count b={b} tau={tau}");
        }
        assert_eq!(engine.top_k(&q, 5, l), oracle.top_k(&q, 5, l), "{tag}: topk b={b}");
    }
}

/// Writes `bytes` as the sole segment (`engine.wal.0`) of a fresh log
/// directory and returns the segment base path.
fn prefix_log(dir: &Path, bytes: &[u8]) -> PathBuf {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("engine.wal.0"), bytes).unwrap();
    dir.join("engine.wal")
}

/// Generates a log with a writer engine, then (a) parses every
/// byte-prefix — each must yield a record-boundary prefix of the full
/// record sequence — and (b) replays sampled prefixes into fresh
/// engines, which must match the oracle of exactly the surviving
/// writes; the full-log replay must additionally stay appendable and
/// survive a second recovery.
#[test]
fn prop_every_wal_prefix_replays_to_acked_state() {
    for &(b, l) in SHAPES {
        let mut rng = Rng::new((0x3A1 + b * 131 + l) as u64);
        let centers: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let base: Vec<Vec<u8>> = (0..60)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        let set = SketchSet::from_rows(b, l, &base);

        // Writer: every acknowledged op lands in the log first.
        let gen_dir = fresh_dir(&format!("gen_{b}"));
        let wal_base = gen_dir.join("engine.wal");
        let writer = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let rep = writer.attach_wal(&wal_base, WalSync::Always).unwrap();
        assert_eq!(rep.replayed_inserts + rep.replayed_deletes, 0, "fresh log is empty");
        let seed_batch: Vec<Vec<u8>> =
            (0..5).map(|_| random_row(&mut rng, b, l, &centers)).collect();
        writer.insert_batch(&seed_batch).unwrap();
        for _ in 0..9 {
            match rng.below(5) {
                0..=2 => {
                    let m = 1 + rng.below_usize(10);
                    let batch: Vec<Vec<u8>> =
                        (0..m).map(|_| random_row(&mut rng, b, l, &centers)).collect();
                    writer.insert_batch(&batch).unwrap();
                }
                3 => {
                    let _ = writer.delete(rng.below(writer.n() as u64) as u32);
                }
                _ => {
                    writer.merge();
                }
            }
        }
        drop(writer);
        let full = std::fs::read(gen_dir.join("engine.wal.0")).unwrap();
        let all = wal::read_records(&wal_base).unwrap();
        assert!(all.iter().any(|r| matches!(r, WalRecord::Insert { .. })), "log has inserts");

        // (a) Every byte-prefix — a possible crash point — parses to a
        // record-boundary prefix of the full sequence, never garbage.
        let parse_dir = std::env::temp_dir()
            .join(format!("bst_prop_wal_{}_parse_{b}", std::process::id()));
        for cut in 0..=full.len() {
            let base_path = prefix_log(&parse_dir, &full[..cut]);
            let recs = wal::read_records(&base_path).unwrap();
            assert_eq!(recs, all[..recs.len()], "prefix {cut} of {}", full.len());
        }

        // (b) Replay sampled prefixes into fresh engines (a different
        // shard count than the writer: striping is the replayer's).
        let mut cuts = vec![0usize, full.len()];
        cuts.extend((0..10).map(|_| rng.below_usize(full.len() + 1)));
        let replay_dir = std::env::temp_dir()
            .join(format!("bst_prop_wal_{}_replay_{b}", std::process::id()));
        for cut in cuts {
            let base_path = prefix_log(&replay_dir, &full[..cut]);
            let recs = wal::read_records(&base_path).unwrap();
            let oracle = Oracle::new(&base, &recs, l);
            let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
            let rep = engine.attach_wal(&base_path, WalSync::Always).unwrap();
            // Recovery physically truncated the torn suffix.
            let seg_len = std::fs::metadata(replay_dir.join("engine.wal.0")).unwrap().len();
            assert_eq!(seg_len + rep.truncated_bytes, cut as u64, "cut {cut}");
            check_engine(&engine, &oracle, &mut rng, b, l, &format!("cut {cut}"));

            if cut == full.len() {
                assert_eq!(rep.truncated_bytes, 0, "clean log has no torn tail");
                // The recovered engine is a live writer: new ops append
                // past the replayed tail and survive a second recovery.
                let extra: Vec<Vec<u8>> =
                    (0..7).map(|_| random_row(&mut rng, b, l, &centers)).collect();
                let range = engine.insert_batch(&extra).unwrap();
                assert_eq!(range.start as usize, oracle.rows.len(), "ids continue");
                let victim = range.start + 2;
                assert!(engine.delete(victim));
                drop(engine);
                let recs2 = wal::read_records(&base_path).unwrap();
                assert_eq!(recs2.len(), recs.len() + 2, "replay appended two records");
                let oracle2 = Oracle::new(&base, &recs2, l);
                assert!(!oracle2.alive[victim as usize]);
                let again = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
                again.attach_wal(&base_path, WalSync::Always).unwrap();
                check_engine(&again, &oracle2, &mut rng, b, l, "second recovery");
            }
        }
        for d in [&gen_dir, &parse_dir, &replay_dir] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// Group commit (PR 10) under concurrent writers: every acknowledged
/// batch must be durable at exactly the ids its ack returned, the
/// record stream must stay gap-free (ids are reserved under the insert
/// lock in log order even when the fsyncs coalesce), and every
/// byte-prefix of the log — a crash mid-group — must still replay to a
/// record-boundary prefix. One fsync may cover many records, but never
/// fewer records than were acknowledged.
#[test]
fn prop_concurrent_group_commit_acks_are_durable_and_prefixes_replay() {
    const WRITERS: usize = 4;
    const BATCHES: usize = 6;
    for &(b, l) in SHAPES {
        let mut rng = Rng::new((0x4C7 + b * 131 + l) as u64);
        let centers: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let base: Vec<Vec<u8>> = (0..40)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        let set = SketchSet::from_rows(b, l, &base);

        let gen_dir = fresh_dir(&format!("group_{b}"));
        let wal_base = gen_dir.join("engine.wal");
        let writer = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        // `attach_wal` under `always` enables group commit by default.
        writer.attach_wal(&wal_base, WalSync::Always).unwrap();

        // Concurrent writers, each recording the id range every ack
        // returned alongside the rows it wrote.
        let acked: Vec<(u32, Vec<Vec<u8>>)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..WRITERS)
                .map(|w| {
                    let writer = &writer;
                    let centers = &centers;
                    let mut trng = Rng::new((0x9100 + w * 17 + b * 3 + l) as u64);
                    s.spawn(move || {
                        let mut acks = Vec::new();
                        for _ in 0..BATCHES {
                            let m = 1 + trng.below_usize(5);
                            let batch: Vec<Vec<u8>> = (0..m)
                                .map(|_| random_row(&mut trng, b, l, centers))
                                .collect();
                            let range = writer.insert_batch(&batch).unwrap();
                            assert_eq!(range.len(), batch.len());
                            acks.push((range.start, batch));
                            if w == 0 && trng.below(3) == 0 {
                                // One writer mixes in deletes so the log
                                // interleaves record kinds mid-group.
                                let _ = writer.delete(trng.below(writer.n() as u64) as u32);
                            }
                        }
                        acks
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let metrics = writer.metrics();
        let fsyncs = metrics.wal_fsyncs.load(std::sync::atomic::Ordering::Relaxed);
        let covered = metrics.wal_group_records.load(std::sync::atomic::Ordering::Relaxed);
        drop(writer);

        // The full log is a gap-free record sequence (Oracle::new
        // asserts insert-id contiguity) containing every acked batch at
        // exactly its acked ids.
        let all = wal::read_records(&wal_base).unwrap();
        let oracle = Oracle::new(&base, &all, l);
        let inserted: usize = acked.iter().map(|(_, rows)| rows.len()).sum();
        assert_eq!(oracle.rows.len(), base.len() + inserted, "every acked row is in the log");
        for (start, batch) in &acked {
            for (j, row) in batch.iter().enumerate() {
                assert_eq!(&oracle.rows[*start as usize + j], row, "acked id is durable");
            }
        }
        // Acks never outran the watermark: the fsyncs the engine
        // accounted for cover every record in the log, in fewer (or
        // equal) syscalls than records.
        assert_eq!(covered, all.len() as u64, "watermark publishes covered the whole log");
        assert!((1..=covered).contains(&fsyncs), "fsyncs={fsyncs} records={covered}");

        // Sampled byte-prefixes (crashes mid-group) replay to exactly
        // the surviving record prefix.
        let full = std::fs::read(gen_dir.join("engine.wal.0")).unwrap();
        let replay_dir = std::env::temp_dir()
            .join(format!("bst_prop_wal_{}_group_replay_{b}", std::process::id()));
        let mut cuts = vec![0usize, full.len()];
        cuts.extend((0..6).map(|_| rng.below_usize(full.len() + 1)));
        for cut in cuts {
            let base_path = prefix_log(&replay_dir, &full[..cut]);
            let recs = wal::read_records(&base_path).unwrap();
            assert_eq!(recs, all[..recs.len()], "concurrent log cut {cut} is a prefix");
            let cut_oracle = Oracle::new(&base, &recs, l);
            let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
            engine.attach_wal(&base_path, WalSync::Always).unwrap();
            check_engine(&engine, &cut_oracle, &mut rng, b, l, &format!("group cut {cut}"));
        }
        for d in [&gen_dir, &replay_dir] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// A mid-group fsync failure (injected at the `wal.sync` failpoint)
/// must fail the write — no false acks — while the log stays
/// appendable and gap-free: the failed span is re-staged and the next
/// group's successful fsync carries it to disk, so replay sees every
/// record in id order. Needs the failpoint registry, so this test only
/// builds with `--features failpoints`.
#[cfg(feature = "failpoints")]
#[test]
fn group_fsync_failure_nacks_the_write_and_log_stays_appendable() {
    use bst::util::failpoint::{self, Action};
    let (b, l) = (2, 12);
    let mut rng = Rng::new(0x5D3);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let base: Vec<Vec<u8>> = (0..30)
        .map(|_| random_row(&mut rng, b, l, &centers))
        .collect();
    let set = SketchSet::from_rows(b, l, &base);

    let dir = fresh_dir("groupfail");
    let wal_base = dir.join("engine.wal");
    let writer = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
    writer.attach_wal(&wal_base, WalSync::Always).unwrap();

    let a: Vec<Vec<u8>> = (0..4).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    writer.insert_batch(&a).unwrap();

    // The next group's leader fsync fails exactly once.
    let scope = wal_base.to_string_lossy().into_owned();
    failpoint::arm_scoped("wal.sync", &scope, 0, 1, Action::Error);
    let bad: Vec<Vec<u8>> = (0..3).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    let err = writer.insert_batch(&bad).expect_err("failed group fsync must NACK the write");
    failpoint::clear("wal.sync");
    assert!(err.contains("not acknowledged"), "unexpected error: {err}");

    // The log is still a live writer: the next write groups with the
    // re-staged span and both reach disk.
    let c: Vec<Vec<u8>> = (0..2).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    let range = writer.insert_batch(&c).expect("log stays appendable after a failed group");
    assert_eq!(range.start as usize, base.len() + a.len() + bad.len(), "ids stay gap-free");
    drop(writer);

    // Replay: acked batches are all present; the NACKed batch rode the
    // retry to disk (a false NACK — allowed; a missing acked row would
    // be a false ack — never allowed). The record stream is gap-free
    // (Oracle::new asserts contiguity).
    let recs = wal::read_records(&wal_base).unwrap();
    let oracle = Oracle::new(&base, &recs, l);
    assert_eq!(oracle.rows.len(), base.len() + a.len() + bad.len() + c.len());
    for (i, row) in a.iter().enumerate() {
        assert_eq!(&oracle.rows[base.len() + i], row, "acked pre-failure row durable");
    }
    for (i, row) in c.iter().enumerate() {
        assert_eq!(&oracle.rows[range.start as usize + i], row, "acked post-failure row durable");
    }
    let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
    engine.attach_wal(&wal_base, WalSync::Always).unwrap();
    check_engine(&engine, &oracle, &mut rng, b, l, "post-failure replay");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Replay composes with snapshots: recovering into a *loaded* engine
/// only applies records past the snapshot's id high-water mark, and a
/// stale pre-rotation segment (what a crash between `rotate_begin` and
/// `rotate_commit` leaves behind) is skipped idempotently rather than
/// double-applied.
#[test]
fn replay_past_snapshot_hwm_skips_stale_segments() {
    let (b, l) = (2, 10);
    let mut rng = Rng::new(0x3B2);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let base: Vec<Vec<u8>> = (0..80)
        .map(|_| random_row(&mut rng, b, l, &centers))
        .collect();
    let set = SketchSet::from_rows(b, l, &base);

    let dir = fresh_dir("hwm");
    let wal_base = dir.join("engine.wal");
    let snap = dir.join("engine.snap");
    let writer = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
    writer.attach_wal(&wal_base, WalSync::Always).unwrap();
    let pre: Vec<Vec<u8>> = (0..15).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    writer.insert_batch(&pre).unwrap();
    assert!(writer.delete(3));
    // Save rotates the log; records covering the snapshot are gone...
    writer.save(&snap).unwrap();
    let post: Vec<Vec<u8>> = (0..8).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    writer.insert_batch(&post).unwrap();
    assert!(writer.delete(97)); // a post-snapshot row
    drop(writer);

    // ...but resurrect the pre-save records as a stale older segment, as
    // a crash between the snapshot rename and the segment cleanup would.
    let stale = {
        let probe_dir = fresh_dir("hwm_probe");
        let probe = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        probe.attach_wal(&probe_dir.join("engine.wal"), WalSync::Always).unwrap();
        probe.insert_batch(&pre).unwrap();
        assert!(probe.delete(3));
        drop(probe);
        let bytes = std::fs::read(probe_dir.join("engine.wal.0")).unwrap();
        let _ = std::fs::remove_dir_all(&probe_dir);
        bytes
    };
    std::fs::write(dir.join("engine.wal.0"), &stale).unwrap();

    // 2 stale records (pre insert + delete) + 2 live ones (post insert
    // + delete), in segment order.
    assert_eq!(wal::read_records(&wal_base).unwrap().len(), 4);
    // The stale segment contributes nothing: its writes are already
    // inside the snapshot (ids below the high-water mark), so the final
    // state is simply base + pre + post minus the two deletes.
    let oracle = {
        let mut rows = base.clone();
        rows.extend(pre.iter().cloned());
        rows.extend(post.iter().cloned());
        let mut alive = vec![true; rows.len()];
        alive[3] = false;
        alive[97] = false;
        Oracle { rows, alive }
    };

    let engine = Engine::load(&snap).unwrap();
    let rep = engine.attach_wal(&wal_base, WalSync::Always).unwrap();
    assert_eq!(rep.segments, 2, "stale + live segments scanned");
    assert_eq!(rep.replayed_inserts, 8, "only post-snapshot rows replay");
    assert_eq!(rep.replayed_deletes, 2, "deletes replay idempotently");
    assert_eq!(rep.skipped_records, 1, "stale insert below the hwm is skipped");
    check_engine(&engine, &oracle, &mut rng, b, l, "hwm replay");
    let mut hit = engine.search(&oracle.rows[97], 0);
    hit.sort_unstable();
    assert!(!hit.contains(&97), "post-snapshot delete replayed");
    let _ = std::fs::remove_dir_all(&dir);
}
