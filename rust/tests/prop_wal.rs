//! Property suite for the write-ahead log (PR 8): across b ∈ {1, 2, 4,
//! 8} and random insert / delete / merge interleavings, *every*
//! byte-prefix of the WAL — each one a possible power-loss outcome —
//! must parse to a record-boundary prefix of the full log, and a fresh
//! engine replaying it must answer exactly like a linear-scan oracle of
//! the writes that survived the cut. Recovered logs must also stay
//! appendable: writes after a replay are themselves replayed by the
//! next recovery.
//!
//! Fault-injected tears (short appends, fsync failures, worker panics)
//! live in the unit suites (`store::wal`, `coordinator::engine`), which
//! build with the failpoint registry; this integration suite tears the
//! log byte-by-byte instead, which needs no injection hooks.

use bst::coordinator::engine::{Engine, ShardIndexKind};
use bst::sketch::hamming::ham_chars;
use bst::sketch::SketchSet;
use bst::store::wal::{self, WalRecord, WalSync};
use bst::trie::bst::BstConfig;
use bst::util::Rng;
use std::path::{Path, PathBuf};

/// Shapes exercising every alphabet width.
const SHAPES: &[(usize, usize)] = &[(1, 16), (2, 12), (4, 8), (8, 6)];

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bst_prop_wal_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The oracle: base rows plus a WAL record sequence applied in order.
/// Inserts are contiguous by construction (the engine reserves id
/// ranges under the insert lock, in log order), so any prefix of the
/// log extends the base without gaps.
struct Oracle {
    rows: Vec<Vec<u8>>,
    alive: Vec<bool>,
}

impl Oracle {
    fn new(base: &[Vec<u8>], records: &[WalRecord], l: usize) -> Oracle {
        let mut o = Oracle { rows: base.to_vec(), alive: vec![true; base.len()] };
        for rec in records {
            match rec {
                WalRecord::Insert { start_id, n, chars } => {
                    assert_eq!(*start_id as usize, o.rows.len(), "log ids are contiguous");
                    assert_eq!(chars.len(), *n as usize * l);
                    for row in chars.chunks_exact(l) {
                        o.rows.push(row.to_vec());
                        o.alive.push(true);
                    }
                }
                WalRecord::Delete { id } => {
                    if (*id as usize) < o.rows.len() {
                        o.alive[*id as usize] = false;
                    }
                }
                WalRecord::MergeMarker => {}
            }
        }
        o
    }

    fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        (0..self.rows.len())
            .filter(|&i| self.alive[i] && ham_chars(&self.rows[i], q) <= tau)
            .map(|i| i as u32)
            .collect()
    }

    fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        let mut all: Vec<(usize, u32)> = (0..self.rows.len())
            .filter(|&i| self.alive[i])
            .map(|i| (ham_chars(&self.rows[i], q), i as u32))
            .filter(|&(d, _)| d <= tau)
            .collect();
        all.sort_unstable();
        all.truncate(k);
        all.into_iter().map(|(d, id)| (id, d)).collect()
    }
}

fn random_row(rng: &mut Rng, b: usize, l: usize, centers: &[Vec<u8>]) -> Vec<u8> {
    let mut row = centers[rng.below_usize(centers.len())].clone();
    for _ in 0..rng.below_usize(3) {
        let p = rng.below_usize(l);
        row[p] = rng.below(1 << b) as u8;
    }
    row
}

fn check_engine(engine: &Engine, oracle: &Oracle, rng: &mut Rng, b: usize, l: usize, tag: &str) {
    assert_eq!(engine.n(), oracle.rows.len(), "{tag}: id high-water mark");
    for _ in 0..2 {
        let q: Vec<u8> = if oracle.rows.is_empty() || rng.below(2) == 0 {
            (0..l).map(|_| rng.below(1 << b) as u8).collect()
        } else {
            oracle.rows[rng.below_usize(oracle.rows.len())].clone()
        };
        for tau in [0usize, 2, 4] {
            let mut got = engine.search(&q, tau);
            got.sort_unstable();
            assert_eq!(got, oracle.search(&q, tau), "{tag}: search b={b} tau={tau}");
            assert_eq!(engine.count(&q, tau), got.len(), "{tag}: count b={b} tau={tau}");
        }
        assert_eq!(engine.top_k(&q, 5, l), oracle.top_k(&q, 5, l), "{tag}: topk b={b}");
    }
}

/// Writes `bytes` as the sole segment (`engine.wal.0`) of a fresh log
/// directory and returns the segment base path.
fn prefix_log(dir: &Path, bytes: &[u8]) -> PathBuf {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("engine.wal.0"), bytes).unwrap();
    dir.join("engine.wal")
}

/// Generates a log with a writer engine, then (a) parses every
/// byte-prefix — each must yield a record-boundary prefix of the full
/// record sequence — and (b) replays sampled prefixes into fresh
/// engines, which must match the oracle of exactly the surviving
/// writes; the full-log replay must additionally stay appendable and
/// survive a second recovery.
#[test]
fn prop_every_wal_prefix_replays_to_acked_state() {
    for &(b, l) in SHAPES {
        let mut rng = Rng::new((0x3A1 + b * 131 + l) as u64);
        let centers: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let base: Vec<Vec<u8>> = (0..60)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        let set = SketchSet::from_rows(b, l, &base);

        // Writer: every acknowledged op lands in the log first.
        let gen_dir = fresh_dir(&format!("gen_{b}"));
        let wal_base = gen_dir.join("engine.wal");
        let writer = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let rep = writer.attach_wal(&wal_base, WalSync::Always).unwrap();
        assert_eq!(rep.replayed_inserts + rep.replayed_deletes, 0, "fresh log is empty");
        let seed_batch: Vec<Vec<u8>> =
            (0..5).map(|_| random_row(&mut rng, b, l, &centers)).collect();
        writer.insert_batch(&seed_batch).unwrap();
        for _ in 0..9 {
            match rng.below(5) {
                0..=2 => {
                    let m = 1 + rng.below_usize(10);
                    let batch: Vec<Vec<u8>> =
                        (0..m).map(|_| random_row(&mut rng, b, l, &centers)).collect();
                    writer.insert_batch(&batch).unwrap();
                }
                3 => {
                    let _ = writer.delete(rng.below(writer.n() as u64) as u32);
                }
                _ => {
                    writer.merge();
                }
            }
        }
        drop(writer);
        let full = std::fs::read(gen_dir.join("engine.wal.0")).unwrap();
        let all = wal::read_records(&wal_base).unwrap();
        assert!(all.iter().any(|r| matches!(r, WalRecord::Insert { .. })), "log has inserts");

        // (a) Every byte-prefix — a possible crash point — parses to a
        // record-boundary prefix of the full sequence, never garbage.
        let parse_dir = std::env::temp_dir()
            .join(format!("bst_prop_wal_{}_parse_{b}", std::process::id()));
        for cut in 0..=full.len() {
            let base_path = prefix_log(&parse_dir, &full[..cut]);
            let recs = wal::read_records(&base_path).unwrap();
            assert_eq!(recs, all[..recs.len()], "prefix {cut} of {}", full.len());
        }

        // (b) Replay sampled prefixes into fresh engines (a different
        // shard count than the writer: striping is the replayer's).
        let mut cuts = vec![0usize, full.len()];
        cuts.extend((0..10).map(|_| rng.below_usize(full.len() + 1)));
        let replay_dir = std::env::temp_dir()
            .join(format!("bst_prop_wal_{}_replay_{b}", std::process::id()));
        for cut in cuts {
            let base_path = prefix_log(&replay_dir, &full[..cut]);
            let recs = wal::read_records(&base_path).unwrap();
            let oracle = Oracle::new(&base, &recs, l);
            let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
            let rep = engine.attach_wal(&base_path, WalSync::Always).unwrap();
            // Recovery physically truncated the torn suffix.
            let seg_len = std::fs::metadata(replay_dir.join("engine.wal.0")).unwrap().len();
            assert_eq!(seg_len + rep.truncated_bytes, cut as u64, "cut {cut}");
            check_engine(&engine, &oracle, &mut rng, b, l, &format!("cut {cut}"));

            if cut == full.len() {
                assert_eq!(rep.truncated_bytes, 0, "clean log has no torn tail");
                // The recovered engine is a live writer: new ops append
                // past the replayed tail and survive a second recovery.
                let extra: Vec<Vec<u8>> =
                    (0..7).map(|_| random_row(&mut rng, b, l, &centers)).collect();
                let range = engine.insert_batch(&extra).unwrap();
                assert_eq!(range.start as usize, oracle.rows.len(), "ids continue");
                let victim = range.start + 2;
                assert!(engine.delete(victim));
                drop(engine);
                let recs2 = wal::read_records(&base_path).unwrap();
                assert_eq!(recs2.len(), recs.len() + 2, "replay appended two records");
                let oracle2 = Oracle::new(&base, &recs2, l);
                assert!(!oracle2.alive[victim as usize]);
                let again = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
                again.attach_wal(&base_path, WalSync::Always).unwrap();
                check_engine(&again, &oracle2, &mut rng, b, l, "second recovery");
            }
        }
        for d in [&gen_dir, &parse_dir, &replay_dir] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// Replay composes with snapshots: recovering into a *loaded* engine
/// only applies records past the snapshot's id high-water mark, and a
/// stale pre-rotation segment (what a crash between `rotate_begin` and
/// `rotate_commit` leaves behind) is skipped idempotently rather than
/// double-applied.
#[test]
fn replay_past_snapshot_hwm_skips_stale_segments() {
    let (b, l) = (2, 10);
    let mut rng = Rng::new(0x3B2);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let base: Vec<Vec<u8>> = (0..80)
        .map(|_| random_row(&mut rng, b, l, &centers))
        .collect();
    let set = SketchSet::from_rows(b, l, &base);

    let dir = fresh_dir("hwm");
    let wal_base = dir.join("engine.wal");
    let snap = dir.join("engine.snap");
    let writer = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
    writer.attach_wal(&wal_base, WalSync::Always).unwrap();
    let pre: Vec<Vec<u8>> = (0..15).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    writer.insert_batch(&pre).unwrap();
    assert!(writer.delete(3));
    // Save rotates the log; records covering the snapshot are gone...
    writer.save(&snap).unwrap();
    let post: Vec<Vec<u8>> = (0..8).map(|_| random_row(&mut rng, b, l, &centers)).collect();
    writer.insert_batch(&post).unwrap();
    assert!(writer.delete(97)); // a post-snapshot row
    drop(writer);

    // ...but resurrect the pre-save records as a stale older segment, as
    // a crash between the snapshot rename and the segment cleanup would.
    let stale = {
        let probe_dir = fresh_dir("hwm_probe");
        let probe = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        probe.attach_wal(&probe_dir.join("engine.wal"), WalSync::Always).unwrap();
        probe.insert_batch(&pre).unwrap();
        assert!(probe.delete(3));
        drop(probe);
        let bytes = std::fs::read(probe_dir.join("engine.wal.0")).unwrap();
        let _ = std::fs::remove_dir_all(&probe_dir);
        bytes
    };
    std::fs::write(dir.join("engine.wal.0"), &stale).unwrap();

    // 2 stale records (pre insert + delete) + 2 live ones (post insert
    // + delete), in segment order.
    assert_eq!(wal::read_records(&wal_base).unwrap().len(), 4);
    // The stale segment contributes nothing: its writes are already
    // inside the snapshot (ids below the high-water mark), so the final
    // state is simply base + pre + post minus the two deletes.
    let oracle = {
        let mut rows = base.clone();
        rows.extend(pre.iter().cloned());
        rows.extend(post.iter().cloned());
        let mut alive = vec![true; rows.len()];
        alive[3] = false;
        alive[97] = false;
        Oracle { rows, alive }
    };

    let engine = Engine::load(&snap).unwrap();
    let rep = engine.attach_wal(&wal_base, WalSync::Always).unwrap();
    assert_eq!(rep.segments, 2, "stale + live segments scanned");
    assert_eq!(rep.replayed_inserts, 8, "only post-snapshot rows replay");
    assert_eq!(rep.replayed_deletes, 2, "deletes replay idempotently");
    assert_eq!(rep.skipped_records, 1, "stale insert below the hwm is skipped");
    check_engine(&engine, &oracle, &mut rng, b, l, "hwm replay");
    let mut hit = engine.search(&oracle.rows[97], 0);
    hit.sort_unstable();
    assert!(!hit.contains(&97), "post-snapshot delete replayed");
    let _ = std::fs::remove_dir_all(&dir);
}
