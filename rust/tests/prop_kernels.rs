//! Property suite for the streaming range- and batch-verification
//! kernels (`PlaneStore::ham_range_leq` / `ham_many_leq` / `range_scan`):
//! verdicts must agree with the `ham_chars` oracle — and with the
//! per-item path — across `b ∈ {1,2,4,8}`, `width ∈ {0,1,31,63,64}`,
//! random `(lo, hi)` ranges, duplicate-heavy candidate lists, and every
//! `tau ∈ {0, …, width}`. Also proves the rewired search paths (bST
//! sparse scan, linear, MI-bST, SIH, HmSearch) still produce identical
//! result sets and collector stats.

use bst::index::{HmSearch, LinearScan, MultiBst, SearchIndex, Sih};
use bst::query::{CollectIds, QueryCtx, StatsObserver};
use bst::sketch::hamming::ham_chars;
use bst::sketch::plane_store::PlaneStore;
use bst::sketch::{SketchSet, VerticalSet};
use bst::trie::bst::{BstConfig, BstTrie};
use bst::trie::{SketchTrie, SortedSketches};
use bst::util::Rng;

/// Random char rows + the vertical plane store over them (the layout the
/// sparse layer and `VerticalSet` both use: plane `k` packs bit `k` of
/// each of the `width` chars).
fn rows_and_store(b: usize, width: usize, n: usize, rng: &mut Rng) -> (Vec<Vec<u8>>, PlaneStore) {
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| (0..width).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let ps = PlaneStore::from_fn(b, width, n, |k, i| {
        let mut field = 0u64;
        for (pos, &c) in rows[i].iter().enumerate() {
            field |= (((c >> k) & 1) as u64) << pos;
        }
        field
    });
    (rows, ps)
}

fn pack_planes(q: &[u8], b: usize) -> Vec<u64> {
    (0..b)
        .map(|k| {
            let mut field = 0u64;
            for (pos, &c) in q.iter().enumerate() {
                field |= (((c >> k) & 1) as u64) << pos;
            }
            field
        })
        .collect()
}

#[test]
fn prop_kernels_match_ham_chars_oracle() {
    let mut rng = Rng::new(0xC0DE);
    for &b in &[1usize, 2, 4, 8] {
        for &width in &[0usize, 1, 31, 63, 64] {
            let n = 160;
            let (rows, ps) = rows_and_store(b, width, n, &mut rng);
            for _ in 0..4 {
                let q: Vec<u8> = (0..width).map(|_| rng.below(1 << b) as u8).collect();
                let qp = pack_planes(&q, b);
                let dists: Vec<usize> = rows.iter().map(|r| ham_chars(r, &q)).collect();
                for tau in 0..=width {
                    // random range + the full range
                    let lo = rng.below_usize(n);
                    let hi = lo + rng.below_usize(n - lo + 1);
                    for &(lo, hi) in &[(lo, hi), (0, n)] {
                        let mut at = lo;
                        ps.ham_range_leq(lo, hi, &qp, tau, |i, verdict| {
                            assert_eq!(i, at, "emit order must be ascending");
                            at += 1;
                            let d = dists[i];
                            assert_eq!(
                                verdict,
                                (d <= tau).then_some(d),
                                "range b={b} w={width} i={i} tau={tau}"
                            );
                            // per-item path must agree with the kernel
                            assert_eq!(verdict, ps.ham_leq(i, &qp, tau));
                            Some(tau)
                        });
                        assert_eq!(at, hi, "kernel must cover the whole range");
                    }
                    // duplicate-heavy unsorted candidate list
                    let ids: Vec<u32> =
                        (0..2 * n).map(|_| rng.below(n as u64) as u32).collect();
                    let mut seen = 0usize;
                    ps.ham_many_leq(&ids, &qp, tau, |id, verdict| {
                        assert_eq!(id, ids[seen], "batch order must be list order");
                        seen += 1;
                        let d = dists[id as usize];
                        assert_eq!(
                            verdict,
                            (d <= tau).then_some(d),
                            "batch b={b} w={width} id={id} tau={tau}"
                        );
                        Some(tau)
                    });
                    assert_eq!(seen, ids.len());
                }
            }
        }
    }
}

/// The live-threshold contract: verdicts must track whatever the sink
/// returned for the previous item — the mechanism TopK uses to tighten
/// verification mid-scan.
#[test]
fn prop_kernels_respect_live_threshold() {
    let mut rng = Rng::new(0xBEA7);
    for &(b, width) in &[(2usize, 31usize), (4, 16), (8, 8), (8, 64)] {
        let n = 120;
        let (rows, ps) = rows_and_store(b, width, n, &mut rng);
        let q: Vec<u8> = (0..width).map(|_| rng.below(1 << b) as u8).collect();
        let qp = pack_planes(&q, b);
        let dists: Vec<usize> = rows.iter().map(|r| ham_chars(r, &q)).collect();

        let mut tau = width;
        ps.ham_range_leq(0, n, &qp, tau, |i, verdict| {
            let d = dists[i];
            assert_eq!(verdict, (d <= tau).then_some(d), "i={i} live tau={tau}");
            if i % 7 == 6 {
                tau = tau.saturating_sub(2);
            }
            Some(tau)
        });

        let ids: Vec<u32> = (0..n as u32).rev().collect();
        let mut tau = width;
        let mut k = 0usize;
        ps.ham_many_leq(&ids, &qp, tau, |id, verdict| {
            let d = dists[id as usize];
            assert_eq!(verdict, (d <= tau).then_some(d), "id={id} live tau={tau}");
            k += 1;
            if k % 5 == 0 {
                tau = tau.saturating_sub(1);
            }
            Some(tau)
        });
    }
}

/// The rewired verifiers (bST sparse scan, linear, MI-bST, SIH,
/// HmSearch) must produce the same id sets as the brute-force oracle,
/// and — for the collector-exact paths (bST, linear) — identical
/// traversal stats to the per-item accounting they replaced: every
/// candidate visited exactly once, pruned xor emitted.
#[test]
fn prop_rewired_verifiers_match_oracle_and_stats() {
    for &(b, l, seed) in &[(2usize, 16usize, 41u64), (4, 12, 42), (8, 8, 43)] {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let rows: Vec<Vec<u8>> = (0..300)
            .map(|_| {
                let mut r = centers[rng.below_usize(8)].clone();
                for _ in 0..rng.below_usize(4) {
                    let p = rng.below_usize(l);
                    r[p] = rng.below(1 << b) as u8;
                }
                r
            })
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let linear = LinearScan::build(&set);
        let mi = MultiBst::build(&set, 2);
        let sih = Sih::build(&set);
        let vert = VerticalSet::from_horizontal(&set);

        let mut ctx = QueryCtx::new();
        for qi in [0usize, 17, 86] {
            let q = rows[qi].clone();
            for tau in [0usize, 1, 2, 4] {
                let expect: Vec<u32> = (0..rows.len())
                    .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                    .map(|i| i as u32)
                    .collect();

                // bST: ids + per-leaf stats accounting
                let mut out = Vec::new();
                let mut obs = StatsObserver::new(CollectIds::new(tau, &mut out));
                bst.run(&q, &mut ctx, &mut obs);
                let stats = obs.stats;
                out.sort();
                assert_eq!(out, expect, "bst b={b} tau={tau}");
                assert_eq!(stats.emitted, expect.len(), "bst emitted b={b} tau={tau}");
                assert!(stats.visited > 0, "bst must visit at least the root");

                // linear: ids + exact stats (n visits, prune/emit split)
                let mut out = Vec::new();
                let mut obs = StatsObserver::new(CollectIds::new(tau, &mut out));
                linear.run(&q, &mut ctx, &mut obs);
                let stats = obs.stats;
                out.sort();
                assert_eq!(out, expect, "linear b={b} tau={tau}");
                assert_eq!(stats.visited, rows.len(), "linear visits every row once");
                assert_eq!(stats.emitted, expect.len());
                assert_eq!(stats.pruned, rows.len() - expect.len());

                // batched candidate verifiers
                let mut got = mi.search(&q, tau);
                got.sort();
                assert_eq!(got, expect, "mi-bst b={b} tau={tau}");
                if tau <= 1 {
                    let mut got = sih.search(&q, tau);
                    got.sort();
                    got.dedup();
                    assert_eq!(got, expect, "sih b={b} tau={tau}");
                }
                let hm = HmSearch::build(&set, tau.max(1));
                let mut got = hm.search(&q, tau);
                got.sort();
                assert_eq!(got, expect, "hmsearch b={b} tau={tau}");

                // VerticalSet::scan routes through the range kernel too
                assert_eq!(vert.scan(&q, tau), expect, "scan b={b} tau={tau}");
            }
        }
    }
}
