//! Three-layer composition proof: the Rust runtime loads the AOT-lowered
//! JAX/Pallas artifacts and produces the same sketches / distances as the
//! native Rust implementations.
//!
//! Requires `make artifacts` (skips with a message otherwise — CI runs
//! `make test` which builds them first) AND the `pjrt` feature: without
//! it `bst::runtime` is the dependency-free stub, so these tests are
//! compiled out entirely.
#![cfg(feature = "pjrt")]

use bst::data::{generate_dense, generate_sets, Dataset, GenConfig};
use bst::runtime::Runtime;
use bst::sketch::{CwsParams, MinhashParams, SketchSet, VerticalSet};
use bst::util::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(Box::leak(dir.into_boxed_path()))
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn minhash_artifact_is_bit_identical_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).expect("runtime");
    let sk = rt.sketcher("review").expect("sketcher");

    let ds = Dataset::Review;
    let cfg = GenConfig { n: 3000, seed: 77, threads: 4, cluster_size: 16, background: 0.2 };
    let sets = generate_sets(ds, &cfg);
    let params = MinhashParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);

    // native
    let native = params.sketch_batch(&sets, 4);

    // XLA path: densify
    let d = ds.dim();
    let mut x = vec![0f32; cfg.n * d];
    for (i, s) in sets.iter().enumerate() {
        for &j in s {
            x[i * d + j as usize] = 1.0;
        }
    }
    let via_xla = sk.sketch_minhash(&x, cfg.n, &params).expect("xla sketch");

    assert_eq!(native.n(), via_xla.n());
    for i in 0..cfg.n {
        assert_eq!(native.row(i), via_xla.row(i), "sketch {i} differs");
    }
}

#[test]
fn cws_artifact_matches_native_within_ulp_tolerance() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).expect("runtime");
    let sk = rt.sketcher("sift").expect("sketcher");

    let ds = Dataset::Sift;
    let cfg = GenConfig { n: 2500, seed: 33, threads: 4, cluster_size: 16, background: 0.2 };
    let x = generate_dense(ds, &cfg);
    let params = CwsParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);

    let native = params.sketch_batch(&x, cfg.n, 4);
    let via_xla = sk.sketch_cws(&x, cfg.n, &params).expect("xla sketch");

    // f32 `ln` may differ in the last ulp between libm and XLA → the
    // floor() in the CWS prelude can flip, changing isolated argmins.
    let total = cfg.n * ds.l();
    let mut mismatches = 0usize;
    for i in 0..cfg.n {
        let (a, b) = (native.row(i), via_xla.row(i));
        mismatches += a.iter().zip(&b).filter(|(x, y)| x != y).count();
    }
    let rate = mismatches as f64 / total as f64;
    assert!(
        rate < 0.005,
        "CWS char mismatch rate {rate:.4} exceeds tolerance ({mismatches}/{total})"
    );
}

#[test]
fn hamming_artifact_matches_native_scan() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).expect("runtime");
    let scan = rt.scanner("cp").expect("scanner");

    let mut rng = Rng::new(55);
    let (b, l, n) = (2usize, 32usize, 5000usize);
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let set = SketchSet::from_rows(b, l, &rows);
    let vert = VerticalSet::from_horizontal(&set);

    for qi in [0usize, 123, n - 1] {
        let q = &rows[qi];
        let dist = scan.distances(&vert, q).expect("distances");
        assert_eq!(dist.len(), n);
        let qp = vert.pack_query(q);
        for i in (0..n).step_by(37) {
            assert_eq!(dist[i] as usize, vert.ham(i, &qp), "i={i} q={qi}");
        }
        assert_eq!(dist[qi], 0);
        // threshold search agrees with the native scan
        let got = scan.search(&vert, q, 3).expect("search");
        let expect = vert.scan(q, 3);
        assert_eq!(got, expect);
    }
}

#[test]
fn gist_64char_hamming_uses_two_words() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).expect("runtime");
    let scan = rt.scanner("gist").expect("scanner");
    assert_eq!(scan.meta().w, 2);

    let mut rng = Rng::new(66);
    let (b, l, n) = (8usize, 64usize, 1200usize);
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| (0..l).map(|_| rng.below(256) as u8).collect())
        .collect();
    let set = SketchSet::from_rows(b, l, &rows);
    let vert = VerticalSet::from_horizontal(&set);
    let q = &rows[7];
    let dist = scan.distances(&vert, q).expect("distances");
    let qp = vert.pack_query(q);
    for i in (0..n).step_by(11) {
        assert_eq!(dist[i] as usize, vert.ham(i, &qp), "i={i}");
    }
}
