//! Acceptance check for serve-from-snapshot cold start: `Engine::load`
//! answers `search` / `count` / `topk` identically to `Engine::build`
//! over the same sketches, and loading performs **zero** reconstruction —
//! no `SortedSketches::build`, no rank/select directory builds.
//!
//! The mapped cold start (`Engine::load_with(path, true)`) additionally
//! proves **zero payload-sized heap copies**: every wide array getter
//! that fails to borrow from the mapping bumps a process-global fallback
//! counter, and this test asserts the counter does not move — on a
//! little-endian host every section payload is 8-aligned inside the
//! page-aligned mapping, so every borrow must succeed.
//!
//! The no-rebuild and no-copy proofs use process-global counters, so
//! this file intentionally contains a single `#[test]` (sibling tests in
//! the same binary would race the counters).

use bst::bits::rsvec::directory_builds;
use bst::coordinator::engine::{Engine, ShardIndexKind};
use bst::sketch::SketchSet;
use bst::store::mapped_borrow_fallbacks;
use bst::trie::builder::build_invocations;
use bst::trie::bst::BstConfig;
use bst::util::Rng;

#[test]
fn engine_load_serves_without_reconstruction() {
    let (b, l, n) = (2usize, 16usize, 2000usize);
    let mut rng = Rng::new(0xC01D);
    let centers: Vec<Vec<u8>> = (0..10)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            let mut r = centers[rng.below_usize(10)].clone();
            for _ in 0..rng.below_usize(4) {
                let p = rng.below_usize(l);
                r[p] = rng.below(1 << b) as u8;
            }
            r
        })
        .collect();
    let set = SketchSet::from_rows(b, l, &rows);

    let dir = std::env::temp_dir().join("bst_cold_start_test");
    std::fs::create_dir_all(&dir).unwrap();

    for (kind, name) in [
        (ShardIndexKind::Bst(BstConfig::default()), "si-bst"),
        (ShardIndexKind::MultiBst(2), "mi-bst"),
    ] {
        let built = Engine::build(&set, 3, &kind);
        let path = dir.join(format!("{name}.snap"));
        built.save(&path).unwrap();

        let builds_before = build_invocations();
        let dirs_before = directory_builds();
        let loaded = Engine::load(&path).unwrap();
        assert_eq!(
            build_invocations(),
            builds_before,
            "{name}: load must not re-run SortedSketches::build"
        );
        assert_eq!(
            directory_builds(),
            dirs_before,
            "{name}: load must not rebuild any rank/select directory"
        );
        assert_eq!(loaded.n(), built.n());
        assert_eq!(loaded.l(), built.l());
        assert_eq!(loaded.n_shards(), built.n_shards());
        // heap_bytes counts capacity, and loaded vectors are exact-sized
        // where built ones may carry growth slack — so compare loosely.
        assert!(loaded.heap_bytes() > 0);
        assert!(loaded.heap_bytes() <= built.heap_bytes(), "{name}: loaded is never larger");

        // Mapped cold start: same no-rebuild guarantees, plus zero
        // payload-sized heap copies — every wide-array read borrows the
        // mapping (any copy fallback would bump the global counter).
        let builds_before = build_invocations();
        let dirs_before = directory_builds();
        let falls_before = mapped_borrow_fallbacks();
        let mapped = Engine::load_with(&path, true).unwrap();
        assert_eq!(
            build_invocations(),
            builds_before,
            "{name}: mapped load must not re-run SortedSketches::build"
        );
        assert_eq!(
            directory_builds(),
            dirs_before,
            "{name}: mapped load must not rebuild any rank/select directory"
        );
        assert_eq!(
            mapped_borrow_fallbacks(),
            falls_before,
            "{name}: mapped load must not copy any payload array"
        );
        assert_eq!(mapped.n(), built.n());
        // Borrowed arrays report zero owned heap, so the mapped engine's
        // assembly-time heap must come in strictly below the owned load.
        assert!(
            mapped.heap_bytes() < loaded.heap_bytes(),
            "{name}: mapped heap {} !< owned heap {}",
            mapped.heap_bytes(),
            loaded.heap_bytes()
        );

        let mut qrng = Rng::new(0x5EED);
        for _ in 0..10 {
            let q = rows[qrng.below_usize(rows.len())].clone();
            for tau in [0usize, 1, 3, 5] {
                let mut a = built.search(&q, tau);
                let mut b = loaded.search(&q, tau);
                let mut m = mapped.search(&q, tau);
                a.sort();
                b.sort();
                m.sort();
                assert_eq!(a, b, "{name}: search tau={tau}");
                assert_eq!(a, m, "{name}: mapped search tau={tau}");
                assert_eq!(built.count(&q, tau), loaded.count(&q, tau), "{name}: count");
                assert_eq!(built.count(&q, tau), mapped.count(&q, tau), "{name}: mapped count");
            }
            for k in [1usize, 10, 100] {
                assert_eq!(
                    built.top_k(&q, k, l),
                    loaded.top_k(&q, k, l),
                    "{name}: topk k={k}"
                );
                assert_eq!(
                    built.top_k(&q, k, l),
                    mapped.top_k(&q, k, l),
                    "{name}: mapped topk k={k}"
                );
            }
        }
        std::fs::remove_file(&path).unwrap();
    }
}
