//! Snapshot roundtrip property suite: for every trie and every index,
//! across `b ∈ {1, 2, 4, 8}`, save → load must answer `search` / `count`
//! / `topk` identically to the freshly built structure (compared
//! result-for-result, unsorted — a loaded structure is bit-identical, so
//! even the emission order must match), re-serialization must be
//! byte-stable, truncated payloads must be rejected, and corrupted
//! container bytes must be caught by the section checksums.
//!
//! Every roundtrip runs on **two axes**: the owned load (payload bytes
//! copied into fresh allocations) and the mapped load (the container
//! `mmap`ed read-only, payload arrays borrowing the mapping zero-copy).
//! Both must answer identically and re-serialize byte-identically.

use bst::index::{
    HmSearch, LinearScan, Mih, MultiBst, SearchIndex, Sih, SingleBst, SingleFst, SingleLouds,
};
use bst::query::{CountOnly, QueryCtx, TopK};
use bst::sketch::SketchSet;
use bst::store::{from_payload, to_payload, ByteReader, Persist, Snapshot, SnapshotBuilder};
use bst::trie::bst::{BstConfig, BstTrie};
use bst::trie::fst::FstTrie;
use bst::trie::louds::LoudsTrie;
use bst::trie::pointer::PointerTrie;
use bst::trie::{SketchTrie, SortedSketches};
use bst::util::Rng;

/// `(b, L)` shapes covering every supported alphabet width.
const SHAPES: [(usize, usize); 4] = [(1, 16), (2, 12), (4, 8), (8, 6)];

fn clustered_rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<u8>> = (0..12)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    (0..n)
        .map(|_| {
            let mut row = centers[rng.below_usize(12)].clone();
            for _ in 0..rng.below_usize(3) {
                let p = rng.below_usize(l);
                row[p] = rng.below(1 << b) as u8;
            }
            row
        })
        .collect()
}

fn queries(rows: &[Vec<u8>], b: usize, l: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    let mut qs: Vec<Vec<u8>> = rows.iter().take(4).cloned().collect();
    qs.extend((0..3).map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect::<Vec<u8>>()));
    qs
}

/// Roundtrips `x` through its payload encoding, checks byte-stability,
/// truncation rejection, and container-checksum corruption rejection,
/// then hands `(original, loaded)` to the caller's equality check —
/// once for the owned load and once for the mapped (zero-copy) load.
fn check_persist<T: Persist>(x: &T, label: &str, check_equal: impl Fn(&T, &T)) {
    let bytes = to_payload(x);
    let loaded: T = from_payload(&mut ByteReader::new(&bytes))
        .unwrap_or_else(|e| panic!("{label}: roundtrip failed: {e}"));
    assert_eq!(
        to_payload(&loaded),
        bytes,
        "{label}: re-serialization must be byte-stable"
    );
    check_equal(x, &loaded);

    // Mapped axis: the same payload served from a read-only mapping.
    // Section payloads are 8-aligned within the page-aligned mapping,
    // so the wide arrays borrow in place instead of being copied.
    {
        let mut builder = SnapshotBuilder::new();
        builder.add_section("payload", bytes.clone());
        let dir = std::env::temp_dir().join("bst_prop_snapshot_mmap");
        std::fs::create_dir_all(&dir).unwrap();
        let safe: String = label
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        let path = dir.join(format!("{safe}.snap"));
        std::fs::write(&path, builder.to_bytes()).unwrap();
        let snap = Snapshot::open_mapped(&path)
            .unwrap_or_else(|e| panic!("{label}: mapped open failed: {e}"));
        let mut r = snap.section("payload").unwrap();
        let mapped: T = from_payload(&mut r)
            .unwrap_or_else(|e| panic!("{label}: mapped roundtrip failed: {e}"));
        assert_eq!(
            to_payload(&mapped),
            bytes,
            "{label}: mapped re-serialization must be byte-stable"
        );
        check_equal(x, &mapped);
        let _ = std::fs::remove_file(&path);
    }

    // Truncated payloads must error, never panic.
    for cut in [0usize, 5, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            from_payload::<T>(&mut ByteReader::new(&bytes[..cut.min(bytes.len() - 1)])).is_err(),
            "{label}: truncation at {cut} must be rejected"
        );
    }

    // Container-level corruption is caught by the section checksum.
    let mut builder = SnapshotBuilder::new();
    builder.add_section("payload", bytes.clone());
    let file = builder.to_bytes();
    assert!(Snapshot::from_bytes(file.clone()).is_ok(), "{label}");
    let mut bad = file.clone();
    let mid = file.len() - 1 - bytes.len() / 2; // inside the payload
    bad[mid] ^= 0x04;
    assert!(
        Snapshot::from_bytes(bad).is_err(),
        "{label}: corrupted container byte must be rejected"
    );
}

/// All three query modes of a trie against one query.
fn trie_results<T: SketchTrie>(
    t: &T,
    q: &[u8],
    tau: usize,
) -> (Vec<u32>, usize, Vec<(u32, usize)>) {
    let ids = t.search(q, tau);
    let mut ctx = QueryCtx::new();
    let mut cnt = CountOnly::new(tau);
    t.run(q, &mut ctx, &mut cnt);
    let mut topk = TopK::new(5, tau);
    t.run(q, &mut ctx, &mut topk);
    (ids, cnt.count(), topk.finish())
}

fn check_trie<T: SketchTrie + Persist>(t: &T, label: &str, qs: &[Vec<u8>], taus: &[usize]) {
    check_persist(t, label, |orig, loaded| {
        for q in qs {
            for &tau in taus {
                assert_eq!(
                    trie_results(orig, q, tau),
                    trie_results(loaded, q, tau),
                    "{label}: tau={tau} q={q:?}"
                );
            }
        }
    });
}

fn check_index<T: SearchIndex + Persist>(t: &T, label: &str, qs: &[Vec<u8>], taus: &[usize]) {
    check_persist(t, label, |orig, loaded| {
        for q in qs {
            for &tau in taus {
                assert_eq!(orig.search(q, tau), loaded.search(q, tau), "{label} tau={tau}");
                assert_eq!(orig.count(q, tau), loaded.count(q, tau), "{label} tau={tau}");
            }
            let tau = *taus.last().unwrap();
            assert_eq!(orig.top_k(q, 5, tau), loaded.top_k(q, 5, tau), "{label} topk");
        }
    });
}

#[test]
fn all_tries_roundtrip_across_b() {
    for &(b, l) in &SHAPES {
        let rows = clustered_rows(b, l, 400, (b * 131 + l) as u64);
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let qs = queries(&rows, b, l, 0xA1);
        let taus = [0usize, 1, 2];

        let bst = BstTrie::build(&ss, BstConfig::default());
        check_trie(&bst, &format!("bST b={b}"), &qs, &taus);
        // forced layer corners exercise every middle representation
        for (lm, ls) in [(0usize, l), (0, 0), (1, l / 2)] {
            let cfg = BstConfig { lm: Some(lm), ls: Some(ls), ..Default::default() };
            let t = BstTrie::build(&ss, cfg);
            check_trie(&t, &format!("bST b={b} lm={lm} ls={ls}"), &qs, &taus);
        }
        check_trie(&LoudsTrie::build(&ss), &format!("LOUDS b={b}"), &qs, &taus);
        check_trie(&FstTrie::build(&ss), &format!("FST b={b}"), &qs, &taus);
        check_trie(&PointerTrie::build(&ss), &format!("PT b={b}"), &qs, &taus);
    }
}

#[test]
fn all_indexes_roundtrip_across_b() {
    for &(b, l) in &SHAPES {
        let rows = clustered_rows(b, l, 350, (b * 37 + l) as u64);
        let set = SketchSet::from_rows(b, l, &rows);
        let qs = queries(&rows, b, l, 0xB2);
        let taus = [0usize, 1, 2];
        // SIH enumerates the full signature ball — keep its radius tight
        // for the wide alphabet.
        let sih_taus: &[usize] = if b >= 4 { &[0, 1] } else { &[0, 1, 2] };

        check_index(
            &SingleBst::build(&set, BstConfig::default()),
            &format!("SI-bST b={b}"),
            &qs,
            &taus,
        );
        check_index(&SingleLouds::build(&set), &format!("SI-LOUDS b={b}"), &qs, &taus);
        check_index(&SingleFst::build(&set), &format!("SI-FST b={b}"), &qs, &taus);
        check_index(&MultiBst::build(&set, 2), &format!("MI-bST b={b}"), &qs, &taus);
        check_index(&Mih::build(&set, 2), &format!("MIH b={b}"), &qs, &taus);
        check_index(&Sih::build(&set), &format!("SIH b={b}"), &qs, sih_taus);
        check_index(&HmSearch::build(&set, 2), &format!("HmSearch b={b}"), &qs, &taus);
        check_index(&LinearScan::build(&set), &format!("LinearScan b={b}"), &qs, &taus);
    }
}

#[test]
fn mixed_key_indexes_roundtrip() {
    // b=8, L=12 → 96-bit sketches: SIH carries a verification store and
    // MIH (m=1) uses mixed block keys.
    let (b, l) = (8usize, 12usize);
    let rows = clustered_rows(b, l, 250, 0xC3);
    let set = SketchSet::from_rows(b, l, &rows);
    let qs = queries(&rows, b, l, 0xC4);
    check_index(&Sih::build(&set), "SIH mixed", &qs, &[0, 1]);
    check_index(&Mih::build(&set, 1), "MIH mixed", &qs, &[0, 1]);
}

#[test]
fn cross_structure_corruption_is_rejected() {
    // A valid LOUDS payload must not parse as a bST (and vice versa):
    // the layered validation catches shape mismatches, not just EOF.
    let rows = clustered_rows(2, 10, 200, 0xD5);
    let set = SketchSet::from_rows(2, 10, &rows);
    let ss = SortedSketches::build(&set);
    let bst_bytes = to_payload(&BstTrie::build(&ss, BstConfig::default()));
    let louds_bytes = to_payload(&LoudsTrie::build(&ss));
    assert!(from_payload::<LoudsTrie>(&mut ByteReader::new(&bst_bytes)).is_err());
    assert!(from_payload::<BstTrie>(&mut ByteReader::new(&louds_bytes)).is_err());
}
