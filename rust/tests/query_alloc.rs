//! Acceptance check for the query-execution refactor: after one warm-up
//! query per shape, a `BstTrie` threshold search performs **zero** heap
//! allocations — the packed query planes, the middle-layer fan-out buffer
//! and the hit vector are all reused through `QueryCtx` / `CollectIds` —
//! and so does a top-k search: the adaptive heap is parked in `QueryCtx`
//! between queries (`SearchIndex::top_k_into`).
//!
//! Measured with a counting global allocator. This file intentionally
//! contains a single `#[test]` so no sibling test thread allocates inside
//! the measurement window.

use bst::index::{LinearScan, SearchIndex, SingleBst};
use bst::query::{BlockCollector, CollectIds, Collector, CountOnly, QueryCtx};
use bst::sketch::SketchSet;
use bst::trie::bst::{BstConfig, BstTrie};
use bst::trie::{SketchTrie, SortedSketches};
use bst::util::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn bst_search_is_allocation_free_after_warmup() {
    // Clustered database so all three bST layers materialize.
    let (b, l, n) = (2usize, 16usize, 1500usize);
    let mut rng = Rng::new(0xA110C);
    let centers: Vec<Vec<u8>> = (0..10)
        .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
        .collect();
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            let mut r = centers[rng.below_usize(10)].clone();
            for _ in 0..rng.below_usize(3) {
                let p = rng.below_usize(l);
                r[p] = rng.below(1 << b) as u8;
            }
            r
        })
        .collect();
    let set = SketchSet::from_rows(b, l, &rows);
    let ss = SortedSketches::build(&set);
    let bst = BstTrie::build(&ss, BstConfig::default());

    let queries: Vec<Vec<u8>> = (0..16)
        .map(|i| rows[i * 31].clone())
        .collect();
    let taus = [0usize, 1, 2, 4];

    let mut ctx = QueryCtx::new();
    let mut out: Vec<u32> = Vec::new();

    // Warm-up: run every (query, tau) once to size the scratch buffers
    // and the hit vector's capacity.
    for q in &queries {
        for &tau in &taus {
            out.clear();
            let mut coll = CollectIds::new(tau, &mut out);
            bst.run(q, &mut ctx, &mut coll);
        }
    }

    // Measurement: the same traffic must not touch the allocator at all.
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..3 {
        for q in &queries {
            for &tau in &taus {
                out.clear();
                let mut coll = CollectIds::new(tau, &mut out);
                bst.run(q, &mut ctx, &mut coll);
            }
            // counting traversals share the same zero-alloc path
            let mut cnt = CountOnly::new(2);
            bst.run(q, &mut ctx, &mut cnt);
            assert!(cnt.count() > 0, "query is a database row");
        }
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "bST threshold search must be allocation-free after QueryCtx warm-up"
    );
    assert!(!out.is_empty(), "last query returned its own posting group");

    // --- Top-k: the heap lives in QueryCtx; after warm-up the whole
    // nearest-neighbor query (traversal + heap + drained results) must
    // not touch the allocator either.
    let idx = SingleBst::build(&set, BstConfig::default());
    let mut topk_ctx = QueryCtx::new();
    let mut hits: Vec<(u32, usize)> = Vec::new();
    let ks = [1usize, 8, 32];
    for q in &queries {
        for &k in &ks {
            idx.top_k_into(q, k, l, &mut topk_ctx, &mut hits);
        }
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..3 {
        for q in &queries {
            for &k in &ks {
                idx.top_k_into(q, k, l, &mut topk_ctx, &mut hits);
                assert!(!hits.is_empty(), "query is a database row");
            }
        }
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "top-k must be allocation-free after the QueryCtx heap warms up"
    );

    // --- Range-kernel scan: the linear verifier streams the whole
    // database through `ham_range_leq`; after one warm-up query the
    // packed planes, the kernel cursor (stack-only) and the hit vector
    // must never touch the allocator.
    let linear = LinearScan::build(&set);
    let mut lin_ctx = QueryCtx::new();
    for q in &queries {
        for &tau in &taus {
            out.clear();
            let mut coll = CollectIds::new(tau, &mut out);
            linear.run(q, &mut lin_ctx, &mut coll);
        }
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..3 {
        for q in &queries {
            for &tau in &taus {
                out.clear();
                let mut coll = CollectIds::new(tau, &mut out);
                linear.run(q, &mut lin_ctx, &mut coll);
            }
        }
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "range-kernel linear scan must be allocation-free after warm-up"
    );
    assert!(!out.is_empty(), "last query returned at least itself");

    // --- Blocked execution: a whole query block shares one trie pass.
    // The packed block planes live in `QueryCtx` (`block_q`), the
    // per-query work counters sit on the `BlockCollector`'s stack, and
    // the collectors/slot arrays are stack arrays — after one warm-up
    // block, re-running the block must not touch the allocator.
    const W: usize = 8;
    let block_qs: Vec<&[u8]> = queries.iter().take(W).map(|q| q.as_slice()).collect();
    let mut blk_ctx = QueryCtx::new();
    let mut block_outs: [Vec<u32>; W] = std::array::from_fn(|_| Vec::new());
    let mut run_block = |ctx: &mut QueryCtx, outs: &mut [Vec<u32>; W]| {
        let mut out_it = outs.iter_mut();
        let mut colls: [CollectIds; W] = std::array::from_fn(|_| {
            let o = out_it.next().unwrap();
            o.clear();
            CollectIds::new(2, o)
        });
        let mut coll_it = colls.iter_mut();
        let mut slots: [&mut dyn Collector; W] =
            std::array::from_fn(|_| coll_it.next().unwrap() as &mut dyn Collector);
        let mut bc = BlockCollector::new(&mut slots);
        bst.run_block(&block_qs, ctx, &mut bc);
    };
    run_block(&mut blk_ctx, &mut block_outs); // warm-up: size block_q + hit vecs
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..3 {
        run_block(&mut blk_ctx, &mut block_outs);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(after - before, 0, "blocked bST execution must be allocation-free after warm-up");
    assert!(block_outs.iter().all(|o| !o.is_empty()), "every block query is a database row");
}
