//! End-to-end server test: engine + batcher + TCP protocol over a real
//! socket (port 0, OS-assigned).

use bst::coordinator::engine::{Engine, ShardIndexKind};
use bst::coordinator::{server, ServeConfig};
use bst::sketch::hamming::ham_chars;
use bst::sketch::SketchSet;
use bst::trie::bst::BstConfig;
use bst::util::json::Json;
use bst::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn make_engine(n: usize) -> (Arc<Engine>, Vec<Vec<u8>>) {
    let mut rng = Rng::new(0x5e1);
    let centers: Vec<Vec<u8>> = (0..6)
        .map(|_| (0..12).map(|_| rng.below(4) as u8).collect())
        .collect();
    let rows: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            let mut r = centers[rng.below_usize(6)].clone();
            for _ in 0..rng.below_usize(3) {
                let p = rng.below_usize(12);
                r[p] = rng.below(4) as u8;
            }
            r
        })
        .collect();
    let set = SketchSet::from_rows(2, 12, &rows);
    (
        Arc::new(Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()))),
        rows,
    )
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        let _ = stream.set_nodelay(true);
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn call(&mut self, req: &str) -> Json {
        self.writer.write_all(req.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).expect("valid json response")
    }
}

#[test]
fn search_over_tcp_matches_engine() {
    let (engine, rows) = make_engine(800);
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let handle = server::serve(Arc::clone(&engine), cfg).expect("serve");
    let mut client = Client::connect(handle.addr);

    // ping
    let pong = client.call(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));

    // searches
    for qi in [0usize, 100, 500] {
        let q = &rows[qi];
        let tau = 2;
        let req = format!(
            r#"{{"op":"search","q":[{}],"tau":{tau}}}"#,
            q.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
        );
        let resp = client.call(&req);
        let mut ids: Vec<u32> = resp
            .get("ids")
            .and_then(|a| a.as_arr())
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect();
        ids.sort();
        let expect: Vec<u32> = (0..rows.len())
            .filter(|&i| ham_chars(&rows[i], q) <= tau)
            .map(|i| i as u32)
            .collect();
        assert_eq!(ids, expect, "qi={qi}");
        assert!(resp.get("latency_us").is_some());
    }

    // count matches the id search
    {
        let q = &rows[100];
        let tau = 2usize;
        let qs = q.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",");
        let ids = client
            .call(&format!(r#"{{"op":"search","q":[{qs}],"tau":{tau}}}"#))
            .get("ids")
            .and_then(|a| a.as_arr())
            .unwrap()
            .len();
        let resp = client.call(&format!(r#"{{"op":"count","q":[{qs}],"tau":{tau}}}"#));
        assert_eq!(resp.get("count").and_then(|c| c.as_usize()), Some(ids));

        // top-k: dists sorted, ids within tau, k respected
        let resp = client.call(&format!(r#"{{"op":"topk","q":[{qs}],"k":4,"tau":6}}"#));
        let t_ids = resp.get("ids").and_then(|a| a.as_arr()).unwrap();
        let dists = resp.get("dists").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(t_ids.len(), dists.len());
        assert!(t_ids.len() <= 4 && !t_ids.is_empty());
        let dv: Vec<usize> = dists.iter().map(|d| d.as_usize().unwrap()).collect();
        assert!(dv.windows(2).all(|w| w[0] <= w[1]), "dists sorted: {dv:?}");
        assert_eq!(dv[0], 0, "query is a database row");

        // malformed top-k (k=0) is rejected
        let err = client.call(&format!(r#"{{"op":"topk","q":[{qs}],"k":0}}"#));
        assert!(err.get("error").is_some());
    }

    // stats reflect the traffic
    let stats = client.call(r#"{"op":"stats"}"#);
    assert!(stats.get("queries").unwrap().as_usize().unwrap() >= 3);

    // malformed request → error, connection stays usable
    let err = client.call(r#"{"op":"search"}"#);
    assert!(err.get("error").is_some());
    let pong = client.call(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));

    // wrong query length → protocol error
    let err = client.call(r#"{"op":"search","q":[1,2],"tau":1}"#);
    assert!(err.get("error").is_some());

    handle.stop();
}

#[test]
fn reload_swaps_in_snapshot_engine() {
    let (engine, rows) = make_engine(400);
    let n1 = rows.len();

    // A second database with the same L but different size, saved as a
    // snapshot the running server will be told to reload.
    let mut rng = Rng::new(0x7e10);
    let rows2: Vec<Vec<u8>> = (0..150)
        .map(|_| (0..12).map(|_| rng.below(4) as u8).collect())
        .collect();
    let set2 = SketchSet::from_rows(2, 12, &rows2);
    let engine2 = Engine::build(&set2, 2, &ShardIndexKind::Bst(BstConfig::default()));
    let dir = std::env::temp_dir().join("bst_server_reload_test");
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("reload.snap");
    engine2.save(&snap).unwrap();
    drop(engine2);

    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let handle = server::serve(engine, cfg).expect("serve");
    let mut client = Client::connect(handle.addr);
    let q = "0,".repeat(11) + "0"; // L=12 query; tau=L counts everything

    let before = client.call(&format!(r#"{{"op":"count","q":[{q}],"tau":12}}"#));
    assert_eq!(before.get("count").and_then(|c| c.as_usize()), Some(n1));

    // A bad path is rejected and the old engine keeps serving.
    let err = client.call(r#"{"op":"reload","path":"/nonexistent/x.snap"}"#);
    assert!(err.get("error").is_some());
    let still = client.call(&format!(r#"{{"op":"count","q":[{q}],"tau":12}}"#));
    assert_eq!(still.get("count").and_then(|c| c.as_usize()), Some(n1));

    // A corrupt snapshot fails validation — error response, old engine
    // keeps serving untouched.
    let corrupt = dir.join("corrupt.snap");
    let mut bytes = std::fs::read(&snap).unwrap();
    let mid = bytes.len() / 2;
    for b in &mut bytes[mid..mid + 8] {
        *b ^= 0x11;
    }
    std::fs::write(&corrupt, &bytes).unwrap();
    let err = client.call(&format!(
        r#"{{"op":"reload","path":"{}"}}"#,
        corrupt.display()
    ));
    assert!(err.get("error").is_some(), "{err:?}");
    let still = client.call(&format!(r#"{{"op":"count","q":[{q}],"tau":12}}"#));
    assert_eq!(still.get("count").and_then(|c| c.as_usize()), Some(n1));

    // A snapshot with the right L but a different alphabet width is
    // rejected as a schema mismatch.
    let rows4: Vec<Vec<u8>> = (0..80)
        .map(|_| (0..12).map(|_| rng.below(16) as u8).collect())
        .collect();
    let set4 = SketchSet::from_rows(4, 12, &rows4);
    let engine4 = Engine::build(&set4, 1, &ShardIndexKind::Bst(BstConfig::default()));
    let snap4 = dir.join("wrong_b.snap");
    engine4.save(&snap4).unwrap();
    drop(engine4);
    let err = client.call(&format!(
        r#"{{"op":"reload","path":"{}"}}"#,
        snap4.display()
    ));
    let msg = err.get("error").and_then(|e| e.as_str()).expect("error response").to_string();
    assert!(msg.contains("b=4"), "mismatch names the offending width: {msg}");
    let still = client.call(&format!(r#"{{"op":"count","q":[{q}],"tau":12}}"#));
    assert_eq!(still.get("count").and_then(|c| c.as_usize()), Some(n1));

    // Reload the snapshot: subsequent queries hit the new database.
    let ok = client.call(&format!(
        r#"{{"op":"reload","path":"{}"}}"#,
        snap.display()
    ));
    assert_eq!(ok.get("ok").and_then(|b| b.as_bool()), Some(true), "{ok:?}");
    assert_eq!(ok.get("n").and_then(|n| n.as_usize()), Some(150));
    let after = client.call(&format!(r#"{{"op":"count","q":[{q}],"tau":12}}"#));
    assert_eq!(after.get("count").and_then(|c| c.as_usize()), Some(150));

    // top-k over the reloaded engine still flows end to end.
    let topk = client.call(&format!(r#"{{"op":"topk","q":[{q}],"k":3}}"#));
    assert_eq!(topk.get("ids").and_then(|a| a.as_arr()).map(|a| a.len()), Some(3));

    handle.stop();
    for p in [&snap, &corrupt, &snap4] {
        std::fs::remove_file(p).unwrap();
    }
}

/// A server in mapped mode (`--mmap`): the cold-started engine serves
/// zero-copy from the snapshot mapping, answers over TCP exactly like
/// the engine it was saved from, and `reload` keeps the mapped mode.
#[test]
fn mapped_serving_over_tcp_matches_owned() {
    let (engine, rows) = make_engine(500);
    let dir = std::env::temp_dir().join("bst_server_mmap_test");
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("serve.snap");
    engine.save(&snap).unwrap();

    let mapped = Engine::load_with(&snap, true).expect("mapped cold start");
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), mmap: true, ..Default::default() };
    let handle = server::serve(Arc::new(mapped), cfg).expect("serve");
    let mut client = Client::connect(handle.addr);

    for qi in [0usize, 250, 499] {
        let q = &rows[qi];
        let req = format!(
            r#"{{"op":"search","q":[{}],"tau":2}}"#,
            q.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
        );
        let mut ids: Vec<u32> = client
            .call(&req)
            .get("ids")
            .and_then(|a| a.as_arr())
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect();
        ids.sort();
        let mut expect = engine.search(q, 2);
        expect.sort();
        assert_eq!(ids, expect, "qi={qi}");
    }

    // reload under the mapped serving mode swaps in another mapped load
    let ok = client.call(&format!(
        r#"{{"op":"reload","path":"{}"}}"#,
        snap.display()
    ));
    assert_eq!(ok.get("ok").and_then(|b| b.as_bool()), Some(true), "{ok:?}");
    assert_eq!(ok.get("n").and_then(|n| n.as_usize()), Some(rows.len()));
    let q = "0,".repeat(11) + "0";
    let after = client.call(&format!(r#"{{"op":"count","q":[{q}],"tau":12}}"#));
    assert_eq!(after.get("count").and_then(|c| c.as_usize()), Some(rows.len()));

    handle.stop();
    std::fs::remove_file(&snap).unwrap();
}

#[test]
fn write_ops_over_tcp() {
    let (engine, rows) = make_engine(300);
    let n0 = rows.len();
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let handle = server::serve(Arc::clone(&engine), cfg).expect("serve");
    let mut client = Client::connect(handle.addr);
    let enc = |r: &[u8]| r.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",");

    // insert two rows: consecutive global ids starting at n0
    let resp = client.call(&format!(
        r#"{{"op":"insert","rows":[[{}],[{}]]}}"#,
        enc(&rows[0]),
        enc(&rows[1])
    ));
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{resp:?}");
    assert_eq!(resp.get("first_id").and_then(|x| x.as_usize()), Some(n0));
    assert_eq!(resp.get("inserted").and_then(|x| x.as_usize()), Some(2));

    // the duplicate of row 0 is immediately visible at tau=0
    let found = client.call(&format!(r#"{{"op":"search","q":[{}],"tau":0}}"#, enc(&rows[0])));
    let ids: Vec<usize> = found
        .get("ids")
        .and_then(|a| a.as_arr())
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();
    assert!(ids.contains(&n0), "inserted row visible: {ids:?}");

    // delete it again; repeated delete reports false
    let resp = client.call(&format!(r#"{{"op":"delete","id":{n0}}}"#));
    assert_eq!(resp.get("deleted").and_then(|b| b.as_bool()), Some(true));
    let resp = client.call(&format!(r#"{{"op":"delete","id":{n0}}}"#));
    assert_eq!(resp.get("deleted").and_then(|b| b.as_bool()), Some(false));
    let found = client.call(&format!(r#"{{"op":"search","q":[{}],"tau":0}}"#, enc(&rows[0])));
    let ids: Vec<usize> = found
        .get("ids")
        .and_then(|a| a.as_arr())
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();
    assert!(!ids.contains(&n0), "tombstone respected: {ids:?}");

    // force a merge: all shards fold, none skipped, results unchanged
    let resp = client.call(r#"{"op":"merge"}"#);
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true));
    assert_eq!(resp.get("merged").and_then(|x| x.as_usize()), Some(engine.n_shards()));
    assert_eq!(resp.get("skipped").and_then(|x| x.as_usize()), Some(0));
    let after = client.call(&format!(r#"{{"op":"search","q":[{}],"tau":0}}"#, enc(&rows[1])));
    let after_ids: Vec<usize> = after
        .get("ids")
        .and_then(|a| a.as_arr())
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();
    assert!(after_ids.contains(&(n0 + 1)), "surviving insert still found post-merge");
    assert!(!after_ids.contains(&n0), "tombstone survives the merge");

    // malformed writes are rejected without killing the connection
    let err = client.call(r#"{"op":"insert","rows":[[1,2]]}"#);
    assert!(err.get("error").is_some(), "wrong row length");
    let err = client.call(r#"{"op":"insert","rows":[]}"#);
    assert!(err.get("error").is_some());
    let pong = client.call(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));

    // stats expose the write counters
    let stats = client.call(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("inserts").and_then(|x| x.as_usize()), Some(2));
    assert_eq!(stats.get("deletes").and_then(|x| x.as_usize()), Some(1));
    assert!(stats.get("merges").and_then(|x| x.as_usize()).unwrap() >= 1);

    handle.stop();
}

#[test]
fn concurrent_clients() {
    let (engine, rows) = make_engine(600);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        max_delay_us: 300,
        ..Default::default()
    };
    let handle = server::serve(Arc::clone(&engine), cfg).expect("serve");
    let addr = handle.addr;

    let mut joins = Vec::new();
    for t in 0..6 {
        let rows = rows.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr);
            let mut rng = Rng::new(t);
            for _ in 0..25 {
                let qi = rng.below_usize(rows.len());
                let tau = rng.below_usize(4);
                let req = format!(
                    r#"{{"op":"search","q":[{}],"tau":{tau}}}"#,
                    rows[qi]
                        .iter()
                        .map(|c| c.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                );
                let resp = client.call(&req);
                let ids = resp.get("ids").and_then(|a| a.as_arr()).unwrap();
                // must at least contain itself
                assert!(ids.iter().any(|x| x.as_f64() == Some(qi as f64)));
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let metrics = engine.metrics();
    assert!(metrics.queries.load(std::sync::atomic::Ordering::Relaxed) >= 150);
    handle.stop();
}
