//! Property suite for the dynamic-update path (PR 4): base + delta +
//! tombstone query results must equal a linear-scan oracle across
//! b ∈ {1, 2, 4, 8} and random insert / delete / merge interleavings,
//! the mutated engine must roundtrip through the v2 snapshot sections,
//! and the v1 format must keep loading (all-immutable) while rejecting
//! files that smuggle delta sections under the old version.

use bst::coordinator::engine::{Engine, MergeSummary, ShardIndexKind};
use bst::index::{SearchIndex, SingleBst};
use bst::sketch::hamming::ham_chars;
use bst::sketch::SketchSet;
use bst::store::{to_payload_legacy, ByteWriter, SnapshotBuilder, FORMAT_VERSION_V1};
use bst::trie::bst::BstConfig;
use bst::util::Rng;

/// Shapes exercising every alphabet width (L kept small enough that the
/// randomized suite stays fast but clusters still form).
const SHAPES: &[(usize, usize)] = &[(1, 16), (2, 12), (4, 8), (8, 6)];

struct Oracle {
    rows: Vec<Vec<u8>>,
    alive: Vec<bool>,
}

impl Oracle {
    fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        (0..self.rows.len())
            .filter(|&i| self.alive[i] && ham_chars(&self.rows[i], q) <= tau)
            .map(|i| i as u32)
            .collect()
    }

    fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        let mut all: Vec<(usize, u32)> = (0..self.rows.len())
            .filter(|&i| self.alive[i])
            .map(|i| (ham_chars(&self.rows[i], q), i as u32))
            .filter(|&(d, _)| d <= tau)
            .collect();
        all.sort_unstable();
        all.truncate(k);
        all.into_iter().map(|(d, id)| (id, d)).collect()
    }
}

fn random_row(rng: &mut Rng, b: usize, l: usize, centers: &[Vec<u8>]) -> Vec<u8> {
    let mut row = centers[rng.below_usize(centers.len())].clone();
    for _ in 0..rng.below_usize(3) {
        let p = rng.below_usize(l);
        row[p] = rng.below(1 << b) as u8;
    }
    row
}

fn check_engine(engine: &Engine, oracle: &Oracle, rng: &mut Rng, b: usize, l: usize, tag: &str) {
    for _ in 0..3 {
        let q: Vec<u8> = if oracle.rows.is_empty() || rng.below(2) == 0 {
            (0..l).map(|_| rng.below(1 << b) as u8).collect()
        } else {
            oracle.rows[rng.below_usize(oracle.rows.len())].clone()
        };
        for tau in [0usize, 1, 2, 4] {
            let mut got = engine.search(&q, tau);
            got.sort_unstable();
            assert_eq!(got, oracle.search(&q, tau), "{tag}: search b={b} tau={tau}");
            assert_eq!(engine.count(&q, tau), got.len(), "{tag}: count b={b} tau={tau}");
        }
        for k in [1usize, 5, 100] {
            assert_eq!(engine.top_k(&q, k, l), oracle.top_k(&q, k, l), "{tag}: topk b={b} k={k}");
        }
    }
}

/// Random insert / delete / merge interleavings against the oracle, with
/// background merges enabled (tiny threshold) so seal/install races are
/// exercised, then a force merge, a snapshot roundtrip, and more writes
/// on the reloaded engine.
#[test]
fn prop_dynamic_matches_linear_oracle() {
    let dir = std::env::temp_dir().join("bst_prop_dynamic");
    std::fs::create_dir_all(&dir).unwrap();
    for &(b, l) in SHAPES {
        let mut rng = Rng::new((0xD1A + b * 131 + l) as u64);
        let centers: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let n0 = 250;
        let initial: Vec<Vec<u8>> = (0..n0)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        let set = SketchSet::from_rows(b, l, &initial);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        engine.set_merge_threshold(24);
        let mut oracle = Oracle { rows: initial, alive: vec![true; n0] };

        for step in 0..12 {
            match rng.below(4) {
                // insert a batch
                0 | 1 => {
                    let m = 1 + rng.below_usize(40);
                    let batch: Vec<Vec<u8>> =
                        (0..m).map(|_| random_row(&mut rng, b, l, &centers)).collect();
                    let range = engine.insert_batch(&batch).unwrap();
                    assert_eq!(range.start as usize, oracle.rows.len(), "ids are sequential");
                    assert_eq!(range.end - range.start, m as u32);
                    oracle.rows.extend(batch);
                    oracle.alive.resize(oracle.rows.len(), true);
                }
                // delete a random id (possibly already dead)
                2 => {
                    let id = rng.below_usize(oracle.rows.len() + 5);
                    let expect = id < oracle.rows.len() && oracle.alive[id];
                    assert_eq!(engine.delete(id as u32), expect, "delete id={id}");
                    if expect {
                        oracle.alive[id] = false;
                    }
                }
                // force merge
                _ => {
                    let summary = engine.merge();
                    assert_eq!(summary, MergeSummary { merged: 3, skipped: 0 });
                }
            }
            check_engine(&engine, &oracle, &mut rng, b, l, &format!("step {step}"));
        }

        // Snapshot the mutated engine mid-state (deltas + tombstones in
        // the container), reload, and keep writing.
        let path = dir.join(format!("dyn_{b}.snap"));
        engine.save(&path).unwrap();
        let loaded = Engine::load(&path).unwrap();
        assert_eq!(loaded.n(), oracle.rows.len());
        assert_eq!(loaded.b(), b);
        check_engine(&loaded, &oracle, &mut rng, b, l, "reloaded");
        // Mapped axis: the same mid-state snapshot (deltas + tombstones
        // live in the container) served zero-copy from a read-only
        // mapping must match the oracle exactly like the owned load.
        let mapped = Engine::load_with(&path, true).unwrap();
        assert_eq!(mapped.n(), loaded.n());
        assert_eq!(mapped.b(), b);
        check_engine(&mapped, &oracle, &mut rng, b, l, "reloaded (mapped)");

        let extra: Vec<Vec<u8>> = (0..17)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        loaded.insert_batch(&extra).unwrap();
        oracle.rows.extend(extra);
        oracle.alive.resize(oracle.rows.len(), true);
        let id = (oracle.rows.len() - 3) as u32;
        assert!(loaded.delete(id));
        oracle.alive[id as usize] = false;
        check_engine(&loaded, &oracle, &mut rng, b, l, "reloaded+written");

        // After a final merge everything is immutable and still equal.
        assert_eq!(loaded.merge().skipped, 0);
        check_engine(&loaded, &oracle, &mut rng, b, l, "final merge");
        loaded.save(&path).unwrap();
        let cold = Engine::load(&path).unwrap();
        check_engine(&cold, &oracle, &mut rng, b, l, "cold after merge");
        // A mapped cold start stays fully writable: inserts land in
        // owned deltas, merges rebuild into owned memory (never into
        // the read-only mapping), and a save from the mapped engine
        // reloads identically.
        let cold_mapped = Engine::load_with(&path, true).unwrap();
        check_engine(&cold_mapped, &oracle, &mut rng, b, l, "cold after merge (mapped)");
        let extra: Vec<Vec<u8>> = (0..9)
            .map(|_| random_row(&mut rng, b, l, &centers))
            .collect();
        cold_mapped.insert_batch(&extra).unwrap();
        oracle.rows.extend(extra);
        oracle.alive.resize(oracle.rows.len(), true);
        let id = (oracle.rows.len() - 2) as u32;
        assert!(cold_mapped.delete(id));
        oracle.alive[id as usize] = false;
        check_engine(&cold_mapped, &oracle, &mut rng, b, l, "mapped+written");
        assert_eq!(cold_mapped.merge().skipped, 0);
        check_engine(&cold_mapped, &oracle, &mut rng, b, l, "mapped+merged");
        cold_mapped.save(&path).unwrap();
        let resaved = Engine::load(&path).unwrap();
        check_engine(&resaved, &oracle, &mut rng, b, l, "saved from mapped");
        std::fs::remove_file(&path).unwrap();
    }
}

/// The mutated snapshot carries the new sections, and byte-level
/// corruption of the delta payload is caught on load.
#[test]
fn mutated_snapshot_sections_and_corruption() {
    let mut rng = Rng::new(0xD2B);
    let rows: Vec<Vec<u8>> = (0..200)
        .map(|_| (0..10).map(|_| rng.below(4) as u8).collect())
        .collect();
    let set = SketchSet::from_rows(2, 10, &rows[..150]);
    let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
    engine.insert_batch(&rows[150..]).unwrap();
    engine.delete(10);
    let dir = std::env::temp_dir().join("bst_prop_dynamic");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sections.snap");
    engine.save(&path).unwrap();

    let snap = bst::store::Snapshot::open(&path).unwrap();
    assert_eq!(snap.version(), bst::store::FORMAT_VERSION);
    let expected_sections = [
        "meta",
        "shard.0",
        "shard.1",
        "rows.0",
        "rows.1",
        "delta.0",
        "delta.1",
        "tombstones.0",
        "tombstones.1",
    ];
    for name in expected_sections {
        assert!(snap.has_section(name), "missing section {name}");
    }
    drop(snap);

    // Flip bytes across the whole file: every corruption must surface as
    // Err (checksum or validation), never a panic or a silent misload.
    let good = std::fs::read(&path).unwrap();
    let mut ok = 0usize;
    for pos in (17..good.len()).step_by(good.len() / 23) {
        let mut bad = good.clone();
        for b in &mut bad[pos..(pos + 8).min(good.len())] {
            *b ^= 0x24;
        }
        std::fs::write(&path, &bad).unwrap();
        let owned_err = Engine::load(&path).is_err();
        // Validation is identical under both load modes — a mapped load
        // must reject exactly the files the owned load rejects.
        assert_eq!(
            Engine::load_with(&path, true).is_err(),
            owned_err,
            "mapped/owned corruption verdicts diverge at pos={pos}"
        );
        if owned_err {
            ok += 1;
        }
    }
    assert!(ok > 0, "at least the payload flips must be rejected");
    std::fs::write(&path, &good).unwrap();
    assert!(Engine::load(&path).is_ok(), "pristine bytes load again");
    assert!(Engine::load_with(&path, true).is_ok(), "pristine bytes map again");
    std::fs::remove_file(&path).unwrap();
}

/// Builds a v1-era container byte-for-byte: v1 `meta` layout (L, n,
/// shard offsets) + `shard.N` payloads in the legacy unpadded byte
/// layout, version field patched to 1 (v1/v2 sections carry no interior
/// alignment padding — the reader keys the layout off the version).
fn v1_container(set: &SketchSet, extra_sections: &[(&str, Vec<u8>)]) -> Vec<u8> {
    let index = ShardIndexKind::Bst(BstConfig::default()).build_index(set);
    let mut meta = ByteWriter::legacy();
    meta.put_usize(set.l());
    meta.put_usize(set.n());
    meta.put_usize(1); // one shard
    meta.put_u64(0); // offset 0
    let mut builder = SnapshotBuilder::new();
    builder.add_section("meta", meta.into_bytes());
    builder.add_section("shard.0", to_payload_legacy(&index));
    for (name, payload) in extra_sections {
        builder.add_section(name, payload.clone());
    }
    let mut bytes = builder.to_bytes();
    bytes[8..12].copy_from_slice(&FORMAT_VERSION_V1.to_le_bytes());
    bytes
}

/// v1 snapshots still load — as all-immutable engines: queries work,
/// inserts/deletes land in deltas/tombstones, but merges are skipped
/// (no raw rows behind the base) — and a v1 file that smuggles a
/// `delta.N` section is rejected outright.
#[test]
fn v1_loads_all_immutable_and_rejects_smuggled_deltas() {
    let mut rng = Rng::new(0xD3C);
    let rows: Vec<Vec<u8>> = (0..120)
        .map(|_| (0..12).map(|_| rng.below(4) as u8).collect())
        .collect();
    let set = SketchSet::from_rows(2, 12, &rows);
    let dir = std::env::temp_dir().join("bst_prop_dynamic");
    std::fs::create_dir_all(&dir).unwrap();

    let path = dir.join("legacy.snap");
    std::fs::write(&path, v1_container(&set, &[])).unwrap();
    let engine = Engine::load(&path).unwrap();
    assert_eq!(engine.n(), 120);
    assert_eq!(engine.b(), 2);
    // v1 files also load under the mapped mode (their unpadded interiors
    // simply fall back to owned copies where alignment demands it).
    let v1_mapped = Engine::load_with(&path, true).unwrap();
    assert_eq!(v1_mapped.n(), 120);
    assert_eq!(v1_mapped.search(&rows[0], 0), engine.search(&rows[0], 0));
    // read path parity against a from-scratch index
    let oracle_idx = SingleBst::build(&set, BstConfig::default());
    for qi in [0usize, 50, 119] {
        for tau in [0usize, 2] {
            let mut got = engine.search(&rows[qi], tau);
            got.sort_unstable();
            let mut expect = oracle_idx.search(&rows[qi], tau);
            expect.sort_unstable();
            assert_eq!(got, expect, "qi={qi} tau={tau}");
        }
    }
    // writes work (delta-only), but merging is skipped: no raw rows
    let range = engine.insert_batch(&rows[..5]).unwrap();
    assert_eq!(range, 120..125);
    assert!(engine.delete(121));
    let summary = engine.merge();
    assert_eq!(summary, MergeSummary { merged: 0, skipped: 1 });
    let mut got = engine.search(&rows[0], 0);
    got.sort_unstable();
    assert!(got.contains(&120), "delta row visible after skipped merge");
    assert!(!got.contains(&121), "tombstone respected");
    // Re-saving encodes v2, but legacy shards still have no raw rows:
    // has_rows stays 0 and the reloaded engine remains merge-skipped.
    let resaved = dir.join("legacy_resaved.snap");
    engine.save(&resaved).unwrap();
    let reloaded = Engine::load(&resaved).unwrap();
    assert_eq!(reloaded.n(), 125);
    assert_eq!(reloaded.merge().skipped, 1);

    // A "v1" file carrying a delta section must not silently load.
    let mut w = ByteWriter::legacy();
    w.put_u32s(&[1, 2, 3]);
    let smuggled = v1_container(&set, &[("delta.0", w.into_bytes())]);
    let bad = dir.join("smuggled.snap");
    std::fs::write(&bad, smuggled).unwrap();
    assert!(Engine::load(&bad).is_err(), "v1 with delta sections is rejected");

    for p in [path, resaved, bad] {
        std::fs::remove_file(p).unwrap();
    }
}
