//! Property-based tests for the succinct bit substrate (proptest is not
//! vendored; we drive our own PRNG through many random configurations and
//! assert the defining invariants).

use bst::bits::rsvec::SelectMode;
use bst::bits::{BitVec, IntVec, RsBitVec};
use bst::util::Rng;

/// rank/select inverse laws over random densities and lengths.
#[test]
fn prop_rank_select_inverse() {
    let mut rng = Rng::new(0xB175);
    for case in 0..60 {
        let n = 1 + rng.below_usize(30_000);
        let density = rng.f64();
        let bv: BitVec = {
            let mut r = Rng::new(case);
            (0..n).map(|_| r.f64() < density).collect()
        };
        let rs = RsBitVec::new(bv.clone(), SelectMode::Both);
        // total consistency
        assert_eq!(rs.count_ones(), bv.count_ones());
        assert_eq!(rs.rank1(n), rs.count_ones());
        // rank is monotone with unit steps
        let mut prev = 0;
        for i in (0..=n).step_by(1 + n / 97) {
            let r = rs.rank1(i);
            assert!(r >= prev && r <= i);
            prev = r;
        }
        // select1 ∘ rank1 = identity on ones
        let ones = rs.count_ones();
        if ones > 0 {
            for _ in 0..50 {
                let k = rng.below_usize(ones);
                let pos = rs.select1(k);
                assert!(rs.get(pos));
                assert_eq!(rs.rank1(pos), k);
            }
        }
        // select0 ∘ rank0
        let zeros = n - ones;
        if zeros > 0 {
            for _ in 0..50 {
                let k = rng.below_usize(zeros);
                let pos = rs.select0(k);
                assert!(!rs.get(pos));
                assert_eq!(rs.rank0(pos), k);
            }
        }
    }
}

/// Unaligned get_bits equals bit-by-bit reconstruction for random layouts.
#[test]
fn prop_get_bits_consistency() {
    let mut rng = Rng::new(0xB173);
    for _ in 0..40 {
        let n_words = 1 + rng.below_usize(100);
        let mut bv = BitVec::new();
        for _ in 0..n_words {
            bv.push_bits(rng.next_u64(), 64);
        }
        for _ in 0..200 {
            let width = 1 + rng.below_usize(64);
            if bv.len() < width {
                continue;
            }
            let pos = rng.below_usize(bv.len() - width + 1);
            let got = bv.get_bits(pos, width);
            let mut expect = 0u64;
            for i in 0..width {
                expect |= (bv.get(pos + i) as u64) << i;
            }
            assert_eq!(got, expect);
        }
    }
}

/// IntVec roundtrips across random widths and lengths.
#[test]
fn prop_intvec_roundtrip() {
    let mut rng = Rng::new(0x1279);
    for _ in 0..50 {
        let width = 1 + rng.below_usize(64);
        let n = rng.below_usize(2000);
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let mut iv = IntVec::new(width);
        for &v in &vals {
            iv.push(v);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(iv.get(i), v, "width={width} i={i}");
        }
    }
}

/// Select on pathological run-structured vectors (long runs of 0s/1s).
#[test]
fn prop_select_on_runs() {
    let mut rng = Rng::new(0x58EC);
    for _ in 0..30 {
        let mut bv = BitVec::new();
        let mut expected_ones = Vec::new();
        let mut pos = 0usize;
        for _ in 0..rng.below_usize(30) + 1 {
            let run = 1 + rng.below_usize(3000);
            let bit = rng.f64() < 0.5;
            for _ in 0..run {
                bv.push(bit);
                if bit {
                    expected_ones.push(pos);
                }
                pos += 1;
            }
        }
        let rs = RsBitVec::new(bv, SelectMode::Ones);
        assert_eq!(rs.count_ones(), expected_ones.len());
        for (k, &p) in expected_ones.iter().enumerate().step_by(17) {
            assert_eq!(rs.select1(k), p);
        }
    }
}
