//! Minimal command-line argument parser (clap is not vendored).
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag` forms,
//! plus positional arguments.

use std::collections::BTreeMap;

/// Parsed arguments: positionals + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
}

impl Args {
    /// Parses from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("eval table3 --scale 0.5 --queries=100 --verbose");
        assert_eq!(a.positional, vec!["eval", "table3"]);
        assert_eq!(a.get_f64("scale", 1.0), 0.5);
        assert_eq!(a.get_usize("queries", 0), 100);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_or("addr", "127.0.0.1:7878"), "127.0.0.1:7878");
        assert_eq!(a.get_u64("seed", 42), 42);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("--offset -5");
        // "-5" does not start with -- so it's consumed as the value
        assert_eq!(a.get("offset"), Some("-5"));
    }
}
