//! Query execution: reusable per-query scratch + pluggable collectors.
//!
//! The hot path of every search method is a traversal (trie descent,
//! signature probing, linear scan) that *produces candidate ids with
//! known Hamming distances*. Before this subsystem, each layer baked in
//! one consumption policy ("append ids to a `Vec<u32>`") and re-allocated
//! its scratch on every call. The query subsystem splits the two concerns:
//!
//! * [`QueryCtx`] — all per-query scratch, owned by the caller and reused
//!   across queries: packed query bit-planes, the middle-layer fan-out
//!   buffer (sized `1 << b`, one slot per traversal level), and nothing
//!   else. After one warm-up query a `BstTrie` threshold search performs
//!   **zero heap allocations** (asserted by `tests/query_alloc.rs`).
//! * [`Collector`] — the consumption policy, threaded through every trie
//!   ([`crate::trie::SketchTrie::run`]) and every index
//!   ([`crate::index::SearchIndex::run`]):
//!     * [`CollectIds`] — classic semantics: append matching ids.
//!     * [`CountOnly`] — aggregate counting, no result materialization.
//!     * [`TopK`] — bounded max-heap over exact distances; its
//!       [`Collector::tau`] tightens as the heap fills, turning any
//!       threshold traversal into an adaptive nearest-neighbor search
//!       (the top-k extension of Kanda & Tabei's dynamic-sketch line).
//!     * [`StatsObserver`] — wraps another collector and fills
//!       [`TraversalStats`] (visited / pruned / emitted), the node-visit
//!       accounting the eval harness reports.
//!
//! The contract between traversal and collector: the traversal may prune
//! any subtree whose running distance exceeds the *current* `c.tau()`,
//! and must call `c.emit(ids, dist)` with the **exact** distance for every
//! surviving candidate group. Because `TopK::tau()` only ever decreases,
//! pruning against the live threshold is always sound.
//!
//! Blocked execution ([`BlockCollector`]) runs up to [`MAX_BLOCK`]
//! compatible queries through one traversal pass; every per-query event
//! is routed to that query's own collector, so blocked results and
//! stats are byte-identical to serial execution.

mod block;
mod collector;
mod ctx;

pub use block::{live_mask, BlockCollector, SlotRef, MAX_BLOCK};
pub use collector::{CollectIds, Collector, CountOnly, StatsObserver, TopK, TraversalStats};
pub use ctx::QueryCtx;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_ids_appends() {
        let mut out = Vec::new();
        let mut c = CollectIds::new(3, &mut out);
        assert_eq!(c.tau(), 3);
        c.emit(&[1, 2], 1);
        c.emit(&[7], 3);
        assert_eq!(out, vec![1, 2, 7]);
    }

    #[test]
    fn count_only_counts() {
        let mut c = CountOnly::new(2);
        c.emit(&[1, 2, 3], 0);
        c.emit(&[9], 2);
        assert_eq!(c.count(), 4);
        assert_eq!(c.tau(), 2);
    }

    #[test]
    fn topk_keeps_k_smallest_by_dist_then_id() {
        let mut c = TopK::new(3, 10);
        c.emit(&[5], 4);
        c.emit(&[1], 2);
        c.emit(&[9], 2);
        assert_eq!(c.tau(), 4, "heap full: tau = current worst distance");
        c.emit(&[3], 1); // evicts (4, 5)
        c.emit(&[8], 9); // above tau, ignored
        let got = c.finish();
        assert_eq!(got, vec![(3, 1), (1, 2), (9, 2)]);
    }

    #[test]
    fn topk_tie_break_is_smallest_id() {
        let mut c = TopK::new(2, 5);
        c.emit(&[30, 10, 20], 1);
        assert_eq!(c.finish(), vec![(10, 1), (20, 1)]);
    }

    #[test]
    fn topk_partial_fill_keeps_initial_tau() {
        let mut c = TopK::new(4, 6);
        c.emit(&[1], 5);
        assert_eq!(c.tau(), 6, "heap not full: initial tau still active");
        assert_eq!(c.finish(), vec![(1, 5)]);
    }

    #[test]
    fn topk_zero_k_is_empty() {
        let mut c = TopK::new(0, 3);
        c.emit(&[1], 0);
        assert_eq!(c.tau(), 0);
        assert!(c.finish().is_empty());
    }

    #[test]
    fn stats_observer_counts_and_delegates() {
        let mut out = Vec::new();
        let mut obs = StatsObserver::new(CollectIds::new(2, &mut out));
        obs.on_visit();
        obs.on_visit();
        obs.on_prune();
        obs.emit(&[4, 5], 1);
        let stats = obs.stats;
        assert_eq!((stats.visited, stats.pruned, stats.emitted), (2, 1, 2));
        assert_eq!(out, vec![4, 5]);
    }

    #[test]
    fn batched_hooks_equal_n_single_hooks() {
        // range kernels account whole blocks; totals must match n
        // individual hook calls both for observers and for defaults.
        let mut obs = StatsObserver::new(CountOnly::new(1));
        obs.on_visit_many(5);
        obs.on_prune_many(3);
        assert_eq!((obs.stats.visited, obs.stats.pruned), (5, 3));
        let dyn_obs: &mut dyn Collector = &mut obs;
        dyn_obs.on_visit_many(2);
        assert_eq!(obs.stats.visited, 7);
    }

    #[test]
    fn ctx_kid_buffer_is_sized_from_sigma() {
        let mut ctx = QueryCtx::new();
        ctx.ensure_kids(1 << 8, 4);
        assert!(ctx.kids_capacity() >= 256 * 4);
        // shrinking requests never shrink the buffer
        ctx.ensure_kids(1 << 2, 2);
        assert!(ctx.kids_capacity() >= 256 * 4);
    }
}
