//! Reusable per-query scratch.

/// All scratch a single query needs, owned by the caller so repeated
/// queries reuse the same buffers (shard workers keep one per thread).
///
/// * `q_planes` — the query suffix packed into vertical bit-planes
///   (filled by `SparseLayer::pack_query_into` / `VerticalSet::pack_query_into`).
/// * `kids` — the middle-layer fan-out buffer. Traversals store each
///   level's children in the level's own stride-`sigma` segment, so the
///   buffer is shared across the whole recursion without aliasing: a
///   frame at depth `d` only writes `[d * sigma, (d + 1) * sigma)`.
///
/// Buffers only ever grow; after the first query at a given shape every
/// later query runs allocation-free (see `tests/query_alloc.rs`).
#[derive(Debug, Default)]
pub struct QueryCtx {
    /// Packed query bit-planes (`b` words).
    pub(crate) q_planes: Vec<u64>,
    /// Packed block-query planes for blocked execution (`m · b` words;
    /// query `j`'s planes live at `[j·b, (j+1)·b)`).
    pub(crate) block_q: Vec<u64>,
    /// Flat child buffer: `levels` segments of `kid_stride` slots each.
    pub(crate) kids: Vec<(u32, u8)>,
    /// Current segment width (`1 << b` of the structure being queried).
    kid_stride: usize,
    /// Parked top-k heap, recycled across nearest-neighbor queries (the
    /// `TopK` collector borrows it via take/put because the collector and
    /// the ctx are both live during a traversal).
    topk_heap: std::collections::BinaryHeap<(usize, u32)>,
}

impl QueryCtx {
    pub fn new() -> Self {
        QueryCtx {
            q_planes: Vec::new(),
            block_q: Vec::new(),
            kids: Vec::new(),
            kid_stride: 0,
            topk_heap: std::collections::BinaryHeap::new(),
        }
    }

    /// Takes the parked top-k heap (empty or warm). Pair with
    /// [`QueryCtx::put_topk_heap`] after the query so the capacity is
    /// reused — see `SearchIndex::top_k_into`.
    pub fn take_topk_heap(&mut self) -> std::collections::BinaryHeap<(usize, u32)> {
        std::mem::take(&mut self.topk_heap)
    }

    /// Parks a heap (typically recovered via `TopK::into_heap`) for the
    /// next top-k query.
    pub fn put_topk_heap(&mut self, heap: std::collections::BinaryHeap<(usize, u32)>) {
        self.topk_heap = heap;
    }

    /// Ensures the child buffer holds `levels` segments of `sigma` slots.
    /// `sigma` must be `1 << b` with `b <= 8` (labels are `u8`).
    pub(crate) fn ensure_kids(&mut self, sigma: usize, levels: usize) {
        debug_assert!(sigma <= 256, "alphabet wider than u8 labels: {sigma}");
        self.kid_stride = sigma;
        let need = sigma.saturating_mul(levels);
        if self.kids.len() < need {
            self.kids.resize(need, (0, 0));
        }
    }

    /// Start of depth `d`'s segment in [`Self::kids`].
    #[inline]
    pub(crate) fn kid_off(&self, depth: usize) -> usize {
        depth * self.kid_stride
    }

    /// Current size of the child buffer (diagnostics / tests).
    pub fn kids_capacity(&self) -> usize {
        self.kids.len()
    }

    /// Heap bytes currently held by the scratch buffers.
    pub fn heap_bytes(&self) -> usize {
        self.q_planes.capacity() * std::mem::size_of::<u64>()
            + self.block_q.capacity() * std::mem::size_of::<u64>()
            + self.kids.capacity() * std::mem::size_of::<(u32, u8)>()
            + self.topk_heap.capacity() * std::mem::size_of::<(usize, u32)>()
    }
}
