//! Block execution support: the [`BlockCollector`] fan-out.
//!
//! Blocked traversals (trie descent, range scans, candidate
//! verification) process a whole *query block* — up to
//! [`MAX_BLOCK`] compatible queries — in one pass over the data. Each
//! query still owns its own consumption policy (a plain
//! [`Collector`]); the `BlockCollector` holds one mutable slot per
//! query and routes every per-query event (`tau` reads, `emit`,
//! visit/prune accounting) to exactly the collector it belongs to, so
//! blocked execution stays **byte-identical** to one-at-a-time
//! execution in both results and [`super::TraversalStats`].
//!
//! Besides routing, the block collector tracks per-query *work*
//! (nodes/items visited): the batcher attributes a block's wall time to
//! its member queries by share of work, keeping per-query latency
//! accounting real (documented in `coordinator/protocol.rs`).

use super::Collector;
pub use crate::sketch::plane_store::{live_mask, MAX_BLOCK};

/// Per-query fan-out for blocked traversals: slot `j` is query `j`'s
/// own collector. All hooks take an explicit query index; the
/// traversal decides *which* queries see an event, the block collector
/// guarantees only those queries' collectors observe it.
pub struct BlockCollector<'a, 'b> {
    slots: &'a mut [&'b mut dyn Collector],
    /// Per-query visited-node counters (wall-time attribution weights).
    work: [u64; MAX_BLOCK],
}

impl<'a, 'b> BlockCollector<'a, 'b> {
    /// Wraps one collector per query. `slots.len()` is the block width
    /// `m` (`<= MAX_BLOCK`).
    pub fn new(slots: &'a mut [&'b mut dyn Collector]) -> Self {
        assert!(
            slots.len() <= MAX_BLOCK,
            "query block wider than MAX_BLOCK: {}",
            slots.len()
        );
        BlockCollector { slots, work: [0; MAX_BLOCK] }
    }

    /// Number of queries in the block.
    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Query `j`'s live threshold (may shrink between reads — top-k).
    #[inline]
    pub fn tau(&self, j: usize) -> usize {
        self.slots[j].tau()
    }

    /// Emits a candidate group at exact distance `dist` to query `j`.
    #[inline]
    pub fn emit(&mut self, j: usize, ids: &[u32], dist: usize) {
        self.slots[j].emit(ids, dist);
    }

    /// Query `j` entered a node / compared a candidate.
    #[inline]
    pub fn on_visit(&mut self, j: usize) {
        self.work[j] += 1;
        self.slots[j].on_visit();
    }

    /// Query `j` cut a child/candidate on its distance budget.
    #[inline]
    pub fn on_prune(&mut self, j: usize) {
        self.slots[j].on_prune();
    }

    /// Batched visit accounting for query `j` (range kernels).
    #[inline]
    pub fn on_visit_many(&mut self, j: usize, n: usize) {
        self.work[j] += n as u64;
        self.slots[j].on_visit_many(n);
    }

    /// Batched prune accounting for query `j`.
    #[inline]
    pub fn on_prune_many(&mut self, j: usize, n: usize) {
        self.slots[j].on_prune_many(n);
    }

    /// Work done on behalf of query `j` so far (visited count). The
    /// batcher splits block wall time proportionally to these weights.
    #[inline]
    pub fn work(&self, j: usize) -> u64 {
        self.work[j]
    }
}

/// Adapter exposing one slot of a [`BlockCollector`] as a plain
/// [`Collector`]. The serial fallbacks of `run_block` (indexes without
/// a native blocked path) drive each member query through the ordinary
/// single-query traversal wearing this adapter, so per-query stats and
/// work accounting still flow through the block collector.
pub struct SlotRef<'c, 'a, 'b> {
    bc: &'c mut BlockCollector<'a, 'b>,
    j: usize,
}

impl<'c, 'a, 'b> SlotRef<'c, 'a, 'b> {
    pub fn new(bc: &'c mut BlockCollector<'a, 'b>, j: usize) -> Self {
        debug_assert!(j < bc.len());
        SlotRef { bc, j }
    }
}

impl Collector for SlotRef<'_, '_, '_> {
    #[inline]
    fn tau(&self) -> usize {
        self.bc.tau(self.j)
    }

    #[inline]
    fn emit(&mut self, ids: &[u32], dist: usize) {
        self.bc.emit(self.j, ids, dist);
    }

    #[inline]
    fn on_visit(&mut self) {
        self.bc.on_visit(self.j);
    }

    #[inline]
    fn on_prune(&mut self) {
        self.bc.on_prune(self.j);
    }

    #[inline]
    fn on_visit_many(&mut self, n: usize) {
        self.bc.on_visit_many(self.j, n);
    }

    #[inline]
    fn on_prune_many(&mut self, n: usize) {
        self.bc.on_prune_many(self.j, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::{CollectIds, CountOnly, StatsObserver};

    #[test]
    fn block_collector_routes_per_query() {
        let mut out0 = Vec::new();
        let mut c0 = StatsObserver::new(CollectIds::new(2, &mut out0));
        let mut c1 = StatsObserver::new(CountOnly::new(5));
        {
            let mut slots: [&mut dyn Collector; 2] = [&mut c0, &mut c1];
            let mut bc = BlockCollector::new(&mut slots);
            assert_eq!(bc.len(), 2);
            assert_eq!((bc.tau(0), bc.tau(1)), (2, 5));
            bc.on_visit(0);
            bc.on_visit_many(1, 3);
            bc.on_prune(1);
            bc.on_prune_many(0, 2);
            bc.emit(0, &[7, 8], 1);
            bc.emit(1, &[9], 4);
            assert_eq!((bc.work(0), bc.work(1)), (1, 3));
        }
        assert_eq!(out0, vec![7, 8]);
        assert_eq!(
            (c0.stats.visited, c0.stats.pruned, c0.stats.emitted),
            (1, 2, 2)
        );
        assert_eq!(c1.inner.count(), 1);
        assert_eq!(
            (c1.stats.visited, c1.stats.pruned, c1.stats.emitted),
            (3, 1, 1)
        );
    }

    #[test]
    fn slot_ref_is_a_transparent_collector() {
        let mut out = Vec::new();
        let mut c0 = StatsObserver::new(CollectIds::new(3, &mut out));
        let mut c1 = CountOnly::new(1);
        {
            let mut slots: [&mut dyn Collector; 2] = [&mut c0, &mut c1];
            let mut bc = BlockCollector::new(&mut slots);
            let mut s = SlotRef::new(&mut bc, 0);
            assert_eq!(s.tau(), 3);
            s.on_visit();
            s.on_visit_many(4);
            s.on_prune();
            s.on_prune_many(2);
            s.emit(&[1], 0);
            assert_eq!(bc.work(0), 5);
            assert_eq!(bc.work(1), 0);
        }
        assert_eq!(out, vec![1]);
        assert_eq!(
            (c0.stats.visited, c0.stats.pruned, c0.stats.emitted),
            (5, 3, 1)
        );
        assert_eq!(c1.count(), 0);
    }

    #[test]
    fn live_mask_clamps_at_64() {
        assert_eq!(live_mask(0), 0);
        assert_eq!(live_mask(3), 0b111);
        assert_eq!(live_mask(64), u64::MAX);
        assert_eq!(live_mask(200), u64::MAX);
    }
}
