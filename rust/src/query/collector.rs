//! The [`Collector`] trait and its four standard implementations.

use std::collections::BinaryHeap;

/// Node-visit accounting of one traversal: how many nodes the search
/// entered, how many children it cut on the distance budget, and how many
/// ids it reported. Filled by [`StatsObserver`]; the plain collectors
/// compile the hooks away so the hot path stays clean.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraversalStats {
    /// Nodes entered (trie nodes + sparse-layer leaves compared).
    pub visited: usize,
    /// Children / candidates cut by the distance budget.
    pub pruned: usize,
    /// Ids emitted as solutions.
    pub emitted: usize,
}

/// Consumption policy of a similarity search.
///
/// The traversal reads the *live* threshold via [`Collector::tau`] (it may
/// shrink during the query — that is how [`TopK`] adapts) and reports every
/// surviving candidate group through [`Collector::emit`] together with its
/// **exact** Hamming distance. `on_visit` / `on_prune` are observation
/// hooks with empty default bodies.
pub trait Collector {
    /// Current distance threshold; subtrees with running distance above
    /// this may be pruned. Never increases during a query.
    fn tau(&self) -> usize;

    /// Reports candidate ids at exact distance `dist` (`dist <= tau()` at
    /// call time). Groups share one distance (e.g. a leaf posting list).
    fn emit(&mut self, ids: &[u32], dist: usize);

    /// A node (or collapsed leaf) was entered.
    #[inline]
    fn on_visit(&mut self) {}

    /// A child/candidate was cut by the distance budget.
    #[inline]
    fn on_prune(&mut self) {}

    /// Batched form of [`Collector::on_visit`]: `n` nodes/candidates
    /// entered at once. Range kernels account a whole scanned block with
    /// one call instead of `n` per-item hook invocations; the default
    /// expands to `n` single visits so observers that only override
    /// `on_visit` stay exact.
    #[inline]
    fn on_visit_many(&mut self, n: usize) {
        for _ in 0..n {
            self.on_visit();
        }
    }

    /// Batched form of [`Collector::on_prune`] (see
    /// [`Collector::on_visit_many`]).
    #[inline]
    fn on_prune_many(&mut self, n: usize) {
        for _ in 0..n {
            self.on_prune();
        }
    }
}

/// Forwarding impl so monomorphized traversals accept `&mut dyn Collector`
/// (the object-safe form the index layer uses).
impl<C: Collector + ?Sized> Collector for &mut C {
    #[inline]
    fn tau(&self) -> usize {
        (**self).tau()
    }

    #[inline]
    fn emit(&mut self, ids: &[u32], dist: usize) {
        (**self).emit(ids, dist)
    }

    #[inline]
    fn on_visit(&mut self) {
        (**self).on_visit()
    }

    #[inline]
    fn on_prune(&mut self) {
        (**self).on_prune()
    }

    #[inline]
    fn on_visit_many(&mut self, n: usize) {
        (**self).on_visit_many(n)
    }

    #[inline]
    fn on_prune_many(&mut self, n: usize) {
        (**self).on_prune_many(n)
    }
}

/// Today's semantics: append every matching id to a caller-owned buffer.
pub struct CollectIds<'a> {
    tau: usize,
    out: &'a mut Vec<u32>,
}

impl<'a> CollectIds<'a> {
    pub fn new(tau: usize, out: &'a mut Vec<u32>) -> Self {
        CollectIds { tau, out }
    }
}

impl Collector for CollectIds<'_> {
    #[inline]
    fn tau(&self) -> usize {
        self.tau
    }

    #[inline]
    fn emit(&mut self, ids: &[u32], _dist: usize) {
        self.out.extend_from_slice(ids);
    }
}

/// Counts solutions without materializing them.
#[derive(Debug, Clone, Copy)]
pub struct CountOnly {
    tau: usize,
    count: usize,
}

impl CountOnly {
    pub fn new(tau: usize) -> Self {
        CountOnly { tau, count: 0 }
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

impl Collector for CountOnly {
    #[inline]
    fn tau(&self) -> usize {
        self.tau
    }

    #[inline]
    fn emit(&mut self, ids: &[u32], _dist: usize) {
        self.count += ids.len();
    }
}

/// Bounded nearest-neighbor collector: keeps the `k` candidates with the
/// smallest `(dist, id)` pairs (ties broken toward smaller ids, making the
/// result deterministic and exactly comparable to a sorted brute-force
/// scan). Once the heap is full, [`Collector::tau`] drops to the current
/// worst kept distance, so the traversal prunes adaptively.
pub struct TopK {
    k: usize,
    tau0: usize,
    /// Max-heap over `(dist, id)`; `peek()` is the current worst kept pair.
    heap: BinaryHeap<(usize, u32)>,
}

impl TopK {
    /// `tau` is the initial search radius (use the sketch length `L` for an
    /// unbounded nearest-neighbor query). The heap grows with actual
    /// results, so the pre-allocation is capped — a huge untrusted `k`
    /// (e.g. from a wire request) must not translate into a huge
    /// allocation up front.
    pub fn new(k: usize, tau: usize) -> Self {
        TopK { k, tau0: tau, heap: BinaryHeap::with_capacity(k.min(1024) + 1) }
    }

    /// Like [`TopK::new`] but recycling a heap (typically parked in
    /// [`super::QueryCtx`] between queries), so repeated top-k queries
    /// are allocation-free after warm-up. The heap is cleared; its
    /// capacity is kept.
    pub fn with_heap(k: usize, tau: usize, mut heap: BinaryHeap<(usize, u32)>) -> Self {
        heap.clear();
        TopK { k, tau0: tau, heap }
    }

    /// Results sorted by `(dist, id)`, as `(id, dist)` pairs.
    pub fn finish(mut self) -> Vec<(u32, usize)> {
        let mut out = Vec::new();
        self.drain_into(&mut out);
        out
    }

    /// Drains the results into `out` (cleared first), sorted by
    /// `(dist, id)`, leaving the heap empty but with its capacity intact
    /// — recover it with [`TopK::into_heap`] for reuse.
    pub fn drain_into(&mut self, out: &mut Vec<(u32, usize)>) {
        out.clear();
        out.reserve(self.heap.len());
        // max-heap pops worst-first; reverse for ascending (dist, id).
        while let Some((d, id)) = self.heap.pop() {
            out.push((id, d));
        }
        out.reverse();
    }

    /// Recovers the backing heap for reuse across queries.
    pub fn into_heap(self) -> BinaryHeap<(usize, u32)> {
        self.heap
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl Collector for TopK {
    #[inline]
    fn tau(&self) -> usize {
        if self.k == 0 {
            return 0;
        }
        if self.heap.len() == self.k {
            self.heap.peek().map_or(self.tau0, |&(d, _)| d)
        } else {
            self.tau0
        }
    }

    fn emit(&mut self, ids: &[u32], dist: usize) {
        if self.k == 0 || dist > self.tau0 {
            return;
        }
        for &id in ids {
            if self.heap.len() < self.k {
                self.heap.push((dist, id));
            } else if let Some(&worst) = self.heap.peek() {
                if (dist, id) < worst {
                    self.heap.push((dist, id));
                    self.heap.pop();
                }
            }
        }
    }
}

/// Wraps any collector and fills [`TraversalStats`] from the observation
/// hooks — the eval harness's way to measure pruning without a second
/// code path in the tries.
pub struct StatsObserver<C> {
    pub inner: C,
    pub stats: TraversalStats,
}

impl<C: Collector> StatsObserver<C> {
    pub fn new(inner: C) -> Self {
        StatsObserver { inner, stats: TraversalStats::default() }
    }
}

impl<C: Collector> Collector for StatsObserver<C> {
    #[inline]
    fn tau(&self) -> usize {
        self.inner.tau()
    }

    #[inline]
    fn emit(&mut self, ids: &[u32], dist: usize) {
        self.stats.emitted += ids.len();
        self.inner.emit(ids, dist);
    }

    #[inline]
    fn on_visit(&mut self) {
        self.stats.visited += 1;
    }

    #[inline]
    fn on_prune(&mut self) {
        self.stats.pruned += 1;
    }

    #[inline]
    fn on_visit_many(&mut self, n: usize) {
        self.stats.visited += n;
    }

    #[inline]
    fn on_prune_many(&mut self, n: usize) {
        self.stats.pruned += n;
    }
}
