//! Trie representations for b-bit sketch databases.
//!
//! * [`builder`] — shared construction machinery: sorts the sketches,
//!   deduplicates, computes the LCP array, and exposes level-wise node
//!   spans. Every trie below is built from the same `SortedSketches`,
//!   so they index identical topologies.
//! * [`bst`] — the paper's **b-bit Sketch Trie** (§V): dense / middle
//!   (TABLE ∣ LIST) / sparse three-layer succinct representation.
//! * [`pointer`] — classic pointer trie (PT, §IV): the fast-but-fat
//!   baseline and the correctness oracle for the succinct variants.
//! * [`louds`] — monolithic LOUDS-trie (Jacobson; TX-library style), the
//!   first succinct baseline of Table III.
//! * [`fst`] — two-layer Fast Succinct Trie (SuRF-style), the second
//!   succinct baseline of Table III.
//!
//! All tries implement [`SketchTrie`]: Hamming-threshold traversal
//! (Algorithm 1 of the paper) plus space accounting.

pub mod bst;
pub mod builder;
pub mod fst;
pub mod louds;
pub mod pointer;

pub use builder::SortedSketches;

/// Common interface: a trie over a fixed sketch database supporting the
/// paper's similarity search (report ids of all sketches within `tau`).
pub trait SketchTrie {
    /// Appends all ids `i` with `ham(s_i, q) <= tau` to `out`
    /// (ids appear in lexicographic sketch order, not sorted by id).
    fn search_into(&self, q: &[u8], tau: usize, out: &mut Vec<u32>);

    /// Convenience wrapper allocating the result vector.
    fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.search_into(q, tau, &mut out);
        out
    }

    /// Heap bytes owned by the structure (paper space tables).
    fn heap_bytes(&self) -> usize;

    /// Number of trie nodes (`t` in the paper), excluding any super-root.
    fn node_count(&self) -> usize;

    /// Human-readable representation summary for reports.
    fn describe(&self) -> String;
}

/// Count of nodes traversed during the last search — tries expose this via
/// interior counters only in debug/eval builds to keep the hot path clean;
/// instead the eval harness re-runs with this observer variant when node
/// statistics are wanted.
pub struct TraversalStats {
    pub visited: usize,
    pub pruned: usize,
    pub emitted: usize,
}
