//! Trie representations for b-bit sketch databases.
//!
//! * [`builder`] — shared construction machinery: sorts the sketches,
//!   deduplicates, computes the LCP array, and exposes level-wise node
//!   spans. Every trie below is built from the same `SortedSketches`,
//!   so they index identical topologies.
//! * [`bst`] — the paper's **b-bit Sketch Trie** (§V): dense / middle
//!   (TABLE ∣ LIST) / sparse three-layer succinct representation.
//! * [`pointer`] — classic pointer trie (PT, §IV): the fast-but-fat
//!   baseline and the correctness oracle for the succinct variants.
//! * [`louds`] — monolithic LOUDS-trie (Jacobson; TX-library style), the
//!   first succinct baseline of Table III.
//! * [`fst`] — two-layer Fast Succinct Trie (SuRF-style), the second
//!   succinct baseline of Table III.
//!
//! All tries implement [`SketchTrie`], whose primary entry point is the
//! collector-generic [`SketchTrie::run`]: Algorithm 1's pruned traversal,
//! parameterized over a [`Collector`] (ids / count / top-k / stats — see
//! [`crate::query`]) and fed by a caller-owned [`QueryCtx`] holding all
//! per-query scratch. `run` is monomorphized per collector, so the
//! classic id-collecting search compiles to the same tight loop as
//! before, while top-k and counting traversals share every line of the
//! pruning logic. [`SketchTrie::search_into`] / [`SketchTrie::search`]
//! remain as thin compatibility wrappers.

pub mod bst;
pub mod builder;
pub mod fst;
pub mod louds;
pub mod pointer;

pub use builder::SortedSketches;

pub use crate::query::{BlockCollector, Collector, QueryCtx, TraversalStats};

use crate::store::{ensure, StoreError};

/// Snapshot validation shared by every trie: the leaf postings must be a
/// strictly increasing offset table over `post_ids` with one range per
/// leaf (every distinct sketch owns at least one id).
///
/// Returns the largest posting id (`None` for an empty table): loaders
/// bound ids against the database they serve, and this pass already
/// walks the table — computing the maximum here removes the separate
/// O(n) `max_posting` scan the bST loader used to run.
pub(crate) fn validate_postings(
    post_offsets: &[u32],
    post_ids: &[u32],
    n_leaves: usize,
) -> Result<Option<u32>, StoreError> {
    ensure(post_offsets.len() == n_leaves + 1, || {
        format!(
            "postings: {} offsets for {n_leaves} leaves",
            post_offsets.len()
        )
    })?;
    ensure(
        post_offsets.first() == Some(&0)
            && post_offsets.windows(2).all(|w| w[0] < w[1])
            && *post_offsets.last().unwrap() as usize == post_ids.len(),
        || "postings: offsets not strictly increasing from 0 to #ids".to_string(),
    )?;
    Ok(post_ids.iter().copied().max())
}

/// Common interface: a trie over a fixed sketch database supporting the
/// paper's similarity search (all ids with `ham(s_i, q) <= tau`, where
/// `tau` — possibly adaptive — lives in the collector).
pub trait SketchTrie {
    /// Collector-generic traversal: prunes on the collector's live
    /// threshold and emits every surviving posting group with its exact
    /// distance. `ctx` supplies reusable scratch; passing the same ctx
    /// across queries makes the traversal allocation-free after warm-up.
    fn run<C: Collector>(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut C)
    where
        Self: Sized;

    /// Blocked traversal: runs a whole query block (slot `j` of `bc` is
    /// query `j`'s collector) through the structure. Results and
    /// per-query stats are identical to `run` per member query; tries
    /// with a native blocked path descend once for the whole block. The
    /// default falls back to one serial traversal per query, routed
    /// through the block collector so work accounting stays uniform.
    fn run_block(&self, qs: &[&[u8]], ctx: &mut QueryCtx, bc: &mut BlockCollector)
    where
        Self: Sized,
    {
        assert_eq!(qs.len(), bc.len(), "query block / collector slot mismatch");
        for (j, q) in qs.iter().enumerate() {
            let mut slot = crate::query::SlotRef::new(bc, j);
            self.run(q, ctx, &mut slot);
        }
    }

    /// Appends all ids `i` with `ham(s_i, q) <= tau` to `out`
    /// (ids appear in lexicographic sketch order, not sorted by id).
    fn search_into(&self, q: &[u8], tau: usize, out: &mut Vec<u32>)
    where
        Self: Sized,
    {
        let mut ctx = QueryCtx::new();
        let mut coll = crate::query::CollectIds::new(tau, out);
        self.run(q, &mut ctx, &mut coll);
    }

    /// Convenience wrapper allocating the result vector.
    fn search(&self, q: &[u8], tau: usize) -> Vec<u32>
    where
        Self: Sized,
    {
        let mut out = Vec::new();
        self.search_into(q, tau, &mut out);
        out
    }

    /// Heap bytes owned by the structure (paper space tables).
    fn heap_bytes(&self) -> usize;

    /// Number of trie nodes (`t` in the paper), excluding any super-root.
    fn node_count(&self) -> usize;

    /// Human-readable representation summary for reports.
    fn describe(&self) -> String;
}
