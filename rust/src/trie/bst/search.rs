//! Similarity search over bST (Algorithm 1 of the paper).
//!
//! Depth-first traversal carrying the running Hamming distance `dist`
//! between the query prefix and each node's prefix:
//!
//! * **dense layer** — children are arithmetic; when the distance budget
//!   is exhausted (`dist == τ`) only the query-matching child is taken,
//!   which collapses the complete-trie fan-out to a single path;
//! * **middle layer** — `children()` via TABLE/LIST; same budget shortcut
//!   through `child_with_label`;
//! * **sparse layer** — every leaf suffix under the node is compared with
//!   the bit-parallel vertical Hamming kernel against the remaining
//!   budget `τ - dist`.

use super::dense::child0;
use super::BstTrie;

struct Searcher<'a> {
    t: &'a BstTrie,
    q: &'a [u8],
    tau: usize,
    q_planes: Vec<u64>,
    out: &'a mut Vec<u32>,
}

/// Entry point called by [`BstTrie::search_into`].
pub fn search(t: &BstTrie, q: &[u8], tau: usize, out: &mut Vec<u32>) {
    let q_planes = t.sparse.pack_query(&q[t.ls..]);
    let mut s = Searcher { t, q, tau, q_planes, out };
    s.descend(0, 0, 0);
}

impl<'a> Searcher<'a> {
    fn descend(&mut self, level: usize, u: usize, dist: usize) {
        if level == self.t.ls {
            self.scan_sparse(u, dist);
            return;
        }
        let qc = self.q[level];
        if level < self.t.lm {
            // Dense layer: implicit complete 2^b-ary node.
            let base = child0(u, self.t.b);
            if dist == self.tau {
                self.descend(level + 1, base + qc as usize, dist);
            } else {
                let sigma = 1usize << self.t.b;
                for c in 0..sigma {
                    self.descend(level + 1, base + c, dist + usize::from(c != qc as usize));
                }
            }
        } else {
            let ml = &self.t.middle[level - self.t.lm];
            if dist == self.tau {
                if let Some(child) = ml.child_with_label(u, qc) {
                    self.descend(level + 1, child, dist);
                }
            } else {
                // Collect children first to keep the closure borrow local.
                let mut kids: [(u32, u8); 256] = [(0, 0); 256];
                let mut n_kids = 0usize;
                ml.children(u, |child, c| {
                    kids[n_kids] = (child as u32, c);
                    n_kids += 1;
                });
                for &(child, c) in &kids[..n_kids] {
                    let nd = dist + usize::from(c != qc);
                    if nd <= self.tau {
                        self.descend(level + 1, child as usize, nd);
                    }
                }
            }
        }
    }

    #[inline]
    fn scan_sparse(&mut self, u: usize, dist: usize) {
        let budget = self.tau - dist;
        let (lo, hi) = self.t.sparse.leaf_range(u);
        for v in lo..hi {
            if self.t.sparse.ham_suffix(v, &self.q_planes) <= budget {
                self.out.extend_from_slice(self.t.postings_of(v));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchSet;
    use crate::trie::builder::SortedSketches;
    use crate::trie::bst::BstConfig;
    use crate::trie::SketchTrie;

    #[test]
    fn paper_figure1_example() {
        // Figure 1: eleven 2-bit sketches over {a,b,c,d} = {0,1,2,3},
        // query aaaaa, tau = 1 → ids of sketches within distance 1.
        let names = [
            "baabb", "aaaaa", "baaaa", "caaca", "caaca", "aaaaa", "caaca",
            "ddccc", "abaab", "bcbcb", "ddddd",
        ];
        let rows: Vec<Vec<u8>> = names
            .iter()
            .map(|s| s.bytes().map(|c| c - b'a').collect())
            .collect();
        let set = SketchSet::from_rows(2, 5, &rows);
        let ss = SortedSketches::build(&set);
        let bst = super::super::BstTrie::build(&ss, BstConfig::default());
        let q: Vec<u8> = "aaaaa".bytes().map(|c| c - b'a').collect();
        let mut got = bst.search(&q, 1);
        got.sort();
        // ham=0: ids 1,5 ("aaaaa"); ham=1: id 2 ("baaaa").
        assert_eq!(got, vec![1, 2, 5]);
        // tau = 2 additionally admits caaca (ids 3,4,6) and abaab (id 8).
        let mut got2 = bst.search(&q, 2);
        got2.sort();
        assert_eq!(got2, vec![1, 2, 3, 4, 5, 6, 8]);
    }

    #[test]
    fn budget_shortcut_equals_full_enumeration() {
        // tau = 0 must return exactly the duplicate group.
        let rows = vec![
            vec![0u8, 1, 2, 3],
            vec![0, 1, 2, 3],
            vec![0, 1, 2, 2],
            vec![3, 1, 2, 3],
        ];
        let set = SketchSet::from_rows(2, 4, &rows);
        let ss = SortedSketches::build(&set);
        let bst = super::super::BstTrie::build(&ss, BstConfig::default());
        let mut got = bst.search(&[0, 1, 2, 3], 0);
        got.sort();
        assert_eq!(got, vec![0, 1]);
    }
}
