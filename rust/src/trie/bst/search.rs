//! Similarity search over bST (Algorithm 1 of the paper), generic over
//! the consuming [`Collector`].
//!
//! Depth-first traversal carrying the running Hamming distance `dist`
//! between the query prefix and each node's prefix:
//!
//! * **dense layer** — children are arithmetic; when the distance budget
//!   is exhausted (`dist == τ`) only the query-matching child is taken,
//!   which collapses the complete-trie fan-out to a single path;
//! * **middle layer** — `children()` via TABLE/LIST; same budget shortcut
//!   through `child_with_label`; the fan-out buffer lives in the caller's
//!   [`QueryCtx`] (one stride-`2^b` segment per middle level), not on the
//!   stack of every frame;
//! * **sparse layer** — every leaf suffix under the node is compared with
//!   the bit-parallel vertical Hamming kernel against the remaining
//!   budget `τ - dist`.
//!
//! The threshold is re-read from the collector (`c.tau()`) instead of
//! being a constant: [`crate::query::TopK`] shrinks it as its heap fills,
//! so the same traversal serves threshold and nearest-neighbor queries.

use super::dense::child0;
use super::BstTrie;
use crate::query::{live_mask, BlockCollector, Collector, QueryCtx, MAX_BLOCK};

struct Searcher<'a, C: Collector> {
    t: &'a BstTrie,
    q: &'a [u8],
    ctx: &'a mut QueryCtx,
    c: &'a mut C,
}

/// Entry point called by [`BstTrie`]'s `SketchTrie::run`.
pub fn run<C: Collector>(t: &BstTrie, q: &[u8], ctx: &mut QueryCtx, c: &mut C) {
    ctx.ensure_kids(1usize << t.b, t.middle.len());
    t.sparse.pack_query_into(&q[t.ls..], &mut ctx.q_planes);
    let mut s = Searcher { t, q, ctx, c };
    s.descend(0, 0, 0);
}

/// Blocked entry point (`SketchTrie::run_block`): one DFS serves the
/// whole query block. A node is descended if *any* live query admits it;
/// every per-query event (visit / prune / emit, and the live `tau`
/// re-reads driving the pruning decisions) is routed through the
/// [`BlockCollector`], so each member query observes exactly the event
/// sequence its own serial traversal would produce — query `j`'s
/// decisions depend only on `j`'s own collector state, and children are
/// enumerated in the same order as in [`run`].
pub fn run_block(t: &BstTrie, qs: &[&[u8]], ctx: &mut QueryCtx, bc: &mut BlockCollector) {
    let m = bc.len();
    assert_eq!(qs.len(), m, "query block / collector slot mismatch");
    assert!(m <= MAX_BLOCK);
    for q in qs {
        assert_eq!(q.len(), t.l);
    }
    ctx.ensure_kids(1usize << t.b, t.middle.len());
    ctx.block_q.clear();
    for q in qs {
        t.sparse.pack_query_append(&q[t.ls..], &mut ctx.block_q);
    }
    let mut s = BlockSearcher { t, qs, ctx, bc };
    let dists = [0usize; MAX_BLOCK];
    s.descend(0, 0, &dists, live_mask(m));
}

struct BlockSearcher<'a, 'c, 'd> {
    t: &'a BstTrie,
    qs: &'a [&'a [u8]],
    ctx: &'a mut QueryCtx,
    bc: &'a mut BlockCollector<'c, 'd>,
}

impl BlockSearcher<'_, '_, '_> {
    fn descend(&mut self, level: usize, u: usize, dists: &[usize; MAX_BLOCK], live_in: u64) {
        // Node-entry accounting, exactly as each serial traversal would
        // do on its own: a live query whose running distance exceeds its
        // (possibly tightened) threshold prunes here; the rest visit.
        let mut live = 0u64;
        let mut taus = [0usize; MAX_BLOCK];
        let mut rem = live_in;
        while rem != 0 {
            let j = rem.trailing_zeros() as usize;
            rem &= rem - 1;
            let tj = self.bc.tau(j);
            if dists[j] > tj {
                self.bc.on_prune(j);
            } else {
                self.bc.on_visit(j);
                taus[j] = tj;
                live |= 1 << j;
            }
        }
        if live == 0 {
            return;
        }
        let t = self.t;
        if level == t.ls {
            self.scan_sparse(u, dists, live);
            return;
        }
        if level < t.lm {
            // Dense layer: implicit complete 2^b-ary node. Serial descends
            // every child when the budget allows (the child's own entry
            // check records prunes), and only the query-matching child
            // when `dist == tau` — per query, the same children are taken
            // here.
            let base = child0(u, t.b);
            let sigma = 1usize << t.b;
            for ch in 0..sigma {
                let mut nd = [0usize; MAX_BLOCK];
                let mut nl = 0u64;
                let mut r = live;
                while r != 0 {
                    let j = r.trailing_zeros() as usize;
                    r &= r - 1;
                    let qc = self.qs[j][level] as usize;
                    if dists[j] == taus[j] {
                        if ch == qc {
                            nd[j] = dists[j];
                            nl |= 1 << j;
                        }
                    } else {
                        nd[j] = dists[j] + usize::from(ch != qc);
                        nl |= 1 << j;
                    }
                }
                if nl != 0 {
                    self.descend(level + 1, base + ch, &nd, nl);
                }
            }
        } else {
            // Middle layer: enumerate the children ONCE for the whole
            // block into this level's segment of the shared fan-out
            // buffer, then filter per query. Serial prunes over-budget
            // children at the parent (live tau re-read), and takes only
            // the label-matching child when the budget is exhausted — both
            // reproduced per query below.
            let ml = &t.middle[level - t.lm];
            let off = self.ctx.kid_off(level - t.lm);
            let mut n_kids = 0usize;
            {
                let kids = &mut self.ctx.kids;
                ml.children(u, |child, ch| {
                    kids[off + n_kids] = (child as u32, ch);
                    n_kids += 1;
                });
            }
            for i in 0..n_kids {
                let (child, ch) = self.ctx.kids[off + i];
                let mut nd = [0usize; MAX_BLOCK];
                let mut nl = 0u64;
                let mut r = live;
                while r != 0 {
                    let j = r.trailing_zeros() as usize;
                    r &= r - 1;
                    let qc = self.qs[j][level];
                    if dists[j] == taus[j] {
                        if ch == qc {
                            nd[j] = dists[j];
                            nl |= 1 << j;
                        }
                    } else {
                        let d = dists[j] + usize::from(ch != qc);
                        if d <= self.bc.tau(j) {
                            nd[j] = d;
                            nl |= 1 << j;
                        } else {
                            self.bc.on_prune(j);
                        }
                    }
                }
                if nl != 0 {
                    self.descend(level + 1, child as usize, &nd, nl);
                }
            }
        }
    }

    /// Blocked sparse-node scan: one multi-query kernel call verifies
    /// every live query against the node's contiguous leaves. Per-query
    /// accounting mirrors [`Searcher::scan_sparse`] exactly, including
    /// the visit-then-prune of the leaf at which a tightening top-k
    /// threshold drops below the node's running distance.
    fn scan_sparse(&mut self, u: usize, dists: &[usize; MAX_BLOCK], live: u64) {
        let t = self.t;
        let (lo, hi) = t.sparse.leaf_range(u);
        let m = self.bc.len();
        let mut budgets = [0usize; MAX_BLOCK];
        let mut rem = live;
        while rem != 0 {
            let j = rem.trailing_zeros() as usize;
            rem &= rem - 1;
            // Entry accounting guaranteed dists[j] <= tau(j), and `j` has
            // not emitted since, so this cannot underflow.
            budgets[j] = self.bc.tau(j) - dists[j];
        }
        let b0 = budgets;
        let mut vis = [0u32; MAX_BLOCK];
        let mut prn = [0u32; MAX_BLOCK];
        let bc = &mut *self.bc;
        let qs_planes = &self.ctx.block_q;
        t.sparse
            .suffix_scan_multi(lo, hi, qs_planes, &b0[..m], live, |j, v, verdict| {
                vis[j] += 1;
                match verdict {
                    Some(sd) => {
                        bc.emit(j, t.postings_of(v), dists[j] + sd);
                        match bc.tau(j).checked_sub(dists[j]) {
                            Some(nb) => {
                                budgets[j] = nb;
                                Some(nb)
                            }
                            None => {
                                // Threshold tightened below the node's
                                // running distance: serial visits and
                                // prunes the next leaf, then abandons
                                // the rest of the range.
                                if v + 1 < hi {
                                    vis[j] += 1;
                                    prn[j] += 1;
                                }
                                None
                            }
                        }
                    }
                    None => {
                        prn[j] += 1;
                        Some(budgets[j])
                    }
                }
            });
        let mut rem = live;
        while rem != 0 {
            let j = rem.trailing_zeros() as usize;
            rem &= rem - 1;
            bc.on_visit_many(j, vis[j] as usize);
            bc.on_prune_many(j, prn[j] as usize);
        }
    }
}

impl<C: Collector> Searcher<'_, C> {
    fn descend(&mut self, level: usize, u: usize, dist: usize) {
        let tau = self.c.tau();
        if dist > tau {
            // only reachable when the threshold tightened mid-traversal
            self.c.on_prune();
            return;
        }
        self.c.on_visit();
        let t = self.t;
        if level == t.ls {
            self.scan_sparse(u, dist);
            return;
        }
        let qc = self.q[level];
        if level < t.lm {
            // Dense layer: implicit complete 2^b-ary node.
            let base = child0(u, t.b);
            if dist == tau {
                self.descend(level + 1, base + qc as usize, dist);
            } else {
                let sigma = 1usize << t.b;
                for ch in 0..sigma {
                    self.descend(level + 1, base + ch, dist + usize::from(ch != qc as usize));
                }
            }
        } else {
            let ml = &t.middle[level - t.lm];
            if dist == tau {
                if let Some(child) = ml.child_with_label(u, qc) {
                    self.descend(level + 1, child, dist);
                }
            } else {
                // Stage the children in this level's segment of the shared
                // fan-out buffer (deeper frames use their own segments).
                let off = self.ctx.kid_off(level - t.lm);
                let mut n_kids = 0usize;
                {
                    let kids = &mut self.ctx.kids;
                    ml.children(u, |child, ch| {
                        kids[off + n_kids] = (child as u32, ch);
                        n_kids += 1;
                    });
                }
                for i in 0..n_kids {
                    let (child, ch) = self.ctx.kids[off + i];
                    let nd = dist + usize::from(ch != qc);
                    if nd <= self.c.tau() {
                        self.descend(level + 1, child as usize, nd);
                    } else {
                        self.c.on_prune();
                    }
                }
            }
        }
    }

    #[inline]
    fn scan_sparse(&mut self, u: usize, dist: usize) {
        let t = self.t;
        let (lo, hi) = t.sparse.leaf_range(u);
        // One streaming kernel call per sparse node: the cursor walks the
        // contiguous leaves' plane words sequentially (with the b>1
        // lower-bound early exit). Visit/prune accounting is batched at
        // the range level — one `on_visit_many` / `on_prune_many` pair
        // per scanned node instead of two virtual calls per leaf — with
        // totals identical to the per-leaf hooks this replaces.
        let c = &mut *self.c;
        let mut cur = t.sparse.suffix_scan(lo, hi, &self.ctx.q_planes);
        let mut visited = 0usize;
        let mut pruned = 0usize;
        for v in lo..hi {
            visited += 1;
            let Some(budget) = c.tau().checked_sub(dist) else {
                // threshold tightened below this node's running distance
                // mid-scan: the current leaf counts as pruned, the rest
                // of the range is abandoned unvisited (as before).
                pruned += 1;
                break;
            };
            match cur.next_leq(budget) {
                Some(sd) => c.emit(t.postings_of(v), dist + sd),
                None => pruned += 1,
            }
        }
        c.on_visit_many(visited);
        c.on_prune_many(pruned);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::{CollectIds, CountOnly, StatsObserver, TopK};
    use crate::sketch::hamming::ham_chars;
    use crate::sketch::SketchSet;
    use crate::trie::builder::SortedSketches;
    use crate::trie::bst::BstConfig;
    use crate::trie::SketchTrie;

    fn figure1() -> (super::super::BstTrie, Vec<Vec<u8>>, Vec<u8>) {
        // Figure 1: eleven 2-bit sketches over {a,b,c,d} = {0,1,2,3}.
        let names = [
            "baabb", "aaaaa", "baaaa", "caaca", "caaca", "aaaaa", "caaca",
            "ddccc", "abaab", "bcbcb", "ddddd",
        ];
        let rows: Vec<Vec<u8>> = names
            .iter()
            .map(|s| s.bytes().map(|c| c - b'a').collect())
            .collect();
        let set = SketchSet::from_rows(2, 5, &rows);
        let ss = SortedSketches::build(&set);
        let bst = super::super::BstTrie::build(&ss, BstConfig::default());
        let q: Vec<u8> = "aaaaa".bytes().map(|c| c - b'a').collect();
        (bst, rows, q)
    }

    #[test]
    fn paper_figure1_example() {
        let (bst, _rows, q) = figure1();
        let mut got = bst.search(&q, 1);
        got.sort();
        // ham=0: ids 1,5 ("aaaaa"); ham=1: id 2 ("baaaa").
        assert_eq!(got, vec![1, 2, 5]);
        // tau = 2 additionally admits caaca (ids 3,4,6) and abaab (id 8).
        let mut got2 = bst.search(&q, 2);
        got2.sort();
        assert_eq!(got2, vec![1, 2, 3, 4, 5, 6, 8]);
    }

    #[test]
    fn budget_shortcut_equals_full_enumeration() {
        // tau = 0 must return exactly the duplicate group.
        let rows = vec![
            vec![0u8, 1, 2, 3],
            vec![0, 1, 2, 3],
            vec![0, 1, 2, 2],
            vec![3, 1, 2, 3],
        ];
        let set = SketchSet::from_rows(2, 4, &rows);
        let ss = SortedSketches::build(&set);
        let bst = super::super::BstTrie::build(&ss, BstConfig::default());
        let mut got = bst.search(&[0, 1, 2, 3], 0);
        got.sort();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn figure1_topk_matches_brute_force() {
        let (bst, rows, q) = figure1();
        // Brute force: all (dist, id) sorted, truncated to k.
        let mut all: Vec<(usize, u32)> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| (ham_chars(r, &q), i as u32))
            .collect();
        all.sort_unstable();
        for k in [1usize, 3, 5, 11, 20] {
            let mut ctx = QueryCtx::new();
            let mut coll = TopK::new(k, q.len());
            bst.run(&q, &mut ctx, &mut coll);
            let got = coll.finish();
            let expect: Vec<(u32, usize)> = all
                .iter()
                .take(k)
                .map(|&(d, id)| (id, d))
                .collect();
            assert_eq!(got, expect, "k={k}");
        }
    }

    #[test]
    fn count_and_stats_agree_with_ids() {
        let (bst, _rows, q) = figure1();
        let mut ctx = QueryCtx::new();
        for tau in 0..=5 {
            let ids = bst.search(&q, tau);
            let mut cnt = CountOnly::new(tau);
            bst.run(&q, &mut ctx, &mut cnt);
            assert_eq!(cnt.count(), ids.len(), "tau={tau}");

            let mut out = Vec::new();
            let mut obs = StatsObserver::new(CollectIds::new(tau, &mut out));
            bst.run(&q, &mut ctx, &mut obs);
            let stats = obs.stats;
            assert_eq!(stats.emitted, ids.len(), "tau={tau}");
            assert!(stats.visited > 0);
            assert_eq!(out.len(), ids.len());
        }
    }

    #[test]
    fn blocked_descent_matches_serial_ids_stats_and_topk() {
        let (bst, rows, q0) = figure1();
        // A mixed block: ids at different taus, a count and a top-k — one
        // descent must reproduce every query's serial results AND stats.
        let qs_owned: Vec<Vec<u8>> = vec![q0.clone(), rows[7].clone(), rows[9].clone(), q0];
        let taus = [1usize, 2, 0, 5];

        // Serial oracle.
        let mut ctx = QueryCtx::new();
        let mut ser_ids: Vec<Vec<u32>> = Vec::new();
        let mut ser_stats = Vec::new();
        for (q, &tau) in qs_owned.iter().zip(&taus) {
            let mut out = Vec::new();
            let mut obs = StatsObserver::new(CollectIds::new(tau, &mut out));
            bst.run(q, &mut ctx, &mut obs);
            ser_stats.push(obs.stats);
            ser_ids.push(out);
        }
        let mut ser_topk = TopK::new(3, qs_owned[0].len());
        bst.run(&qs_owned[0], &mut ctx, &mut ser_topk);
        let ser_topk = ser_topk.finish();

        // Blocked run: 4 id-collectors + 1 top-k in one block.
        let mut outs: Vec<Vec<u32>> = vec![Vec::new(); 4];
        let mut obs: Vec<StatsObserver<CollectIds>> = outs
            .iter_mut()
            .zip(&taus)
            .map(|(o, &tau)| StatsObserver::new(CollectIds::new(tau, o)))
            .collect();
        let mut topk = TopK::new(3, qs_owned[0].len());
        {
            let mut slots: Vec<&mut dyn crate::query::Collector> =
                obs.iter_mut().map(|o| o as &mut dyn crate::query::Collector).collect();
            slots.push(&mut topk);
            let mut bc = crate::query::BlockCollector::new(&mut slots);
            let qs: Vec<&[u8]> = qs_owned
                .iter()
                .map(|q| q.as_slice())
                .chain(std::iter::once(qs_owned[0].as_slice()))
                .collect();
            run_block(&bst, &qs, &mut ctx, &mut bc);
            assert!(bc.work(0) > 0, "attribution weights must be populated");
        }
        for (j, o) in obs.iter().enumerate() {
            assert_eq!(o.stats, ser_stats[j], "stats mismatch for query {j}");
        }
        drop(obs);
        for (j, out) in outs.iter().enumerate() {
            assert_eq!(out, &ser_ids[j], "ids mismatch for query {j}");
        }
        assert_eq!(topk.finish(), ser_topk, "top-k mismatch");
    }

    #[test]
    fn ctx_reuse_across_taus_and_queries() {
        let (bst, rows, _q) = figure1();
        let mut ctx = QueryCtx::new();
        for q in rows.iter().take(6) {
            for tau in [0usize, 1, 3] {
                let mut out = Vec::new();
                let mut coll = CollectIds::new(tau, &mut out);
                bst.run(q, &mut ctx, &mut coll);
                let mut fresh = bst.search(q, tau);
                out.sort();
                fresh.sort();
                assert_eq!(out, fresh);
            }
        }
    }
}
