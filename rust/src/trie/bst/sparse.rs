//! Sparse layer (§V-C): collapsed root-to-leaf suffix paths.
//!
//! Subtries below level `ℓ_s` barely branch, so bST stores each leaf's
//! remaining `S = L - ℓ_s` characters as a flat string in the path array
//! `P`, plus a bit array `D` marking the leftmost leaf of each subtrie.
//! `children`-style navigation disappears; the search instead restores
//! each candidate suffix and compares it against the query suffix with the
//! **vertical-format** bit-parallel Hamming kernel (Zhang et al.):
//! `b` XOR/OR word ops + one popcount per leaf.
//!
//! `P` is stored directly in vertical format via the flat
//! [`PlaneStore`] (`b` planes of `S` bits per leaf) — the same `b·S` bits
//! per leaf as the character array the paper describes, but Hamming-ready
//! without a transpose and with branch-free reads.

use crate::bits::rsvec::SelectMode;
use crate::bits::{BitVec, RsBitVec};
use crate::sketch::plane_store::PlaneStore;
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::trie::builder::SortedSketches;
use crate::util::HeapSize;

/// Collapsed sparse layer.
pub struct SparseLayer {
    /// Suffix length `S = L - ℓ_s` (may be 0: all leaves are at `ℓ_s`).
    s: usize,
    /// Alphabet bits.
    b: usize,
    /// Vertical suffix planes.
    planes: PlaneStore,
    /// `D[v] = 1` iff leaf `v` is the leftmost leaf of its `ℓ_s`-subtrie.
    d: RsBitVec,
}

impl SparseLayer {
    /// Extracts the suffixes of all distinct sketches below level `ls`.
    pub fn build(ss: &SortedSketches, ls: usize) -> Self {
        let set = ss.set();
        let (b, l) = (set.b(), set.l());
        let s = l - ls;
        let n_leaves = ss.n_distinct();

        let planes = PlaneStore::from_fn(b, s, n_leaves, |bit, k| {
            let mut field = 0u64;
            for (pos, p) in (ls..l).enumerate() {
                field |= (((ss.char_of(k, p) >> bit) & 1) as u64) << pos;
            }
            field
        });

        // D: leftmost leaf of each subtrie rooted at level ls.
        let mut d = BitVec::with_capacity(n_leaves);
        // leaf v starts a new subtrie iff it starts a new node at level ls;
        // for ls = 0 there is a single subtrie containing every leaf.
        if ls == 0 {
            for v in 0..n_leaves {
                d.push(v == 0);
            }
        } else {
            let mut starts = vec![false; n_leaves];
            for span in ss.nodes_at_level(ls) {
                starts[span.start] = true;
            }
            for v in 0..n_leaves {
                d.push(starts[v]);
            }
        }

        SparseLayer { s, b, planes, d: RsBitVec::new(d, SelectMode::Ones) }
    }

    /// Suffix length `S`.
    #[inline]
    #[allow(dead_code)] // diagnostics/tests
    pub fn suffix_len(&self) -> usize {
        self.s
    }

    /// Leaf range `[lo, hi)` of the subtrie rooted at sparse node `u`
    /// (the `u`-th node at level `ℓ_s`).
    #[inline]
    pub fn leaf_range(&self, u: usize) -> (usize, usize) {
        let lo = self.d.select1(u);
        let hi = if u + 1 < self.d.count_ones() {
            self.d.select1(u + 1)
        } else {
            self.d.len()
        };
        (lo, hi)
    }

    /// Packs the query suffix `q[ℓ_s..L)` into plane fields, reusing the
    /// caller's buffer (the per-query scratch in `QueryCtx`).
    pub fn pack_query_into(&self, q_suffix: &[u8], out: &mut Vec<u64>) {
        out.clear();
        self.pack_query_append(q_suffix, out);
    }

    /// Packs a query suffix *appended* to `out` — block execution packs a
    /// whole block's suffixes back to back into one flat `m·b` buffer.
    pub fn pack_query_append(&self, q_suffix: &[u8], out: &mut Vec<u64>) {
        debug_assert_eq!(q_suffix.len(), self.s);
        for k in 0..self.b {
            let mut field = 0u64;
            for (pos, &c) in q_suffix.iter().enumerate() {
                field |= (((c >> k) & 1) as u64) << pos;
            }
            out.push(field);
        }
    }

    /// Allocating convenience wrapper around [`Self::pack_query_into`].
    pub fn pack_query(&self, q_suffix: &[u8]) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.b);
        self.pack_query_into(q_suffix, &mut out);
        out
    }

    /// Hamming distance between leaf `v`'s suffix and packed query planes
    /// (per-item reference path; the traversal streams via
    /// [`Self::suffix_scan`]).
    #[inline]
    #[allow(dead_code)] // diagnostics/tests — oracle for the kernel
    pub fn ham_suffix(&self, v: usize, q_planes: &[u64]) -> usize {
        self.planes.ham(v, q_planes)
    }

    /// Streaming suffix-verification cursor over leaves `[lo, hi)` — one
    /// kernel call per sparse node instead of per-leaf random `field()`
    /// extraction. The leaves of a subtrie are contiguous
    /// ([`Self::leaf_range`]), so the cursor walks the plane words
    /// sequentially; see [`PlaneStore::range_scan`] for the contract.
    #[inline]
    pub fn suffix_scan<'a>(
        &'a self,
        lo: usize,
        hi: usize,
        q_planes: &'a [u64],
    ) -> crate::sketch::plane_store::RangeHam<'a> {
        self.planes.range_scan(lo, hi, q_planes)
    }

    /// Multi-query suffix verification over leaves `[lo, hi)` — the
    /// blocked-traversal counterpart of [`Self::suffix_scan`]: one pass
    /// over the plane words evaluates every live query's suffix budget.
    /// See [`PlaneStore::ham_range_leq_multi`] for the block contract.
    #[inline]
    pub fn suffix_scan_multi<F>(
        &self,
        lo: usize,
        hi: usize,
        qs: &[u64],
        taus0: &[usize],
        live0: u64,
        sink: F,
    ) where
        F: FnMut(usize, usize, Option<usize>) -> Option<usize>,
    {
        self.planes.ham_range_leq_multi(lo, hi, qs, taus0, live0, sink)
    }

    /// Restores the raw suffix characters of leaf `v` (diagnostics/tests).
    #[allow(dead_code)] // diagnostics/tests
    pub fn suffix_chars(&self, v: usize) -> Vec<u8> {
        (0..self.s)
            .map(|pos| {
                let mut c = 0u8;
                for k in 0..self.b {
                    c |= (((self.planes.field(k, v) >> pos) & 1) as u8) << k;
                }
                c
            })
            .collect()
    }

    /// Number of subtrie roots (nodes at level `ℓ_s`).
    #[allow(dead_code)] // diagnostics/tests
    pub fn root_count(&self) -> usize {
        self.d.count_ones()
    }

    /// Total leaves.
    #[allow(dead_code)] // diagnostics/tests
    pub fn leaf_count(&self) -> usize {
        self.d.len()
    }
}

impl Persist for SparseLayer {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.s);
        w.put_usize(self.b);
        self.planes.write_into(w);
        self.d.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let s = r.get_usize()?;
        let b = r.get_usize()?;
        let planes = PlaneStore::read_from(r)?;
        let d = RsBitVec::read_from(r)?;
        ensure((1..=8).contains(&b) && s <= 64, || {
            format!("sparse layer: bad dims b={b} S={s}")
        })?;
        ensure(planes.b() == b && planes.width() == s, || {
            format!(
                "sparse layer: plane store is {}x{}-bit, expected {b}x{s}",
                planes.b(),
                planes.width()
            )
        })?;
        ensure(d.len() == planes.n(), || {
            format!("sparse layer: {} D bits for {} leaves", d.len(), planes.n())
        })?;
        ensure(d.select1_enabled(), || "sparse layer: D select missing".to_string())?;
        // Leaf ranges tile from leaf 0: the first leaf starts a subtrie.
        ensure(d.is_empty() || d.get(0), || "sparse layer: D[0] must be set".to_string())?;
        Ok(SparseLayer { s, b, planes, d })
    }
}

impl HeapSize for SparseLayer {
    fn heap_bytes(&self) -> usize {
        self.planes.heap_bytes() + self.d.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::sketch::SketchSet;
    use crate::util::Rng;

    fn setup(b: usize, l: usize, n: usize, seed: u64) -> SketchSet {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        SketchSet::from_rows(b, l, &rows)
    }

    #[test]
    fn suffixes_roundtrip() {
        for &(b, l, ls) in
            &[(2usize, 10usize, 4usize), (4, 8, 5), (8, 6, 3), (2, 8, 0), (2, 8, 8)]
        {
            let set = setup(b, l, 200, (b + l + ls) as u64);
            let ss = SortedSketches::build(&set);
            let sp = SparseLayer::build(&ss, ls);
            assert_eq!(sp.suffix_len(), l - ls);
            for k in 0..ss.n_distinct() {
                assert_eq!(sp.suffix_chars(k), ss.suffix(k, ls), "k={k} ls={ls}");
            }
        }
    }

    #[test]
    fn leaf_ranges_tile_leaves() {
        let set = setup(2, 10, 400, 3);
        let ss = SortedSketches::build(&set);
        for ls in [0usize, 3, 6, 10] {
            let sp = SparseLayer::build(&ss, ls);
            assert_eq!(sp.root_count(), ss.level_counts()[ls]);
            let mut covered = 0usize;
            for u in 0..sp.root_count() {
                let (lo, hi) = sp.leaf_range(u);
                assert_eq!(lo, covered, "ls={ls} u={u}");
                assert!(hi > lo);
                covered = hi;
            }
            assert_eq!(covered, ss.n_distinct());
        }
    }

    #[test]
    fn ham_suffix_matches_naive() {
        let set = setup(4, 12, 300, 7);
        let ss = SortedSketches::build(&set);
        let sp = SparseLayer::build(&ss, 5);
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let q: Vec<u8> = (0..7).map(|_| rng.below(16) as u8).collect();
            let qp = sp.pack_query(&q);
            for k in (0..ss.n_distinct()).step_by(7) {
                assert_eq!(
                    sp.ham_suffix(k, &qp),
                    ham_chars(&ss.suffix(k, 5), &q),
                    "k={k}"
                );
            }
        }
    }

    #[test]
    fn zero_length_suffix() {
        // ls = L: sparse layer stores nothing; every leaf distance is 0.
        let set = setup(2, 6, 100, 9);
        let ss = SortedSketches::build(&set);
        let sp = SparseLayer::build(&ss, 6);
        assert_eq!(sp.suffix_len(), 0);
        let qp = sp.pack_query(&[]);
        assert_eq!(sp.ham_suffix(0, &qp), 0);
        assert_eq!(sp.root_count(), ss.n_distinct());
    }

    #[test]
    fn space_is_b_s_bits_per_leaf() {
        let set = setup(2, 16, 2000, 13);
        let ss = SortedSketches::build(&set);
        let sp = SparseLayer::build(&ss, 8);
        let payload_bits = ss.n_distinct() * 2 * 8; // b*S per leaf
        // D adds ~1 bit/leaf + rank dirs; stay within 2x of payload.
        assert!(sp.heap_bytes() * 8 >= payload_bits);
        assert!(sp.heap_bytes() * 8 <= payload_bits * 2 + 4096);
    }
}
