//! Dense layer (§V-A): the implicit complete `2^b`-ary trie.
//!
//! Levels `0..ℓ_m` store **nothing** but `ℓ_m` itself: node `u` at level
//! `ℓ < ℓ_m` has exactly the children `u·2^b + c` for every `c ∈ Σ`, and
//! the 0-based node id at each level coincides with the lexicographic rank
//! of its prefix, so the ids flow seamlessly into the middle layer.
//!
//! `children(u_ℓ) = { (u·2^b + c, c) : c ∈ [0, 2^b) }` — pure arithmetic,
//! no memory access. This module only hosts the helper + its tests; the
//! traversal inlines the arithmetic directly.

/// First child id of dense node `u` (its children are
/// `child0(u, b) + c`).
#[inline]
pub fn child0(u: usize, b: usize) -> usize {
    u << b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_enumerate_prefixes_in_lex_order() {
        // b = 2 (alphabet 4): level-2 node for prefix "ca" (chars 2,0)
        // should be id 2*4 + 0 = 8.
        let b = 2;
        let root = 0usize;
        let level1: Vec<usize> = (0..4).map(|c| child0(root, b) + c).collect();
        assert_eq!(level1, vec![0, 1, 2, 3]);
        let ca = child0(level1[2], b) + 0;
        assert_eq!(ca, 8);
        let dd = child0(level1[3], b) + 3;
        assert_eq!(dd, 15);
    }

    #[test]
    fn level_widths_are_powers() {
        let b = 4;
        let mut ids = vec![0usize];
        for _ in 0..3 {
            ids = ids
                .iter()
                .flat_map(|&u| (0..(1 << b)).map(move |c| child0(u, b) + c))
                .collect();
        }
        assert_eq!(ids.len(), 1 << (4 * 3));
        // contiguity: ids are exactly 0..16^3
        assert!(ids.iter().enumerate().all(|(i, &u)| i == u));
    }
}
