//! bST layer configuration (§V of the paper).

use super::middle::MiddleRepr;

/// Construction parameters for [`super::BstTrie`].
#[derive(Debug, Clone, Copy)]
pub struct BstConfig {
    /// Sparse-layer density parameter `λ ∈ (0, 1)`; the sparse layer
    /// starts at the first level whose node count exceeds `λ · t_L`
    /// (i.e. subtries below average fewer than `1/λ` leaves).
    ///
    /// The paper states the condition as `D(ℓ_s, L) < λ`, which is
    /// unsatisfiable as written (`t_L / t_ℓ >= 1`); see DESIGN.md §1 for
    /// the reading implemented here. Paper default: `λ = 0.5`.
    pub lambda: f64,
    /// Force the dense-layer depth `ℓ_m` (None: maximal complete level).
    pub lm: Option<usize>,
    /// Force the sparse-layer start `ℓ_s` (None: from `lambda`).
    pub ls: Option<usize>,
    /// Force every middle level to one representation (None: adaptive
    /// TABLE/LIST selection by the `2^b/(b+1)` density crossover).
    pub force_repr: Option<MiddleRepr>,
}

impl Default for BstConfig {
    fn default() -> Self {
        BstConfig { lambda: 0.5, lm: None, ls: None, force_repr: None }
    }
}

impl BstConfig {
    /// Largest supported alphabet width: labels are `u8` and the query
    /// scratch sizes its per-level fan-out buffer as `1 << b`.
    pub const MAX_B: usize = 8;

    /// Resolves `(ℓ_m, ℓ_s)` for a database with per-level node counts
    /// `counts[0..=L]`.
    pub fn resolve_layers(&self, b: usize, l: usize, counts: &[usize]) -> (usize, usize) {
        debug_assert_eq!(counts.len(), l + 1);
        // max ℓ with t_ℓ = 2^{bℓ} (the level is complete). The implicit
        // dense representation is only valid up to here, so user overrides
        // are clamped to it.
        let max_complete = {
            let mut lm = 0usize;
            let mut full = 1u128;
            for (lv, &t) in counts.iter().enumerate().skip(1) {
                full = full.saturating_mul(1u128 << b);
                if t as u128 == full {
                    lm = lv;
                } else {
                    break;
                }
            }
            lm
        };
        let lm = match self.lm {
            Some(v) => v.min(max_complete),
            None => max_complete,
        };
        let t_l = counts[l];
        let ls = match self.ls {
            Some(v) => v.clamp(lm, l),
            None => {
                let threshold = self.lambda * t_l as f64;
                let mut ls = l;
                for lv in lm..=l {
                    if counts[lv] as f64 > threshold {
                        ls = lv;
                        break;
                    }
                }
                ls
            }
        };
        (lm, ls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_depth_detects_complete_levels() {
        // b=2: alphabet 4. counts: root, 4, 16, 60 (level 3 incomplete).
        let counts = vec![1usize, 4, 16, 60];
        let cfg = BstConfig::default();
        let (lm, _) = cfg.resolve_layers(2, 3, &counts);
        assert_eq!(lm, 2);
    }

    #[test]
    fn no_dense_layer_when_root_fanout_incomplete() {
        let counts = vec![1usize, 3, 9, 27];
        let (lm, _) = BstConfig::default().resolve_layers(2, 3, &counts);
        assert_eq!(lm, 0);
    }

    #[test]
    fn sparse_start_at_lambda_crossing() {
        // t_L = 100, lambda=0.5 → first level with > 50 nodes.
        let counts = vec![1usize, 4, 10, 40, 60, 90, 100];
        let (_, ls) = BstConfig::default().resolve_layers(2, 6, &counts);
        assert_eq!(ls, 4);
    }

    #[test]
    fn overrides_respected_and_clamped() {
        let counts = vec![1usize, 4, 16, 64, 100];
        let cfg = BstConfig { lm: Some(1), ls: Some(0), ..Default::default() };
        let (lm, ls) = cfg.resolve_layers(2, 4, &counts);
        assert_eq!(lm, 1);
        assert_eq!(ls, 1, "ls clamps up to lm");
        let cfg = BstConfig { lm: Some(9), ls: Some(9), ..Default::default() };
        let (lm, ls) = cfg.resolve_layers(2, 4, &counts);
        // lm clamps to the max complete level (3: t_4=100 != 4^4), ls to L.
        assert_eq!((lm, ls), (3, 4));
    }

    #[test]
    fn degenerate_single_chain() {
        // one distinct sketch: t_ℓ = 1 everywhere.
        let counts = vec![1usize; 9];
        let (lm, ls) = BstConfig::default().resolve_layers(2, 8, &counts);
        assert_eq!(lm, 0);
        assert_eq!(ls, 0, "whole trie is one collapsed path");
    }
}
