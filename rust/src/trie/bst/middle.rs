//! Middle layer (§V-B): per-level TABLE or LIST node representation.
//!
//! * **TABLE** — bit array `H_ℓ` of `2^b · t_{ℓ-1}` bits; bit
//!   `u·2^b + c` is set iff node `u` at level `ℓ-1` has a child labeled
//!   `c`. `children(u)` = one rank at the window start + a bit scan of the
//!   `2^b`-bit window (windows are `2^b`-aligned, so they never straddle
//!   more words than `⌈2^b/64⌉`).
//! * **LIST** — label array `C_ℓ` (b bits each) + first-sibling bit array
//!   `B_ℓ`; `children(u)` = `[select1(B_ℓ, u), select1(B_ℓ, u+1))`.
//!
//! Selection (§V-B): TABLE costs `2^b · t_{ℓ-1}` bits, LIST costs
//! `(b+1) · t_ℓ` bits, so TABLE wins iff the level's density
//! `t_ℓ / t_{ℓ-1}` exceeds `2^b / (b+1)`.

use crate::bits::rsvec::SelectMode;
use crate::bits::{BitVec, IntVec, RsBitVec};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::trie::builder::SortedSketches;
use crate::util::HeapSize;

/// Which representation a middle level uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MiddleRepr {
    Table,
    List,
}

/// One encoded middle level.
pub enum MiddleLevel {
    Table {
        /// `H_ℓ`: windowed child bitmaps with rank support.
        h: RsBitVec,
        /// Alphabet bits `b` (window width = `2^b`).
        b: usize,
    },
    List {
        /// `C_ℓ`: edge labels of the level's nodes.
        c: IntVec,
        /// `B_ℓ`: 1 iff the node is the first of its siblings.
        bfirst: RsBitVec,
    },
}

impl MiddleLevel {
    /// Encodes level `level` (1-based) of the trie, choosing TABLE/LIST by
    /// density unless `force` is given.
    pub fn build(ss: &SortedSketches, level: usize, force: Option<MiddleRepr>) -> Self {
        let b = ss.set().b();
        let sigma = 1usize << b;
        let t_prev = ss.level_counts()[level - 1];
        let t_cur = ss.level_counts()[level];

        let density = t_cur as f64 / t_prev as f64;
        let crossover = sigma as f64 / (b as f64 + 1.0);
        let table_bits = sigma.saturating_mul(t_prev);
        let mut repr = force.unwrap_or(if density > crossover {
            MiddleRepr::Table
        } else {
            MiddleRepr::List
        });
        // RsBitVec is bounded at 2^32 bits; huge sparse levels fall back to
        // LIST (the density rule would almost never pick TABLE there).
        if table_bits >= u32::MAX as usize {
            repr = MiddleRepr::List;
        }

        match repr {
            MiddleRepr::Table => {
                let mut h = BitVec::zeros(table_bits);
                let mut parent = 0usize;
                let mut seen_first = false;
                for span in ss.nodes_at_level(level) {
                    if span.first_sibling {
                        if seen_first {
                            parent += 1;
                        }
                        seen_first = true;
                    }
                    h.set(parent * sigma + span.label as usize);
                }
                MiddleLevel::Table { h: RsBitVec::new(h, SelectMode::None), b }
            }
            MiddleRepr::List => {
                let mut c = IntVec::with_capacity(b, t_cur);
                let mut bfirst = BitVec::with_capacity(t_cur);
                for span in ss.nodes_at_level(level) {
                    c.push(span.label as u64);
                    bfirst.push(span.first_sibling);
                }
                MiddleLevel::List {
                    c,
                    bfirst: RsBitVec::new(bfirst, SelectMode::Ones),
                }
            }
        }
    }

    pub fn repr(&self) -> MiddleRepr {
        match self {
            MiddleLevel::Table { .. } => MiddleRepr::Table,
            MiddleLevel::List { .. } => MiddleRepr::List,
        }
    }

    /// Number of nodes at this level (children entries).
    #[allow(dead_code)] // used by tests and diagnostics
    pub fn node_count(&self) -> usize {
        match self {
            MiddleLevel::Table { h, .. } => h.count_ones(),
            MiddleLevel::List { c, .. } => c.len(),
        }
    }

    /// Invokes `f(child_id, label)` for every child of node `u` at the
    /// previous level, in label order.
    #[inline]
    pub fn children<F: FnMut(usize, u8)>(&self, u: usize, mut f: F) {
        match self {
            MiddleLevel::Table { h, b } => {
                let sigma = 1usize << b;
                let start = u * sigma;
                // child ids of the window begin after all earlier 1s
                let mut child = h.rank1(start);
                if sigma <= 64 {
                    // aligned single-word window
                    let mut w = h.get_bits(start, sigma);
                    while w != 0 {
                        let c = w.trailing_zeros() as u8;
                        f(child, c);
                        child += 1;
                        w &= w - 1;
                    }
                } else {
                    // b = 8: four aligned words
                    let words = h.words();
                    let w0 = start / 64;
                    for k in 0..sigma / 64 {
                        let mut w = words.get(w0 + k).copied().unwrap_or(0);
                        while w != 0 {
                            let c = (k * 64) as u8 + w.trailing_zeros() as u8;
                            f(child, c);
                            child += 1;
                            w &= w - 1;
                        }
                    }
                }
            }
            MiddleLevel::List { c, bfirst } => {
                let lo = bfirst.select1(u);
                let hi = if u + 1 < bfirst.count_ones() {
                    bfirst.select1(u + 1)
                } else {
                    c.len()
                };
                for v in lo..hi {
                    f(v, c.get(v) as u8);
                }
            }
        }
    }

    /// Child of node `u` with edge label exactly `label`, if present —
    /// the `dist == τ` fast path of the traversal (and the exact-lookup
    /// primitive when bST serves as an inverted index).
    #[inline]
    pub fn child_with_label(&self, u: usize, label: u8) -> Option<usize> {
        match self {
            MiddleLevel::Table { h, b } => {
                let pos = (u << b) + label as usize;
                h.get(pos).then(|| h.rank1(pos))
            }
            MiddleLevel::List { c, bfirst } => {
                let lo = bfirst.select1(u);
                let hi = if u + 1 < bfirst.count_ones() {
                    bfirst.select1(u + 1)
                } else {
                    c.len()
                };
                // children are label-sorted; ranges are tiny → linear scan
                (lo..hi).find(|&v| c.get(v) as u8 == label)
            }
        }
    }

    /// Space in bits of the core payload (excluding rank/select overhead),
    /// as accounted in §V-B of the paper.
    #[allow(dead_code)] // used by tests and diagnostics
    pub fn payload_bits(&self) -> usize {
        match self {
            MiddleLevel::Table { h, .. } => h.len(),
            MiddleLevel::List { c, bfirst } => c.len() * c.width() + bfirst.len(),
        }
    }
}

impl MiddleLevel {
    /// Snapshot validation: checks this encoding against the node counts
    /// of its level (`t_prev` parents, `t_cur` nodes) for alphabet bits
    /// `b`. Cheap structural checks only — no re-encoding.
    pub(crate) fn validate_level(
        &self,
        b: usize,
        t_prev: usize,
        t_cur: usize,
    ) -> Result<(), StoreError> {
        match self {
            MiddleLevel::Table { h, b: tb } => {
                ensure(*tb == b, || format!("middle TABLE: b {tb} != trie b {b}"))?;
                let want = (1usize << b)
                    .checked_mul(t_prev)
                    .ok_or_else(|| StoreError::Corrupt("middle TABLE: size overflows".into()))?;
                ensure(h.len() == want, || {
                    format!("middle TABLE: {} bits != 2^b * t_prev = {want}", h.len())
                })?;
                ensure(h.count_ones() == t_cur, || {
                    format!("middle TABLE: {} set bits != t_cur = {t_cur}", h.count_ones())
                })
            }
            MiddleLevel::List { c, bfirst } => {
                ensure(c.width() == b, || {
                    format!("middle LIST: label width {} != b {b}", c.width())
                })?;
                ensure(c.len() == t_cur && bfirst.len() == t_cur, || {
                    format!("middle LIST: {} labels != t_cur = {t_cur}", c.len())
                })?;
                ensure(bfirst.count_ones() == t_prev, || {
                    format!(
                        "middle LIST: {} first-sibling bits != t_prev = {t_prev}",
                        bfirst.count_ones()
                    )
                })?;
                ensure(bfirst.select1_enabled(), || {
                    "middle LIST: select directory missing".to_string()
                })
            }
        }
    }
}

impl Persist for MiddleLevel {
    fn write_into(&self, w: &mut ByteWriter) {
        match self {
            MiddleLevel::Table { h, b } => {
                w.put_u8(0);
                w.put_usize(*b);
                h.write_into(w);
            }
            MiddleLevel::List { c, bfirst } => {
                w.put_u8(1);
                c.write_into(w);
                bfirst.write_into(w);
            }
        }
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        match r.get_u8()? {
            0 => {
                let b = r.get_usize()?;
                ensure((1..=8).contains(&b), || format!("middle TABLE: bad b {b}"))?;
                let h = RsBitVec::read_from(r)?;
                ensure(h.len() % (1usize << b) == 0, || {
                    "middle TABLE: bitmap not window-aligned".to_string()
                })?;
                Ok(MiddleLevel::Table { h, b })
            }
            1 => {
                let c = IntVec::read_from(r)?;
                let bfirst = RsBitVec::read_from(r)?;
                ensure(c.len() == bfirst.len(), || {
                    format!("middle LIST: {} labels vs {} bits", c.len(), bfirst.len())
                })?;
                Ok(MiddleLevel::List { c, bfirst })
            }
            t => Err(StoreError::Corrupt(format!("middle level: unknown repr tag {t}"))),
        }
    }
}

impl HeapSize for MiddleLevel {
    fn heap_bytes(&self) -> usize {
        match self {
            MiddleLevel::Table { h, .. } => h.heap_bytes(),
            MiddleLevel::List { c, bfirst } => c.heap_bytes() + bfirst.heap_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchSet;
    use crate::util::Rng;
    use std::collections::BTreeMap;

    /// Reference children: group distinct prefixes.
    fn expected_children(
        rows: &[Vec<u8>],
        level: usize,
    ) -> BTreeMap<Vec<u8>, Vec<(usize, u8)>> {
        use std::collections::BTreeSet;
        let prefixes: BTreeSet<Vec<u8>> =
            rows.iter().map(|r| r[..level].to_vec()).collect();
        let mut by_parent: BTreeMap<Vec<u8>, Vec<(usize, u8)>> = BTreeMap::new();
        for (id, p) in prefixes.iter().enumerate() {
            by_parent
                .entry(p[..level - 1].to_vec())
                .or_default()
                .push((id, p[level - 1]));
        }
        by_parent
    }

    fn check_level(b: usize, l: usize, n: usize, seed: u64, force: Option<MiddleRepr>) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        for level in 1..=l {
            let ml = MiddleLevel::build(&ss, level, force);
            assert_eq!(ml.node_count(), ss.level_counts()[level]);
            let expect = expected_children(&rows, level);
            // parents are the distinct (level-1)-prefixes in lex order
            for (u, (_parent, kids)) in expect.iter().enumerate() {
                let mut got = Vec::new();
                ml.children(u, |id, c| got.push((id, c)));
                assert_eq!(&got, kids, "b={b} level={level} u={u} {:?}", ml.repr());
            }
        }
    }

    #[test]
    fn table_children_match_reference() {
        check_level(2, 6, 400, 1, Some(MiddleRepr::Table));
        check_level(4, 4, 300, 2, Some(MiddleRepr::Table));
        check_level(8, 3, 500, 3, Some(MiddleRepr::Table)); // multi-word windows
        check_level(1, 10, 300, 4, Some(MiddleRepr::Table));
    }

    #[test]
    fn list_children_match_reference() {
        check_level(2, 6, 400, 5, Some(MiddleRepr::List));
        check_level(4, 4, 300, 6, Some(MiddleRepr::List));
        check_level(8, 3, 500, 7, Some(MiddleRepr::List));
    }

    #[test]
    fn adaptive_selection_follows_density_rule() {
        let b = 2usize;
        let mut rng = Rng::new(8);
        let rows: Vec<Vec<u8>> = (0..3000)
            .map(|_| (0..8).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, 8, &rows);
        let ss = SortedSketches::build(&set);
        for level in 1..=8 {
            let ml = MiddleLevel::build(&ss, level, None);
            let density = ss.level_counts()[level] as f64
                / ss.level_counts()[level - 1] as f64;
            let expect = if density > 4.0 / 3.0 {
                MiddleRepr::Table
            } else {
                MiddleRepr::List
            };
            assert_eq!(ml.repr(), expect, "level={level} density={density}");
        }
    }

    #[test]
    fn paper_example_table_figure3() {
        // Figure 3 of the paper: H_2 = 1,1,1,1, 1,0,1,0, ... for a trie
        // where node 1 at level 1 has children a..d and node 2 has {a, c}.
        // We reproduce the semantics: set bits at positions (u-1)*4+c.
        let rows = vec![
            vec![0u8, 0], // a a
            vec![0, 1],   // a b
            vec![0, 2],
            vec![0, 3],
            vec![1, 0], // b a
            vec![1, 2], // b c
        ];
        let set = SketchSet::from_rows(2, 2, &rows);
        let ss = SortedSketches::build(&set);
        let ml = MiddleLevel::build(&ss, 2, Some(MiddleRepr::Table));
        let mut got = Vec::new();
        ml.children(1, |id, c| got.push((id, c)));
        // node "b" (id 1 at level 1): children ids 4,5 labels a,c
        assert_eq!(got, vec![(4, 0), (5, 2)]);
    }

    #[test]
    fn space_crossover_is_honest() {
        // For a level encoded both ways, the density rule must pick the
        // smaller payload.
        let mut rng = Rng::new(9);
        let rows: Vec<Vec<u8>> = (0..2000)
            .map(|_| (0..6).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 6, &rows);
        let ss = SortedSketches::build(&set);
        for level in 1..=6 {
            let t = MiddleLevel::build(&ss, level, Some(MiddleRepr::Table));
            let l_ = MiddleLevel::build(&ss, level, Some(MiddleRepr::List));
            let adaptive = MiddleLevel::build(&ss, level, None);
            let min_bits = t.payload_bits().min(l_.payload_bits());
            assert_eq!(
                adaptive.payload_bits(),
                min_bits,
                "level {level}: adaptive must match the smaller payload"
            );
        }
    }
}
