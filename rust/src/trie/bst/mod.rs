//! The b-bit Sketch Trie (bST) — §V of the paper.
//!
//! A three-layer succinct trie exploiting the distribution of random
//! fixed-length strings: levels near the root are *complete* (every
//! `2^b`-ary branch exists), levels near the leaves barely branch.
//!
//! ```text
//!          level 0 ─┬─ dense layer: implicit complete 2^b-ary trie;
//!                   │  only ℓ_m is stored; children are arithmetic.
//!         level ℓ_m ┼─ middle layer: per level, TABLE (bitmap + rank)
//!                   │  or LIST (labels + first-sibling bits + select),
//!                   │  picked by the density crossover 2^b/(b+1).
//!         level ℓ_s ┼─ sparse layer: subtries collapsed to suffix
//!                   │  strings in vertical format (P) + leftmost-leaf
//!          level L ─┴─ bits (D); Hamming by XOR/OR/popcnt.
//! ```
//!
//! Search is Algorithm 1: DFS carrying the running Hamming distance,
//! pruning once `dist > τ`, switching to bit-parallel suffix comparison
//! in the sparse layer.

mod config;
mod dense;
pub(crate) mod middle;
mod search;
mod sparse;

pub use config::BstConfig;
pub use middle::MiddleRepr;

use super::builder::SortedSketches;
use super::SketchTrie;
use crate::query::{Collector, QueryCtx};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, U32s};
use crate::util::HeapSize;

/// The b-bit sketch trie.
pub struct BstTrie {
    pub(crate) b: usize,
    pub(crate) l: usize,
    /// Dense-layer depth (levels `0..lm` are implicit-complete).
    pub(crate) lm: usize,
    /// Sparse-layer start (levels `ls..L` are collapsed paths).
    pub(crate) ls: usize,
    /// Middle-layer representations for levels `lm+1 ..= ls`
    /// (index 0 ↔ level `lm+1`).
    pub(crate) middle: Vec<middle::MiddleLevel>,
    /// Sparse layer: collapsed suffixes + leaf grouping.
    pub(crate) sparse: sparse::SparseLayer,
    /// Leaf postings (leaf k ↔ distinct sketch k).
    pub(crate) post_offsets: U32s,
    pub(crate) post_ids: U32s,
    /// Largest posting id, cached at construction (`None` when empty) —
    /// loaders bound ids against the stripe they serve on every snapshot
    /// open, so this must not be an O(n) scan per call.
    pub(crate) max_post: Option<u32>,
    /// Node counts per level (diagnostics / reports).
    pub(crate) level_counts: Vec<usize>,
}

impl BstTrie {
    /// Builds a bST over the sorted database with the given configuration.
    pub fn build(ss: &SortedSketches, cfg: BstConfig) -> Self {
        let set = ss.set();
        let (b, l) = (set.b(), set.l());
        // Labels travel as u8 and the per-level fan-out buffer in QueryCtx
        // is sized 1 << b, so the alphabet must fit a byte.
        assert!(
            b <= BstConfig::MAX_B,
            "bST supports b <= {} (u8 labels), got b={b}",
            BstConfig::MAX_B
        );
        let counts = ss.level_counts();

        let (lm, ls) = cfg.resolve_layers(b, l, counts);

        // Middle layer: pick TABLE or LIST per level by node density.
        let mut middle = Vec::with_capacity(ls - lm);
        for level in (lm + 1)..=ls {
            middle.push(middle::MiddleLevel::build(ss, level, cfg.force_repr));
        }

        let sparse = sparse::SparseLayer::build(ss, ls);
        let (post_offsets, post_ids) = ss.postings_parts();
        let max_post = post_ids.iter().copied().max();

        BstTrie {
            b,
            l,
            lm,
            ls,
            middle,
            sparse,
            post_offsets: post_offsets.into(),
            post_ids: post_ids.into(),
            max_post,
            level_counts: counts.to_vec(),
        }
    }

    /// Dense-layer depth `ℓ_m`.
    pub fn dense_depth(&self) -> usize {
        self.lm
    }

    /// Sparse-layer start `ℓ_s`.
    pub fn sparse_start(&self) -> usize {
        self.ls
    }

    /// Per-level representation choices, e.g. `"DDTTLLS"` (Dense / Table /
    /// List / Sparse) — used by `describe` and the eval reports.
    pub fn layer_string(&self) -> String {
        let mut s = String::new();
        for _ in 0..self.lm {
            s.push('D');
        }
        for ml in &self.middle {
            s.push(match ml.repr() {
                MiddleRepr::Table => 'T',
                MiddleRepr::List => 'L',
            });
        }
        for _ in self.ls..self.l {
            s.push('S');
        }
        s
    }

    /// Sketch length `L`.
    pub fn sketch_len(&self) -> usize {
        self.l
    }

    /// Alphabet bits `b`.
    pub fn alphabet_bits(&self) -> usize {
        self.b
    }

    /// Total ids across all leaf postings (= database rows, duplicates
    /// included): every indexed sketch id appears in exactly one group.
    pub fn post_id_count(&self) -> usize {
        self.post_ids.len()
    }

    /// Largest posting id (`None` for an empty postings table) —
    /// snapshot loaders bound ids against the database they serve.
    /// Cached at build/load time (the load-time validation pass already
    /// walks every id), so this is O(1).
    pub fn max_posting(&self) -> Option<u32> {
        self.max_post
    }

    #[inline]
    pub(crate) fn postings_of(&self, leaf: usize) -> &[u32] {
        let lo = self.post_offsets[leaf] as usize;
        let hi = self.post_offsets[leaf + 1] as usize;
        &self.post_ids[lo..hi]
    }
}

impl Persist for BstTrie {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.l);
        w.put_usize(self.lm);
        w.put_usize(self.ls);
        w.put_usize(self.middle.len());
        for ml in &self.middle {
            ml.write_into(w);
        }
        self.sparse.write_into(w);
        w.put_u32s(&self.post_offsets);
        w.put_u32s(&self.post_ids);
        w.put_usizes(&self.level_counts);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let l = r.get_usize()?;
        let lm = r.get_usize()?;
        let ls = r.get_usize()?;
        ensure(
            (1..=BstConfig::MAX_B).contains(&b)
                && l >= 1
                && l <= 64 * 64 // SketchSet's L·b bound; also caps the vec below
                && lm <= ls
                && ls <= l,
            || format!("bST: invalid layer bounds b={b} L={l} lm={lm} ls={ls}"),
        )?;
        let n_middle = r.get_usize()?;
        ensure(n_middle == ls - lm, || {
            format!("bST: {n_middle} middle levels for lm={lm} ls={ls}")
        })?;
        let mut middle = Vec::with_capacity(n_middle);
        for _ in 0..n_middle {
            middle.push(middle::MiddleLevel::read_from(r)?);
        }
        let sparse = sparse::SparseLayer::read_from(r)?;
        let post_offsets = r.get_u32s_ref()?;
        let post_ids = r.get_u32s_ref()?;
        let level_counts = r.get_usizes()?;

        ensure(level_counts.len() == l + 1 && level_counts[0] == 1, || {
            format!("bST: {} level counts for L={l}", level_counts.len())
        })?;
        ensure(level_counts.windows(2).all(|w| w[0] <= w[1]), || {
            "bST: level counts must be nondecreasing".to_string()
        })?;
        // Dense layer: levels 0..=lm must be complete (ids are arithmetic).
        let mut full = 1u128;
        for lv in 1..=lm {
            full = full.saturating_mul(1u128 << b);
            ensure(level_counts[lv] as u128 == full, || {
                format!("bST: dense level {lv} has {} nodes, expected {full}", level_counts[lv])
            })?;
        }
        for (i, ml) in middle.iter().enumerate() {
            let level = lm + 1 + i;
            ml.validate_level(b, level_counts[level - 1], level_counts[level])?;
        }
        let n_leaves = level_counts[l];
        ensure(
            sparse.suffix_len() == l - ls
                && sparse.leaf_count() == n_leaves
                && sparse.root_count() == level_counts[ls],
            || "bST: sparse layer disagrees with level counts".to_string(),
        )?;
        let max_post = super::validate_postings(&post_offsets, &post_ids, n_leaves)?;
        Ok(BstTrie {
            b,
            l,
            lm,
            ls,
            middle,
            sparse,
            post_offsets,
            post_ids,
            max_post,
            level_counts,
        })
    }
}

impl SketchTrie for BstTrie {
    fn run<C: Collector>(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut C) {
        assert_eq!(q.len(), self.l);
        search::run(self, q, ctx, c);
    }

    fn run_block(&self, qs: &[&[u8]], ctx: &mut QueryCtx, bc: &mut crate::query::BlockCollector) {
        search::run_block(self, qs, ctx, bc);
    }

    fn heap_bytes(&self) -> usize {
        self.middle.iter().map(|m| m.heap_bytes()).sum::<usize>()
            + self.sparse.heap_bytes()
            + self.post_offsets.heap_bytes()
            + self.post_ids.heap_bytes()
            + self.level_counts.heap_bytes()
    }

    fn node_count(&self) -> usize {
        self.level_counts[1..].iter().sum()
    }

    fn describe(&self) -> String {
        format!(
            "bST(b={}, L={}, lm={}, ls={}, layers={}, nodes={})",
            self.b,
            self.l,
            self.lm,
            self.ls,
            self.layer_string(),
            self.node_count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::sketch::SketchSet;
    use crate::trie::pointer::PointerTrie;
    use crate::util::Rng;

    fn random_rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect()
    }

    /// Clustered rows so that all three layers materialize.
    fn clustered_rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers = random_rows(b, l, 20, seed ^ 1);
        (0..n)
            .map(|_| {
                let mut row = centers[rng.below_usize(20)].clone();
                for _ in 0..rng.below_usize(3) {
                    let p = rng.below_usize(l);
                    row[p] = rng.below(1 << b) as u8;
                }
                row
            })
            .collect()
    }

    fn check_against_pt(rows: &[Vec<u8>], b: usize, l: usize, cfg: BstConfig, taus: &[usize]) {
        let set = SketchSet::from_rows(b, l, rows);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let bst = BstTrie::build(&ss, cfg);
        let mut rng = Rng::new(0xABCD);
        let mut queries: Vec<Vec<u8>> = (0..20)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        queries.extend(rows.iter().take(10).cloned());
        for q in &queries {
            for &tau in taus {
                let mut expect = pt.search(q, tau);
                let mut got = bst.search(q, tau);
                expect.sort();
                got.sort();
                assert_eq!(got, expect, "{} tau={tau} q={q:?}", bst.describe());
            }
        }
    }

    #[test]
    fn matches_pointer_trie_uniform() {
        for &(b, l) in &[(1usize, 16usize), (2, 8), (2, 16), (4, 8), (8, 4)] {
            let rows = random_rows(b, l, 600, (b * 31 + l) as u64);
            check_against_pt(&rows, b, l, BstConfig::default(), &[0, 1, 2, 3]);
        }
    }

    #[test]
    fn matches_pointer_trie_clustered() {
        for &(b, l) in &[(2usize, 16usize), (4, 12), (8, 8)] {
            let rows = clustered_rows(b, l, 800, (b * 7 + l) as u64);
            check_against_pt(&rows, b, l, BstConfig::default(), &[0, 1, 2, 4]);
        }
    }

    #[test]
    fn forced_layer_boundaries() {
        // Exercise all (lm, ls) corner combinations.
        let rows = clustered_rows(2, 10, 500, 99);
        for (lm, ls) in [(0, 10), (0, 0), (1, 5), (2, 10), (0, 5)] {
            let cfg = BstConfig { lm: Some(lm), ls: Some(ls), ..Default::default() };
            check_against_pt(&rows, 2, 10, cfg, &[0, 1, 3]);
        }
    }

    #[test]
    fn forced_reprs() {
        let rows = clustered_rows(2, 12, 500, 101);
        for repr in [Some(MiddleRepr::Table), Some(MiddleRepr::List), None] {
            let cfg = BstConfig { force_repr: repr, ..Default::default() };
            check_against_pt(&rows, 2, 12, cfg, &[1, 2]);
        }
    }

    #[test]
    fn dense_layer_forms_on_saturated_alphabet() {
        // With b=1, L=16 and 2000 random rows, the top levels are complete.
        let rows = random_rows(1, 16, 4000, 5);
        let set = SketchSet::from_rows(1, 16, &rows);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        assert!(bst.dense_depth() >= 4, "lm={} ({})", bst.dense_depth(), bst.describe());
    }

    #[test]
    fn duplicates_collapse_to_single_leaf() {
        let mut rows = vec![vec![1u8, 2, 3, 1, 2, 3, 1, 2]; 40];
        rows.extend(random_rows(2, 8, 100, 7));
        let set = SketchSet::from_rows(2, 8, &rows);
        let ss = SortedSketches::build(&set);
        let bst = BstTrie::build(&ss, BstConfig::default());
        let got = bst.search(&[1, 2, 3, 1, 2, 3, 1, 2], 0);
        assert!(got.len() >= 40);
        assert!((0..40u32).all(|i| got.contains(&i)));
    }

    #[test]
    fn smaller_than_pointer_trie() {
        let rows = clustered_rows(2, 16, 4000, 13);
        let set = SketchSet::from_rows(2, 16, &rows);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let bst = BstTrie::build(&ss, BstConfig::default());
        assert!(
            bst.heap_bytes() * 3 < pt.heap_bytes(),
            "bst={} pt={}",
            bst.heap_bytes(),
            pt.heap_bytes()
        );
    }
}
