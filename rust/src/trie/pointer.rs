//! Pointer trie (PT, §IV of the paper).
//!
//! The classical representation: explicit node records with child arrays.
//! Fast (direct pointers, no rank/select) but `O(t log t + t·b)` bits —
//! the paper's motivation for bST. Kept as (a) the Table III context and
//! (b) the correctness oracle for every succinct trie in the test suite.

use super::builder::SortedSketches;
use super::SketchTrie;
use crate::query::{Collector, QueryCtx};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::HeapSize;

#[derive(Debug)]
struct Node {
    /// Child node indices, ordered by edge label.
    children: Vec<u32>,
    /// Edge label from the parent (root: 0, unused).
    label: u8,
    /// For leaves: index into postings; `u32::MAX` otherwise.
    leaf: u32,
}

/// Pointer-based trie over a sketch database.
pub struct PointerTrie {
    nodes: Vec<Node>,
    post_offsets: Vec<u32>,
    post_ids: Vec<u32>,
    l: usize,
}

impl PointerTrie {
    /// Builds from the shared sorted form, level by level.
    pub fn build(ss: &SortedSketches) -> Self {
        let l = ss.set().l();
        let mut nodes = vec![Node { children: Vec::new(), label: 0, leaf: u32::MAX }];
        // prev_level[i] = node index of the i-th node on the previous level.
        let mut prev_level: Vec<u32> = vec![0];
        for level in 1..=l {
            let mut cur_level: Vec<u32> = Vec::with_capacity(ss.level_counts()[level]);
            let mut parent_idx = 0usize;
            let mut first_seen = false;
            for span in ss.nodes_at_level(level) {
                if span.first_sibling {
                    if first_seen {
                        parent_idx += 1;
                    }
                    first_seen = true;
                }
                let node_id = nodes.len() as u32;
                let leaf = if level == l { span.start as u32 } else { u32::MAX };
                nodes.push(Node { children: Vec::new(), label: span.label, leaf });
                nodes[prev_level[parent_idx] as usize].children.push(node_id);
                cur_level.push(node_id);
            }
            prev_level = cur_level;
        }
        let (post_offsets, post_ids) = ss.postings_parts();
        PointerTrie { nodes, post_offsets, post_ids, l }
    }

    fn dfs<C: Collector>(&self, node: u32, level: usize, dist: usize, q: &[u8], c: &mut C) {
        if dist > c.tau() {
            c.on_prune();
            return;
        }
        c.on_visit();
        let n = &self.nodes[node as usize];
        if level == self.l {
            let k = n.leaf as usize;
            let lo = self.post_offsets[k] as usize;
            let hi = self.post_offsets[k + 1] as usize;
            c.emit(&self.post_ids[lo..hi], dist);
            return;
        }
        let qc = q[level];
        for &child in &n.children {
            let ch = self.nodes[child as usize].label;
            let ndist = dist + usize::from(ch != qc);
            if ndist <= c.tau() {
                self.dfs(child, level + 1, ndist, q, c);
            } else {
                c.on_prune();
            }
        }
    }
}

impl Persist for PointerTrie {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.l);
        w.put_usize(self.nodes.len());
        for n in &self.nodes {
            w.put_u32s(&n.children);
            w.put_u8(n.label);
            w.put_u32(n.leaf);
        }
        w.put_u32s(&self.post_offsets);
        w.put_u32s(&self.post_ids);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let l = r.get_usize()?;
        let n_nodes = r.get_usize()?;
        ensure(l >= 1 && l <= 64 * 64 && n_nodes >= 2, || {
            format!("PT: bad shape L={l} nodes={n_nodes}")
        })?;
        // Each serialized node is >= 13 bytes (children length prefix +
        // label + leaf): bound the count by the bytes that actually
        // remain before allocating, mirroring ByteReader's own guard.
        ensure(n_nodes <= r.remaining() / 13, || {
            format!("PT: {n_nodes} nodes cannot fit in {} bytes", r.remaining())
        })?;
        let mut nodes = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let children = r.get_u32s()?;
            let label = r.get_u8()?;
            let leaf = r.get_u32()?;
            nodes.push(Node { children, label, leaf });
        }
        let post_offsets = r.get_u32s()?;
        let post_ids = r.get_u32s()?;
        let n_leaves = post_offsets.len().saturating_sub(1);
        super::validate_postings(&post_offsets, &post_ids, n_leaves)?;
        for (i, n) in nodes.iter().enumerate() {
            // children point strictly forward (never at the root), leaf
            // slots index the postings table.
            ensure(
                n.children
                    .iter()
                    .all(|&c| (c as usize) < n_nodes && c as usize > i),
                || format!("PT: node {i} has an out-of-range child"),
            )?;
            ensure(n.leaf == u32::MAX || (n.leaf as usize) < n_leaves, || {
                format!("PT: node {i} has leaf index {} of {n_leaves}", n.leaf)
            })?;
        }
        Ok(PointerTrie { nodes, post_offsets, post_ids, l })
    }
}

impl SketchTrie for PointerTrie {
    fn run<C: Collector>(&self, q: &[u8], _ctx: &mut QueryCtx, c: &mut C) {
        assert_eq!(q.len(), self.l);
        self.dfs(0, 0, 0, q, c);
    }

    fn heap_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<Node>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.heap_bytes())
                .sum::<usize>()
            + self.post_offsets.heap_bytes()
            + self.post_ids.heap_bytes()
    }

    fn node_count(&self) -> usize {
        self.nodes.len() - 1 // exclude root, matching the paper's t
    }

    fn describe(&self) -> String {
        format!("PT(nodes={}, L={})", self.nodes.len() - 1, self.l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::sketch::SketchSet;
    use crate::util::Rng;

    fn build_random(
        b: usize,
        l: usize,
        n: usize,
        seed: u64,
    ) -> (SketchSet, Vec<Vec<u8>>) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (SketchSet::from_rows(b, l, &rows), rows)
    }

    #[test]
    fn search_matches_linear_scan() {
        let (set, rows) = build_random(2, 8, 400, 5);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let mut rng = Rng::new(17);
        for _ in 0..30 {
            let q: Vec<u8> = (0..8).map(|_| rng.below(4) as u8).collect();
            for tau in 0..5 {
                let mut got = pt.search(&q, tau);
                got.sort();
                let expect: Vec<u32> = (0..rows.len())
                    .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, expect, "tau={tau} q={q:?}");
            }
        }
    }

    #[test]
    fn exact_search_tau_zero() {
        let (set, rows) = build_random(4, 6, 200, 7);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        for (i, row) in rows.iter().enumerate() {
            let got = pt.search(row, 0);
            assert!(got.contains(&(i as u32)));
            for &id in &got {
                assert_eq!(&rows[id as usize], row);
            }
        }
    }

    #[test]
    fn node_count_matches_builder() {
        let (set, _) = build_random(2, 6, 300, 9);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        assert_eq!(pt.node_count(), ss.total_nodes());
    }

    #[test]
    fn tau_full_length_returns_everything() {
        let (set, rows) = build_random(2, 5, 100, 11);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let q = vec![0u8; 5];
        let mut got = pt.search(&q, 5);
        got.sort();
        assert_eq!(got, (0..rows.len() as u32).collect::<Vec<_>>());
    }
}
