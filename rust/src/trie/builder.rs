//! Shared trie-construction machinery.
//!
//! All trie representations are derived from the same intermediate form:
//! the database sorted lexicographically, deduplicated into *distinct*
//! sketches with id postings, plus the LCP (longest-common-prefix) array
//! of adjacent distinct sketches.
//!
//! The LCP array determines the entire level-wise topology in O(1) per
//! node, with no pointer trie ever materialized:
//!
//! * distinct sketch `k` starts a new node at level `ℓ` iff
//!   `lcp[k] < ℓ` (with `lcp[0] = -1` for the sentinel);
//! * hence `t_ℓ = #{k : lcp[k] < ℓ}` (node counts per level),
//! * the node starting at `k` on level `ℓ` has edge label
//!   `char(k, ℓ-1)` and is the first of its siblings iff `lcp[k] < ℓ-1`.

use crate::sketch::SketchSet;
use crate::util::HeapSize;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global count of [`SortedSketches::build`] invocations. Diagnostics
/// only: the snapshot tests pin down that `Engine::load` serves without
/// re-running construction (one relaxed increment per build — noise next
/// to the sort it precedes).
static BUILD_INVOCATIONS: AtomicU64 = AtomicU64::new(0);

/// How many times [`SortedSketches::build`] has run in this process.
pub fn build_invocations() -> u64 {
    BUILD_INVOCATIONS.load(Ordering::Relaxed)
}

/// Sorted + deduplicated database with LCP array and id postings.
pub struct SortedSketches<'a> {
    set: &'a SketchSet,
    /// Original id of each distinct sketch, lexicographically sorted.
    reps: Vec<u32>,
    /// `lcp[k]` = LCP(reps[k-1], reps[k]) in characters; `lcp[0] = -1`.
    lcps: Vec<i32>,
    /// Postings: ids of all sketches equal to distinct sketch `k` live at
    /// `post_ids[post_offsets[k] .. post_offsets[k+1]]`.
    post_offsets: Vec<u32>,
    post_ids: Vec<u32>,
    /// `t_ℓ` for `ℓ = 0..=L`.
    level_counts: Vec<usize>,
}

/// One trie node on a level: the half-open range of distinct sketches it
/// covers, its incoming edge label, and whether it is the first child of
/// its parent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeSpan {
    pub start: usize,
    pub end: usize,
    pub label: u8,
    pub first_sibling: bool,
}

impl<'a> SortedSketches<'a> {
    /// Sorts, deduplicates and indexes `set`.
    pub fn build(set: &'a SketchSet) -> Self {
        BUILD_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
        let n = set.n();
        assert!(n > 0, "empty database");
        let perm = set.sorted_permutation();

        let mut reps: Vec<u32> = Vec::new();
        let mut post_offsets: Vec<u32> = Vec::new();
        let mut post_ids: Vec<u32> = Vec::with_capacity(n);
        let mut lcps: Vec<i32> = Vec::new();

        for (idx, &id) in perm.iter().enumerate() {
            let is_new = idx == 0
                || set.cmp_sketches(perm[idx - 1] as usize, id as usize)
                    != std::cmp::Ordering::Equal;
            if is_new {
                if idx == 0 {
                    lcps.push(-1);
                } else {
                    lcps.push(set.lcp(perm[idx - 1] as usize, id as usize) as i32);
                }
                reps.push(id);
                post_offsets.push(post_ids.len() as u32);
            }
            post_ids.push(id);
        }
        post_offsets.push(post_ids.len() as u32);

        // t_ℓ = #{k : lcp[k] < ℓ}; computed via a histogram of lcp values.
        let l = set.l();
        let mut hist = vec![0usize; l + 1]; // hist[v] = #lcps equal to v (v>=0)
        let mut below_zero = 0usize;
        for &v in &lcps {
            if v < 0 {
                below_zero += 1;
            } else {
                hist[v as usize] += 1;
            }
        }
        let mut level_counts = Vec::with_capacity(l + 1);
        level_counts.push(1); // t_0: the root
        let mut acc = below_zero;
        for lv in 1..=l {
            // lcp < lv ⇔ lcp <= lv-1
            acc += hist[lv - 1];
            level_counts.push(acc);
        }
        debug_assert_eq!(level_counts[l], reps.len());

        SortedSketches { set, reps, lcps, post_offsets, post_ids, level_counts }
    }

    #[inline]
    pub fn set(&self) -> &SketchSet {
        self.set
    }

    /// Number of distinct sketches (= leaves `t_L`).
    #[inline]
    pub fn n_distinct(&self) -> usize {
        self.reps.len()
    }

    /// `t_ℓ` for `ℓ ∈ [0, L]`.
    #[inline]
    pub fn level_counts(&self) -> &[usize] {
        &self.level_counts
    }

    /// Total node count `t = Σ_{ℓ>=1} t_ℓ` (the root is conventionally not
    /// counted as a labeled node, matching the paper's `t`).
    pub fn total_nodes(&self) -> usize {
        self.level_counts[1..].iter().sum()
    }

    /// Character `pos` of distinct sketch `k`.
    #[inline]
    pub fn char_of(&self, k: usize, pos: usize) -> u8 {
        self.set.get_char(self.reps[k] as usize, pos)
    }

    /// Ids equal to distinct sketch `k`.
    #[inline]
    pub fn postings(&self, k: usize) -> &[u32] {
        let lo = self.post_offsets[k] as usize;
        let hi = self.post_offsets[k + 1] as usize;
        &self.post_ids[lo..hi]
    }

    /// Moves postings out (offsets, ids) for tries that own them.
    pub fn postings_parts(&self) -> (Vec<u32>, Vec<u32>) {
        (self.post_offsets.clone(), self.post_ids.clone())
    }

    /// Iterates the nodes of level `ℓ >= 1` in lexicographic order.
    pub fn nodes_at_level(&self, level: usize) -> impl Iterator<Item = NodeSpan> + '_ {
        assert!((1..=self.set.l()).contains(&level));
        let n = self.n_distinct();
        let mut k = 0usize;
        std::iter::from_fn(move || {
            if k >= n {
                return None;
            }
            let start = k;
            let first_sibling = self.lcps[k] < level as i32 - 1;
            let label = self.char_of(k, level - 1);
            k += 1;
            while k < n && self.lcps[k] >= level as i32 {
                k += 1;
            }
            Some(NodeSpan { start, end: k, label, first_sibling })
        })
    }

    /// The suffix characters `[from, L)` of distinct sketch `k`.
    pub fn suffix(&self, k: usize, from: usize) -> Vec<u8> {
        (from..self.set.l()).map(|p| self.char_of(k, p)).collect()
    }

    pub fn heap_bytes(&self) -> usize {
        self.reps.heap_bytes()
            + self.lcps.heap_bytes()
            + self.post_offsets.heap_bytes()
            + self.post_ids.heap_bytes()
            + self.level_counts.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::BTreeSet;

    fn random_set(b: usize, l: usize, n: usize, seed: u64) -> (SketchSet, Vec<Vec<u8>>) {
        let mut rng = Rng::new(seed);
        // small alphabet + short length → plenty of duplicates
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (SketchSet::from_rows(b, l, &rows), rows)
    }

    #[test]
    fn distinct_and_postings_partition_ids() {
        let (set, rows) = random_set(2, 4, 500, 1);
        let ss = SortedSketches::build(&set);
        let expect_distinct: BTreeSet<Vec<u8>> = rows.iter().cloned().collect();
        assert_eq!(ss.n_distinct(), expect_distinct.len());
        // every id appears exactly once across postings
        let mut seen = vec![false; 500];
        for k in 0..ss.n_distinct() {
            for &id in ss.postings(k) {
                assert!(!seen[id as usize], "duplicate id {id}");
                seen[id as usize] = true;
                assert_eq!(rows[id as usize], set.row(ss.reps[k] as usize));
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn reps_sorted_lexicographically() {
        let (set, rows) = random_set(4, 6, 300, 2);
        let ss = SortedSketches::build(&set);
        for w in ss.reps.windows(2) {
            assert!(rows[w[0] as usize] < rows[w[1] as usize]);
        }
    }

    #[test]
    fn level_counts_match_prefix_sets() {
        let (set, rows) = random_set(2, 6, 400, 3);
        let ss = SortedSketches::build(&set);
        let counts = ss.level_counts();
        assert_eq!(counts[0], 1);
        for lv in 1..=6 {
            let prefixes: BTreeSet<Vec<u8>> =
                rows.iter().map(|r| r[..lv].to_vec()).collect();
            assert_eq!(counts[lv], prefixes.len(), "level {lv}");
        }
        assert_eq!(counts[6], ss.n_distinct());
    }

    #[test]
    fn nodes_at_level_cover_and_label_correctly() {
        let (set, rows) = random_set(2, 5, 300, 4);
        let ss = SortedSketches::build(&set);
        for lv in 1..=5usize {
            let spans: Vec<NodeSpan> = ss.nodes_at_level(lv).collect();
            assert_eq!(spans.len(), ss.level_counts()[lv], "level {lv}");
            // spans tile [0, n_distinct)
            assert_eq!(spans[0].start, 0);
            assert_eq!(spans.last().unwrap().end, ss.n_distinct());
            for w in spans.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            // label == the lv-1 char of every distinct sketch in the span
            for s in &spans {
                for k in s.start..s.end {
                    assert_eq!(ss.char_of(k, lv - 1), s.label);
                }
            }
            // first_sibling marks parent-group starts: count = t_{lv-1}
            let firsts = spans.iter().filter(|s| s.first_sibling).count();
            assert_eq!(firsts, ss.level_counts()[lv - 1], "level {lv}");
            let _ = rows;
        }
    }

    #[test]
    fn all_identical_sketches() {
        let rows = vec![vec![1u8, 2, 3]; 50];
        let set = SketchSet::from_rows(2, 3, &rows);
        let ss = SortedSketches::build(&set);
        assert_eq!(ss.n_distinct(), 1);
        assert_eq!(ss.level_counts(), &[1, 1, 1, 1]);
        assert_eq!(ss.postings(0).len(), 50);
    }

    #[test]
    fn single_sketch() {
        let set = SketchSet::from_rows(8, 4, &[vec![200u8, 3, 0, 255]]);
        let ss = SortedSketches::build(&set);
        assert_eq!(ss.n_distinct(), 1);
        assert_eq!(ss.total_nodes(), 4);
        assert_eq!(ss.suffix(0, 2), vec![0, 255]);
    }
}
