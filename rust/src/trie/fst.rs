//! Fast Succinct Trie baseline (Zhang et al., SIGMOD 2018 — SuRF).
//!
//! The second succinct baseline of Table III. FST splits the trie at a
//! cutoff level: the (few, wide) top levels use **LOUDS-DENSE** — a
//! `2^b`-bit child bitmap per node — and the (many, narrow) bottom levels
//! use **LOUDS-SPARSE** — label bytes + LOUDS first-sibling bits.
//!
//! Our implementation reuses the bST middle-layer encodings (TABLE ≙
//! LOUDS-DENSE, LIST ≙ LOUDS-SPARSE) at every level, with the cutoff
//! chosen by SuRF's size-ratio rule: the dense top may use at most
//! `1/R` of the bits the sparse encoding of those levels would
//! (`R = 16` here). What FST *lacks* relative to bST — the implicit
//! dense-complete layer and the collapsed sparse suffixes — is exactly
//! the gap Table III measures.

use super::builder::SortedSketches;
use super::bst::MiddleRepr;
use super::SketchTrie;
use crate::query::{Collector, QueryCtx};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, U32s};
use crate::util::HeapSize;

// Reuse the per-level encodings from the bst middle layer.
use super::bst::middle::MiddleLevel;

/// Two-layer FST over a sketch database.
pub struct FstTrie {
    /// Per-level encodings, level 1 at index 0.
    levels: Vec<MiddleLevel>,
    /// First LOUDS-SPARSE level (1-based); levels below are DENSE.
    cutoff: usize,
    b: usize,
    l: usize,
    t: usize,
    post_offsets: U32s,
    post_ids: U32s,
}

impl FstTrie {
    /// Size-ratio parameter from SuRF (dense-to-sparse budget).
    pub const SIZE_RATIO: usize = 16;

    pub fn build(ss: &SortedSketches) -> Self {
        let set = ss.set();
        let (b, l) = (set.b(), set.l());
        let sigma = 1usize << b;
        let counts = ss.level_counts();

        // SuRF rule: the dense (bitmap) top may spend at most 1/R of the
        // bits an all-sparse encoding of the whole trie would use —
        // grow the dense prefix while the cumulative bitmap size stays
        // within that budget.
        let sparse_total: u128 = (1..=l)
            .map(|lv| (b as u128 + 1) * counts[lv] as u128)
            .sum();
        let budget = sparse_total / Self::SIZE_RATIO as u128;
        let mut cutoff = 1usize;
        let mut dense_acc: u128 = 0;
        for lv in 1..=l {
            let dense_bits = sigma as u128 * counts[lv - 1] as u128;
            if dense_acc + dense_bits <= budget && dense_bits < u32::MAX as u128 {
                dense_acc += dense_bits;
                cutoff = lv + 1;
            } else {
                break;
            }
        }

        let levels = (1..=l)
            .map(|lv| {
                let repr = if lv < cutoff { MiddleRepr::Table } else { MiddleRepr::List };
                MiddleLevel::build(ss, lv, Some(repr))
            })
            .collect();

        let (post_offsets, post_ids) = ss.postings_parts();
        FstTrie {
            levels,
            cutoff,
            b,
            l,
            t: ss.total_nodes(),
            post_offsets: post_offsets.into(),
            post_ids: post_ids.into(),
        }
    }

    /// First sparse level (1-based).
    pub fn cutoff(&self) -> usize {
        self.cutoff
    }

    fn dfs<C: Collector>(
        &self,
        u: usize,
        level: usize,
        dist: usize,
        q: &[u8],
        ctx: &mut QueryCtx,
        c: &mut C,
    ) {
        let tau = c.tau();
        if dist > tau {
            c.on_prune();
            return;
        }
        c.on_visit();
        if level == self.l {
            let lo = self.post_offsets[u] as usize;
            let hi = self.post_offsets[u + 1] as usize;
            c.emit(&self.post_ids[lo..hi], dist);
            return;
        }
        let ml = &self.levels[level];
        let qc = q[level];
        if dist == tau {
            if let Some(child) = ml.child_with_label(u, qc) {
                self.dfs(child, level + 1, dist, q, ctx, c);
            }
            return;
        }
        // Stage children in this level's segment of the shared buffer.
        let off = ctx.kid_off(level);
        let mut n_kids = 0usize;
        {
            let kids = &mut ctx.kids;
            ml.children(u, |child, ch| {
                kids[off + n_kids] = (child as u32, ch);
                n_kids += 1;
            });
        }
        for i in 0..n_kids {
            let (child, ch) = ctx.kids[off + i];
            let nd = dist + usize::from(ch != qc);
            if nd <= c.tau() {
                self.dfs(child as usize, level + 1, nd, q, ctx, c);
            } else {
                c.on_prune();
            }
        }
    }
}

impl Persist for FstTrie {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.l);
        w.put_usize(self.t);
        w.put_usize(self.cutoff);
        for ml in &self.levels {
            ml.write_into(w);
        }
        w.put_u32s(&self.post_offsets);
        w.put_u32s(&self.post_ids);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let l = r.get_usize()?;
        let t = r.get_usize()?;
        let cutoff = r.get_usize()?;
        ensure(
            (1..=8).contains(&b)
                && l >= 1
                && l <= 64 * 64 // caps the level vec before allocation
                && (1..=l + 1).contains(&cutoff),
            || format!("FST: bad shape b={b} L={l} cutoff={cutoff}"),
        )?;
        let mut levels = Vec::with_capacity(l);
        for _ in 0..l {
            levels.push(MiddleLevel::read_from(r)?);
        }
        let post_offsets = r.get_u32s_ref()?;
        let post_ids = r.get_u32s_ref()?;
        // Validate the per-level chain: level ℓ's encoding must cover the
        // previous level's node count (the root level has one parent).
        let mut t_prev = 1usize;
        let mut total = 0usize;
        for (i, ml) in levels.iter().enumerate() {
            let t_cur = ml.node_count();
            ml.validate_level(b, t_prev, t_cur)?;
            ensure(
                (i + 1 < cutoff) == matches!(ml.repr(), MiddleRepr::Table),
                || format!("FST: level {} repr disagrees with cutoff {cutoff}", i + 1),
            )?;
            total += t_cur;
            t_prev = t_cur;
        }
        ensure(total == t, || format!("FST: level node counts sum to {total}, not t={t}"))?;
        let n_leaves = t_prev;
        super::validate_postings(&post_offsets, &post_ids, n_leaves)?;
        Ok(FstTrie { levels, cutoff, b, l, t, post_offsets, post_ids })
    }
}

impl SketchTrie for FstTrie {
    fn run<C: Collector>(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut C) {
        assert_eq!(q.len(), self.l);
        ctx.ensure_kids(1usize << self.b, self.l);
        self.dfs(0, 0, 0, q, ctx, c);
    }

    fn heap_bytes(&self) -> usize {
        self.levels.iter().map(|m| m.heap_bytes()).sum::<usize>()
            + self.post_offsets.heap_bytes()
            + self.post_ids.heap_bytes()
    }

    fn node_count(&self) -> usize {
        self.t
    }

    fn describe(&self) -> String {
        format!(
            "FST(nodes={}, L={}, dense<{}), R={}",
            self.t,
            self.l,
            self.cutoff,
            Self::SIZE_RATIO
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchSet;
    use crate::trie::pointer::PointerTrie;
    use crate::util::Rng;

    fn check(b: usize, l: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let fst = FstTrie::build(&ss);
        for _ in 0..15 {
            let q: Vec<u8> = (0..l).map(|_| rng.below(1 << b) as u8).collect();
            for tau in [0usize, 1, 2, 4] {
                let mut a = pt.search(&q, tau);
                let mut c = fst.search(&q, tau);
                a.sort();
                c.sort();
                assert_eq!(a, c, "b={b} l={l} tau={tau}");
            }
        }
    }

    #[test]
    fn matches_pointer_trie() {
        check(2, 8, 500, 21);
        check(4, 6, 400, 22);
        check(8, 4, 300, 23);
    }

    #[test]
    fn has_dense_top_on_random_data() {
        let mut rng = Rng::new(25);
        let rows: Vec<Vec<u8>> = (0..4000)
            .map(|_| (0..12).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 12, &rows);
        let ss = SortedSketches::build(&set);
        let fst = FstTrie::build(&ss);
        assert!(fst.cutoff() > 1, "expected a dense top layer: {}", fst.describe());
        assert!(
            fst.cutoff() <= 12,
            "dense budget must not cover the whole trie: {}",
            fst.describe()
        );
    }
}
