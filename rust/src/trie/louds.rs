//! Monolithic LOUDS-trie baseline (Jacobson 1989; Delpratt et al. 2006).
//!
//! The first succinct baseline of Table III (the paper used the TX
//! library). One global bit string holds every node's degree in unary,
//! level by level, preceded by a super-root (`10`) so the standard
//! child-navigation formulas apply:
//!
//! ```text
//! B = 1 0 | 1^deg(root) 0 | 1^deg(n1) 0 | ...        (level order)
//! ```
//!
//! Node `u` (level-order rank, 0 = root) has its children encoded in the
//! 0-terminated group after zero #u: children ids are the 1s' ranks minus
//! one (the super-root's edge). Navigation costs one `select0` + ranks per
//! node — the global selects over a `~2t`-bit vector are exactly why LOUDS
//! trails bST in Table III.
//!
//! Space: `(b + 2)·t + o(t)` bits (2 topology bits + b label bits/node).

use super::builder::SortedSketches;
use super::SketchTrie;
use crate::query::{Collector, QueryCtx};
use crate::bits::rsvec::SelectMode;
use crate::bits::{BitVec, IntVec, RsBitVec};
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, U32s};
use crate::util::HeapSize;

/// Classic LOUDS representation of a sketch trie.
pub struct LoudsTrie {
    /// Topology bits with rank1/select0 support.
    bits: RsBitVec,
    /// Edge labels of nodes 1.. (level order; root excluded).
    labels: IntVec,
    /// Total nodes (excluding super-root).
    t: usize,
    /// Leaves = last `t_L` nodes in level order.
    n_leaves: usize,
    l: usize,
    post_offsets: U32s,
    post_ids: U32s,
}

impl LoudsTrie {
    pub fn build(ss: &SortedSketches) -> Self {
        let set = ss.set();
        let (b, l) = (set.b(), set.l());
        let t = ss.total_nodes();
        let n_leaves = ss.n_distinct();

        let mut bits = BitVec::with_capacity(2 * t + 4);
        // super-root: one child (the root)
        bits.push(true);
        bits.push(false);
        let mut labels = IntVec::with_capacity(b, t);

        // Emit degrees level by level. The degree of node u at level ℓ is
        // the number of level-(ℓ+1) spans in its child group; groups are
        // delimited by first_sibling flags of the next level.
        for level in 0..l {
            if level + 1 <= l {
                let mut deg = 0usize;
                let mut any = false;
                for span in ss.nodes_at_level(level + 1) {
                    if span.first_sibling && any {
                        // close previous node's group
                        for _ in 0..deg {
                            bits.push(true);
                        }
                        bits.push(false);
                        deg = 0;
                    }
                    any = true;
                    deg += 1;
                    labels.push(span.label as u64);
                }
                if any {
                    for _ in 0..deg {
                        bits.push(true);
                    }
                    bits.push(false);
                }
            }
        }
        // leaves (level L) have degree 0
        for _ in 0..n_leaves {
            bits.push(false);
        }

        // Sanity: ones = t + 1 (every node incl. root appears once as a
        // child), zeros = t + 2 (one terminator per node + super-root).
        debug_assert_eq!(labels.len(), t);
        debug_assert_eq!(bits.len(), 2 * t + 3);

        let (post_offsets, post_ids) = ss.postings_parts();
        LoudsTrie {
            bits: RsBitVec::new(bits, SelectMode::Both),
            labels,
            t,
            n_leaves,
            l,
            post_offsets: post_offsets.into(),
            post_ids: post_ids.into(),
        }
    }

    /// First/last+1 child ids of node `u` (level-order id, 0 = root).
    #[inline]
    fn child_range(&self, u: usize) -> (usize, usize) {
        // group of node u sits between zero #u and zero #(u+1).
        let lo_pos = self.bits.select0(u) + 1;
        let hi_pos = self.bits.select0(u + 1);
        if lo_pos >= hi_pos {
            return (0, 0); // leaf
        }
        // child id of the 1 at position p = rank1(p+1) - 1 (super-root).
        let first = self.bits.rank1(lo_pos + 1) - 1;
        (first, first + (hi_pos - lo_pos))
    }

    /// Level-order id of the first leaf.
    #[inline]
    fn first_leaf(&self) -> usize {
        self.t + 1 - self.n_leaves // +1: root is node 0
    }

    fn dfs<C: Collector>(&self, u: usize, level: usize, dist: usize, q: &[u8], c: &mut C) {
        if dist > c.tau() {
            c.on_prune();
            return;
        }
        c.on_visit();
        if level == self.l {
            let k = u - self.first_leaf();
            let lo = self.post_offsets[k] as usize;
            let hi = self.post_offsets[k + 1] as usize;
            c.emit(&self.post_ids[lo..hi], dist);
            return;
        }
        let (lo, hi) = self.child_range(u);
        let qc = q[level];
        for child in lo..hi {
            let ch = self.labels.get(child - 1) as u8;
            let nd = dist + usize::from(ch != qc);
            if nd <= c.tau() {
                self.dfs(child, level + 1, nd, q, c);
            } else {
                c.on_prune();
            }
        }
    }
}

impl Persist for LoudsTrie {
    fn write_into(&self, w: &mut ByteWriter) {
        self.bits.write_into(w);
        self.labels.write_into(w);
        w.put_usize(self.t);
        w.put_usize(self.n_leaves);
        w.put_usize(self.l);
        w.put_u32s(&self.post_offsets);
        w.put_u32s(&self.post_ids);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let bits = RsBitVec::read_from(r)?;
        let labels = IntVec::read_from(r)?;
        let t = r.get_usize()?;
        let n_leaves = r.get_usize()?;
        let l = r.get_usize()?;
        let post_offsets = r.get_u32s_ref()?;
        let post_ids = r.get_u32s_ref()?;
        ensure(l >= 1 && n_leaves >= 1 && n_leaves <= t, || {
            format!("LOUDS: bad shape t={t} leaves={n_leaves} L={l}")
        })?;
        ensure(labels.len() == t && labels.width() <= 8, || {
            format!("LOUDS: {} labels (width {}) for {t} nodes", labels.len(), labels.width())
        })?;
        ensure(bits.len() == 2 * t + 3 && bits.count_ones() == t + 1, || {
            format!("LOUDS: topology {} bits / {} ones for t={t}", bits.len(), bits.count_ones())
        })?;
        // Navigation needs select0 (group seek) and rank over the ones.
        ensure(bits.select0_enabled(), || "LOUDS: select0 directory missing".to_string())?;
        super::validate_postings(&post_offsets, &post_ids, n_leaves)?;
        Ok(LoudsTrie { bits, labels, t, n_leaves, l, post_offsets, post_ids })
    }
}

impl SketchTrie for LoudsTrie {
    fn run<C: Collector>(&self, q: &[u8], _ctx: &mut QueryCtx, c: &mut C) {
        assert_eq!(q.len(), self.l);
        self.dfs(0, 0, 0, q, c);
    }

    fn heap_bytes(&self) -> usize {
        self.bits.heap_bytes()
            + self.labels.heap_bytes()
            + self.post_offsets.heap_bytes()
            + self.post_ids.heap_bytes()
    }

    fn node_count(&self) -> usize {
        self.t
    }

    fn describe(&self) -> String {
        format!("LOUDS(nodes={}, L={}, bits={})", self.t, self.l, self.bits.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchSet;
    use crate::trie::pointer::PointerTrie;
    use crate::util::Rng;

    fn check(b: usize, l: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(b, l, &rows);
        let ss = SortedSketches::build(&set);
        let pt = PointerTrie::build(&ss);
        let louds = LoudsTrie::build(&ss);
        assert_eq!(louds.node_count(), pt.node_count());
        for _ in 0..15 {
            let q: Vec<u8> = (0..l).map(|_| rng.below(1 << b) as u8).collect();
            for tau in [0usize, 1, 2, 4] {
                let mut a = pt.search(&q, tau);
                let mut c = louds.search(&q, tau);
                a.sort();
                c.sort();
                assert_eq!(a, c, "b={b} l={l} tau={tau}");
            }
        }
    }

    #[test]
    fn matches_pointer_trie() {
        check(2, 8, 500, 1);
        check(4, 6, 400, 2);
        check(8, 4, 300, 3);
        check(1, 12, 600, 4);
    }

    #[test]
    fn single_path_trie() {
        let rows = vec![vec![1u8, 0, 3, 2]; 5];
        let set = SketchSet::from_rows(2, 4, &rows);
        let ss = SortedSketches::build(&set);
        let louds = LoudsTrie::build(&ss);
        assert_eq!(louds.node_count(), 4);
        let got = louds.search(&[1, 0, 3, 2], 0);
        assert_eq!(got.len(), 5);
        assert!(louds.search(&[1, 0, 3, 3], 0).is_empty());
        assert_eq!(louds.search(&[1, 0, 3, 3], 1).len(), 5);
    }

    #[test]
    fn space_near_b_plus_2_bits_per_node() {
        let mut rng = Rng::new(9);
        let rows: Vec<Vec<u8>> = (0..3000)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 16, &rows);
        let ss = SortedSketches::build(&set);
        let louds = LoudsTrie::build(&ss);
        let t = louds.node_count();
        let structure_bytes = louds.bits.heap_bytes() + louds.labels.heap_bytes();
        let ideal_bits = (2 + 2) * t; // (b+2)·t for b=2
        assert!(structure_bytes * 8 >= ideal_bits);
        assert!(
            (structure_bytes * 8) as f64 <= ideal_bits as f64 * 1.35,
            "{} vs ideal {}",
            structure_bytes * 8,
            ideal_bits
        );
    }
}
