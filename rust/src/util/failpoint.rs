//! Deterministic fault injection for crash-recovery tests.
//!
//! A failpoint is a named sequence point (`"wal.sync"`,
//! `"shard.worker"`, …) checked by production code via
//! [`check`]. In normal builds `check` is a compiled-out no-op; under
//! `cfg(test)` or the `failpoints` feature a test can [`arm`] a point
//! with an [`Action`] — panic, synthesized I/O error, short write, or
//! process exit — that fires for a configured window of hits. This is
//! what drives the WAL torn-tail tests, the worker-restart tests, and
//! the mid-save atomicity tests without any timing dependence: the
//! fault fires at exactly the `skip`-th hit, every run.
//!
//! The registry is process-global, but tests run concurrently in one
//! process, so every site passes a *context* string (the WAL base
//! path, the snapshot scratch path, the engine instance tag) and
//! [`arm_scoped`] restricts firing to contexts containing a filter
//! substring — a test arming its own uniquely-named engine or temp
//! directory cannot trip a neighbouring test's site. [`clear`] /
//! [`clear_all`] disarm.
//!
//! Binaries built with `--features failpoints` additionally read the
//! `BST_FAILPOINTS` environment variable at startup (see
//! [`init_from_env`]) so the CI crash gate can inject faults into a
//! real `bst serve` process.

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Panic with the failpoint's name (worker-isolation tests).
    Panic,
    /// Surface a synthesized `io::Error` (fsync/write failure tests).
    Error,
    /// Truncate the write to this many bytes *without* the caller's
    /// usual cleanup — simulates power loss mid-append (torn tail).
    ShortWrite(usize),
    /// `std::process::exit(3)` — a mid-save kill for subprocess tests.
    Exit,
}

#[cfg(any(test, feature = "failpoints"))]
mod imp {
    use super::Action;
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    struct Armed {
        /// Fires only for contexts containing this substring.
        filter: Option<String>,
        /// Matching hits to let through before firing.
        skip: u64,
        /// Fires this many times once reached, then passes again.
        times: u64,
        hits: u64,
        action: Action,
    }

    fn registry() -> &'static Mutex<HashMap<String, Armed>> {
        static REG: OnceLock<Mutex<HashMap<String, Armed>>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Arms `name` for every context: the first `skip` hits pass, the
    /// next `times` hits fire `action`, later hits pass again.
    pub fn arm(name: &str, skip: u64, times: u64, action: Action) {
        arm_entry(name, None, skip, times, action);
    }

    /// [`arm`], but only hits whose context contains `filter` count or
    /// fire — scopes the fault to one test's engine/WAL/file.
    pub fn arm_scoped(name: &str, filter: &str, skip: u64, times: u64, action: Action) {
        arm_entry(name, Some(filter.to_string()), skip, times, action);
    }

    fn arm_entry(name: &str, filter: Option<String>, skip: u64, times: u64, action: Action) {
        registry()
            .lock()
            .unwrap()
            .insert(name.to_string(), Armed { filter, skip, times, hits: 0, action });
    }

    /// Disarms `name` (no-op when not armed).
    pub fn clear(name: &str) {
        registry().lock().unwrap().remove(name);
    }

    /// Disarms everything.
    pub fn clear_all() {
        registry().lock().unwrap().clear();
    }

    /// Called from production sites: `Some(action)` when the point
    /// fires on this hit. [`Action::Panic`] and [`Action::Exit`] are
    /// executed here so call sites only need to handle data actions.
    pub fn check(name: &str, ctx: &str) -> Option<Action> {
        let action = {
            let mut reg = registry().lock().unwrap();
            let armed = reg.get_mut(name)?;
            if let Some(f) = &armed.filter {
                if !ctx.contains(f.as_str()) {
                    return None;
                }
            }
            let hit = armed.hits;
            armed.hits += 1;
            if hit < armed.skip || hit >= armed.skip + armed.times {
                return None;
            }
            armed.action
        };
        match action {
            Action::Panic => panic!("failpoint {name} fired at {ctx}: injected panic"),
            Action::Exit => std::process::exit(3),
            other => Some(other),
        }
    }

    /// Arms failpoints from `BST_FAILPOINTS` (builds with the
    /// `failpoints` feature call this at startup). Entries are
    /// `;`-separated: `name=action[(arg)][@skip[+times]]`, with action
    /// one of `panic` / `error` / `exit` / `short(bytes)`; `skip`
    /// defaults to 0 and `times` to 1. Example:
    /// `wal.sync=error@25;shard.worker=panic@100+1`. Malformed entries
    /// are ignored (the injecting test asserts on observed effects).
    pub fn init_from_env() {
        let Ok(spec) = std::env::var("BST_FAILPOINTS") else {
            return;
        };
        for entry in spec.split(';').filter(|e| !e.is_empty()) {
            let Some((name, rest)) = entry.split_once('=') else {
                continue;
            };
            let (action_str, window) = match rest.split_once('@') {
                Some((a, w)) => (a, Some(w)),
                None => (rest, None),
            };
            let action = if action_str == "panic" {
                Action::Panic
            } else if action_str == "error" {
                Action::Error
            } else if action_str == "exit" {
                Action::Exit
            } else if let Some(arg) = action_str
                .strip_prefix("short(")
                .and_then(|s| s.strip_suffix(')'))
            {
                match arg.parse() {
                    Ok(n) => Action::ShortWrite(n),
                    Err(_) => continue,
                }
            } else {
                continue;
            };
            let (skip, times) = match window {
                None => (0, 1),
                Some(w) => match w.split_once('+') {
                    None => match w.parse() {
                        Ok(s) => (s, 1),
                        Err(_) => continue,
                    },
                    Some((s, t)) => match (s.parse(), t.parse()) {
                        (Ok(s), Ok(t)) => (s, t),
                        _ => continue,
                    },
                },
            };
            arm(name.trim(), skip, times, action);
        }
    }

    /// Synthesized error for [`Action::Error`] sites.
    pub fn io_error(name: &str) -> std::io::Error {
        std::io::Error::other(format!("failpoint {name} fired: injected io error"))
    }
}

#[cfg(any(test, feature = "failpoints"))]
pub use imp::{arm, arm_scoped, check, clear, clear_all, init_from_env, io_error};

/// Release builds without the `failpoints` feature compile every site
/// down to nothing.
#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn check(_name: &str, _ctx: &str) -> Option<Action> {
    None
}

#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn init_from_env() {}

#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn io_error(_name: &str) -> std::io::Error {
    unreachable!("failpoint actions never fire without the failpoints feature")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_is_silent() {
        assert_eq!(check("fp.test.unarmed", ""), None);
    }

    #[test]
    fn skip_times_window() {
        arm("fp.test.window", 2, 1, Action::Error);
        assert_eq!(check("fp.test.window", "x"), None);
        assert_eq!(check("fp.test.window", "x"), None);
        assert_eq!(check("fp.test.window", "x"), Some(Action::Error));
        assert_eq!(check("fp.test.window", "x"), None);
        clear("fp.test.window");
    }

    #[test]
    fn scoped_filter_ignores_other_contexts() {
        arm_scoped("fp.test.scoped", "mine", 0, 1, Action::Error);
        // Non-matching contexts neither fire nor consume hits.
        assert_eq!(check("fp.test.scoped", "theirs"), None);
        assert_eq!(check("fp.test.scoped", "also-not"), None);
        assert_eq!(check("fp.test.scoped", "path/mine/wal"), Some(Action::Error));
        assert_eq!(check("fp.test.scoped", "path/mine/wal"), None);
        clear("fp.test.scoped");
    }

    #[test]
    fn short_write_carries_len() {
        arm("fp.test.short", 0, 1, Action::ShortWrite(5));
        assert_eq!(check("fp.test.short", ""), Some(Action::ShortWrite(5)));
        clear("fp.test.short");
    }

    #[test]
    fn clear_disarms() {
        arm("fp.test.clear", 0, 10, Action::Error);
        clear("fp.test.clear");
        assert_eq!(check("fp.test.clear", ""), None);
    }

    #[test]
    #[should_panic(expected = "injected panic")]
    fn panic_action_panics() {
        arm("fp.test.panic", 0, 1, Action::Panic);
        check("fp.test.panic", "ctx");
    }
}
