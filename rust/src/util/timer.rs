//! Timing and latency statistics for the evaluation harness and the
//! coordinator metrics (criterion is not vendored; `benches/` binaries use
//! this module's measurement loop).

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }
}

/// Online summary statistics over a set of samples (stored; the sample
/// counts here are small — per-query latencies, bench iterations).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    samples: Vec<f64>,
    sorted: bool,
}

impl Stats {
    pub fn new() -> Self {
        Stats::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` by nearest-rank.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        self.samples[rank.clamp(1, n) - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Measurement loop: runs `f` repeatedly until `min_time` has elapsed and at
/// least `min_iters` iterations ran; returns per-iteration stats in
/// microseconds. `f` should return a value consumed by `black_box`-style
/// sinks internally to prevent dead-code elimination.
pub fn measure<F: FnMut()>(min_iters: usize, min_time: Duration, mut f: F) -> Stats {
    // Warmup: a few iterations to populate caches / JIT branch predictors.
    let warmup = min_iters.clamp(1, 3);
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    let loop_start = Instant::now();
    loop {
        let t = Instant::now();
        f();
        stats.push(t.elapsed().as_secs_f64() * 1e6);
        if stats.len() >= min_iters && loop_start.elapsed() >= min_time {
            break;
        }
        // Hard cap so pathological cases terminate.
        if loop_start.elapsed() >= min_time * 20 {
            break;
        }
    }
    stats
}

/// Prevents the optimizer from eliminating a computed value
/// (std::hint::black_box is stable — thin wrapper for call-site clarity).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut s = Stats::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(1.0), 1.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn measure_runs_min_iters() {
        let mut count = 0usize;
        let stats = measure(10, Duration::from_millis(1), || {
            count += 1;
        });
        assert!(stats.len() >= 10);
        assert!(count >= stats.len());
    }
}
