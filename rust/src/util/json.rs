//! Minimal JSON encoder/decoder.
//!
//! `serde`/`serde_json` are not vendored; the coordinator wire protocol and
//! the artifact metadata sidecar need only a small JSON subset, implemented
//! here: objects, arrays, strings (with escapes), f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as f64 (the wire protocol only carries
/// ids, thresholds and latencies — all exactly representable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Array of u32 ids (the common result payload).
    pub fn ids(ids: &[u32]) -> Json {
        Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serializes to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parses a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::at(p.pos, "trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

/// Parse error with byte offset. (Hand-rolled `Display`/`Error` impls —
/// the crate is dependency-free, so no `thiserror` derive.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl JsonError {
    fn at(pos: usize, msg: &str) -> Self {
        JsonError { pos, msg: msg.to_string() }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(JsonError::at(self.pos, &format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(JsonError::at(self.pos, "invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::at(self.pos, "unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(JsonError::at(self.pos, "unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| JsonError::at(self.pos, "bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| JsonError::at(self.pos, "bad codepoint"))?,
                        );
                    }
                    _ => return Err(JsonError::at(self.pos, "bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(JsonError::at(start, "bad utf-8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(JsonError::at(start, "bad utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| JsonError::at(start, "bad utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::at(start, "bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(JsonError::at(self.pos, "expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(JsonError::at(self.pos, "expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("query", Json::ids(&[1, 2, 3])),
            ("tau", Json::num(4.0)),
            ("name", Json::str("sift")),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::num(1_000_000u32);
        assert_eq!(v.to_string(), "1000000");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("quote\" back\\slash \n tab\t ctrl\u{1}");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"日本語 λ\"").unwrap();
        assert_eq!(v.as_str(), Some("日本語 λ"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }
}
