//! Heap-size accounting for the paper's space tables (Tables III & IV).
//!
//! Every index/trie reports its resident size via [`HeapSize`]; the eval
//! harness converts to MiB. We count actual allocated payload bytes
//! (capacity, not length, for vectors) — matching how the paper reports
//! data-structure sizes.

/// Types that can report the heap bytes they own.
pub trait HeapSize {
    /// Bytes of heap memory owned by `self` (excluding `size_of::<Self>()`).
    fn heap_bytes(&self) -> usize;
}

impl<T: Copy> HeapSize for Vec<T> {
    fn heap_bytes(&self) -> usize {
        self.capacity() * std::mem::size_of::<T>()
    }
}

impl HeapSize for String {
    fn heap_bytes(&self) -> usize {
        self.capacity()
    }
}

impl<T: HeapSize> HeapSize for Option<T> {
    fn heap_bytes(&self) -> usize {
        self.as_ref().map_or(0, |x| x.heap_bytes())
    }
}

impl<K, V: HeapSize> HeapSize for std::collections::BTreeMap<K, V> {
    fn heap_bytes(&self) -> usize {
        // Approximation: nodes dominated by K/V payload.
        self.values().map(|v| v.heap_bytes()).sum::<usize>()
            + self.len() * (std::mem::size_of::<K>() + std::mem::size_of::<V>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_heap_bytes() {
        let v: Vec<u64> = Vec::with_capacity(16);
        assert_eq!(v.heap_bytes(), 16 * 8);
        let v: Vec<u8> = vec![0; 10];
        assert!(v.heap_bytes() >= 10);
    }

    #[test]
    fn option_and_string() {
        assert_eq!(None::<String>.heap_bytes(), 0);
        let s = String::from("hello world");
        assert!(Some(s).heap_bytes() >= 11);
    }
}
