//! A small fixed-size thread pool.
//!
//! `tokio` is not vendored in this environment; the coordinator's
//! concurrency needs (shard fan-out, batched ingestion, connection
//! handling) are served by this classic worker-queue pool plus
//! `std::thread::scope` for borrowed-data parallel sections.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool executing boxed closures.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawns `size` workers (`size >= 1`).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                let q = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("bst-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                q.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers, queued }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Submits a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::Acquire);
        self.sender
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Runs `f` over each item of `items` on the pool and collects results
    /// in input order. Blocks until all complete.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("all jobs complete")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel; workers exit after draining.
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel for-each over index chunks using scoped threads (no 'static
/// bound — borrows are fine). Splits `[0, n)` into `chunks` contiguous
/// ranges and runs `f(range)` on each.
pub fn par_chunks<F>(n: usize, chunks: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let chunks = chunks.clamp(1, n.max(1));
    let per = n.div_ceil(chunks);
    thread::scope(|s| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo..hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn par_chunks_covers_everything() {
        let n = 1003;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_chunks(n, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_chunks_handles_edge_sizes() {
        par_chunks(0, 4, |_| panic!("no work expected"));
        let hit = AtomicU64::new(0);
        par_chunks(1, 8, |r| {
            hit.fetch_add(r.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }
}
