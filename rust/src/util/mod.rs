//! Zero-dependency utility substrate.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (`rand`, `serde`, `tokio`, `clap`,
//! `criterion`, `proptest`) are unavailable. This module provides the small,
//! well-tested subset of that functionality the engine needs:
//!
//! * [`rng`] — SplitMix64 / xoshiro256** PRNGs and distributions.
//! * [`pool`] — a scoped thread pool for shard fan-out and ingestion.
//! * [`timer`] — wall-clock timing and latency statistics.
//! * [`json`] — a minimal JSON encoder/decoder for the wire protocol and
//!   artifact metadata.
//! * [`mem`] — heap-size accounting used by the paper's space tables.
//! * [`failpoint`] — deterministic fault injection (test / `failpoints`
//!   feature only) behind the crash-recovery gates.

pub mod failpoint;
pub mod json;
pub mod mem;
pub mod pool;
pub mod rng;
pub mod timer;

pub use mem::HeapSize;
pub use pool::ThreadPool;
pub use rng::Rng;
pub use timer::Stats;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Formats a byte count as a human-readable MiB string (paper tables use MiB).
pub fn mib(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn mib_conversion() {
        assert_eq!(mib(1024 * 1024), 1.0);
        assert_eq!(mib(0), 0.0);
    }
}
