//! Deterministic pseudo-random number generation.
//!
//! `rand` is not vendored in this environment, so we implement the two
//! standard generators the engine needs: **SplitMix64** (seeding, hashing)
//! and **xoshiro256\*\*** (bulk generation), plus the distributions used by
//! the synthetic data generators (uniform, normal, gamma, Zipf).
//!
//! All generators are deterministic given a seed — every experiment in
//! EXPERIMENTS.md is reproducible bit-for-bit.

/// SplitMix64 step: the canonical 64-bit mixer (Steele et al.).
///
/// Also used as a cheap, high-quality integer hash throughout the engine
/// (hash-table keys, per-position sketch hashes).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless mix of a single value through the SplitMix64 finalizer.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256** generator (Blackman & Vigna). Fast, 256-bit state,
/// passes BigCrush; the workhorse PRNG for data generation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Creates a generator from a seed via SplitMix64 state expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper half of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection sampling (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` (never zero — safe for `ln`).
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached second value is not kept —
    /// simplicity beats the 2x constant here; data gen is offline).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze (shape >= 1) and the
    /// boost trick for shape < 1. Used by the native CWS sketcher.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64_open();
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let mut x;
            let mut v;
            loop {
                x = self.normal();
                v = 1.0 + c * x;
                if v > 0.0 {
                    break;
                }
            }
            v = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Shuffles a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Samples `k` distinct indices from `[0, n)` (partial Fisher–Yates
    /// when k is large, rejection when small).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below_usize(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }
}

/// A Zipf(n, s) sampler using rejection-inversion (Hörmann & Derflinger).
///
/// Used to give the synthetic Review/CP set fingerprints realistic
/// heavy-tailed word frequencies.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense: f64,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let n = n as f64;
        let h_x1 = Self::h(1.5, s) - 1.0;
        let h_n = Self::h(n + 0.5, s);
        let dense = 1.0 / (h_n - h_x1);
        Zipf { n, s, h_x1, h_n, dense }
    }

    /// H(x) — antiderivative of x^-s (handles s = 1 by log).
    fn h(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            (x.powf(1.0 - s) - 1.0) / (1.0 - s)
        }
    }

    fn h_inv(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - s)).powf(1.0 / (1.0 - s))
        }
    }

    /// Draws a rank in `[0, n)` (0-based; rank 0 is most frequent).
    ///
    /// Rejection from the piecewise envelope `H(k+1/2) - H(k-1/2) >= k^-s`
    /// (the integral of a convex decreasing density dominates its midpoint
    /// value), so the loop accepts with high probability for any `s`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let _ = self.dense; // normalization constant kept for pmf queries
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = Self::h_inv(u, self.s);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            let env = Self::h(k + 0.5, self.s) - Self::h(k - 0.5, self.s).max(self.h_x1);
            let p = k.powf(-self.s);
            if rng.f64() * env <= p {
                return (k as usize) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Rng::new(13);
        for &shape in &[0.5, 1.0, 2.0, 5.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += rng.gamma(shape);
            }
            let mean = sum / n as f64;
            // Gamma(k,1) has mean k.
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        for &(n, k) in &[(100usize, 5usize), (100, 80), (1, 1), (10, 10)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn zipf_is_heavy_tailed() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Rng::new(17);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            let k = z.sample(&mut rng);
            assert!(k < 1000);
            counts[k] += 1;
        }
        // rank 0 should dominate rank 99 by roughly 100^1.1.
        assert!(counts[0] > counts[99] * 10);
        assert!(counts[0] > counts[9]);
    }

    #[test]
    fn mix64_avalanche() {
        // flipping one input bit should flip ~half the output bits
        let x = 0xDEADBEEFCAFEBABEu64;
        let h0 = mix64(x);
        let h1 = mix64(x ^ 1);
        let flipped = (h0 ^ h1).count_ones();
        assert!((16..=48).contains(&flipped), "flipped={flipped}");
    }
}
