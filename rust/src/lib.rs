//! # `bst` — b-Bit Sketch Trie: scalable similarity search on integer sketches
//!
//! Production-quality reproduction of Kanda & Tabei,
//! *"b-Bit Sketch Trie: Scalable Similarity Search on Integer Sketches"* (2019).
//!
//! A *b-bit sketch* is a length-`L` string over the integer alphabet
//! `[0, 2^b)` produced by a similarity-preserving hash (b-bit minhash,
//! 0-bit CWS, ...). The library answers Hamming-threshold queries
//! `I = { i : ham(s_i, q) <= tau }` over massive sketch databases.
//!
//! ## Layout
//!
//! * [`bits`] — succinct bit-vector substrate (rank/select, packed ints).
//! * [`sketch`] — packed sketch storage, vertical (bit-plane) format,
//!   bit-parallel Hamming, native minhash/CWS sketchers.
//! * [`trie`] — the paper's contribution: the [`trie::bst`] succinct trie,
//!   plus pointer-trie / LOUDS / FST baselines.
//! * [`query`] — query execution: reusable [`query::QueryCtx`] scratch +
//!   the pluggable [`query::Collector`] policies (ids / count / top-k /
//!   traversal stats) shared by every trie and index.
//! * [`index`] — similarity-search indexes: SI-bST, MI-bST, SIH, MIH,
//!   HmSearch, linear scan.
//! * [`data`] — synthetic dataset generators standing in for the paper's
//!   Review / CP / SIFT / GIST corpora.
//! * [`store`] — index persistence: the versioned sectioned snapshot
//!   container and the [`store::Persist`] trait every structure
//!   implements, enabling build-once / serve-from-snapshot cold starts.
//! * [`runtime`] — PJRT (XLA) runtime: loads AOT-lowered JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) for the sketching pipeline and the
//!   XLA Hamming-scan baseline. Python never runs on the request path.
//! * [`coordinator`] — the serving layer: sharded router, dynamic batcher,
//!   TCP server, metrics.
//! * [`eval`] — harness regenerating every table and figure of the paper.
//! * [`util`] — PRNG, thread pool, timers, JSON (no external deps).
//!
//! ## Quickstart
//!
//! ```
//! use bst::sketch::SketchSet;
//! use bst::index::{SearchIndex, SingleBst};
//!
//! // 2-bit sketches of length 8, from raw characters.
//! let rows: Vec<Vec<u8>> = vec![
//!     vec![0, 1, 2, 3, 0, 1, 2, 3],
//!     vec![0, 1, 2, 3, 0, 1, 2, 2],
//!     vec![3, 3, 3, 3, 3, 3, 3, 3],
//! ];
//! let set = SketchSet::from_rows(2, 8, &rows);
//! let index = SingleBst::build(&set, Default::default());
//! let mut hits = index.search(&rows[0], 1);
//! hits.sort();
//! assert_eq!(hits, vec![0, 1]);
//! ```

pub mod bits;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod index;
pub mod query;
pub mod runtime;
pub mod sketch;
pub mod store;
pub mod trie;
pub mod util;

pub use index::SearchIndex;
pub use sketch::SketchSet;
