//! Markdown table rendering for the experiment reports.

/// A simple Markdown table builder.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header(&mut self, cols: Vec<String>) -> &mut Self {
        self.header = cols;
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        debug_assert_eq!(cols.len(), self.header.len(), "row arity");
        self.rows.push(cols);
        self
    }

    /// Renders with column alignment (renders fine in raw terminals too).
    pub fn render(&self) -> String {
        
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cols: &[String]| -> String {
            let cells: Vec<String> = cols
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", cells.join(" | "))
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Formats milliseconds compactly (matching the paper's precision).
pub fn ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 1.0 {
        format!("{x:.1}")
    } else if x >= 0.01 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

/// Formats mebibytes.
pub fn mib_str(bytes: usize) -> String {
    let m = bytes as f64 / (1024.0 * 1024.0);
    if m >= 100.0 {
        format!("{m:.0}")
    } else if m >= 1.0 {
        format!("{m:.1}")
    } else {
        format!("{m:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("demo");
        t.header(vec!["name".into(), "value".into()]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("### demo"));
        assert!(s.contains("| name   | value |"));
        assert!(s.contains("| longer | 2.5   |"));
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(123.4), "123");
        assert_eq!(ms(12.34), "12.3");
        assert_eq!(ms(0.123), "0.12");
        assert_eq!(ms(0.00123), "0.001");
        assert_eq!(mib_str(1024 * 1024 * 250), "250");
        assert_eq!(mib_str(1536 * 1024), "1.5");
    }
}
