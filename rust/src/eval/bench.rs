//! Perf-trajectory experiment (`bst bench`): machine-readable per-query
//! latency points comparing bST against the linear-scan floor.
//!
//! Every PR that touches a hot path re-runs this and commits/uploads the
//! resulting `BENCH_*.json`, so the repo accumulates a comparable series
//! of perf measurements (schema `bst-bench-v1`): one row per
//! `(dataset, index, tau)` with `n`, `b`, `L`, p50/p99 latency in µs and
//! throughput in M queries/s. Absolute numbers are testbed-specific —
//! the trajectory (and the bST-vs-linear gap) is the signal.

use super::EvalOpts;
use crate::data::{self, Dataset, GenConfig};
use crate::index::{LinearScan, SearchIndex, SingleBst};
use crate::query::{CollectIds, QueryCtx};
use crate::trie::bst::BstConfig;
use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};

/// Runs the experiment; returns `(markdown report, json payload)`.
pub fn bench(opts: &EvalOpts, datasets: &[Dataset]) -> (String, Json) {
    let mut md = String::from("# bench — perf trajectory (bST vs linear)\n\n");
    md.push_str("| dataset | index | n | b | L | tau | p50 us | p99 us | Mq/s |\n");
    md.push_str("|---|---|---|---|---|---|---|---|---|\n");
    let mut rows: Vec<Json> = Vec::new();

    for &ds in datasets {
        let cfg = GenConfig::for_dataset(ds, opts.scale, opts.seed, opts.threads);
        let w = data::generate_workload(ds, &cfg);
        let set = &w.sketches;
        let bst = SingleBst::build(set, BstConfig::default());
        let linear = LinearScan::build(set);
        let indexes: [(&str, &dyn SearchIndex); 2] = [("si-bst", &bst), ("linear", &linear)];

        for (name, idx) in indexes {
            for &tau in &[1usize, 2, 4] {
                let mut ctx = QueryCtx::new();
                let mut out: Vec<u32> = Vec::new();
                // warm-up: size the scratch, touch the structure
                for q in w.queries.iter().take(8) {
                    out.clear();
                    let mut coll = CollectIds::new(tau, &mut out);
                    idx.run(q, &mut ctx, &mut coll);
                }
                let mut lat = Stats::new();
                let mut solutions = 0usize;
                for qi in 0..opts.queries {
                    let q = &w.queries[qi % w.queries.len()];
                    let t = Timer::start();
                    out.clear();
                    let mut coll = CollectIds::new(tau, &mut out);
                    idx.run(q, &mut ctx, &mut coll);
                    lat.push(t.elapsed_us());
                    solutions += out.len();
                }
                let (p50, p99, mean) = (lat.p50(), lat.p99(), lat.mean());
                let mqps = if mean > 0.0 { 1.0 / mean } else { 0.0 };
                md.push_str(&format!(
                    "| {} | {name} | {} | {} | {} | {tau} | {p50:.2} | {p99:.2} | {mqps:.3} |\n",
                    ds.name(),
                    set.n(),
                    set.b(),
                    set.l()
                ));
                rows.push(Json::obj(vec![
                    ("dataset", Json::str(ds.name())),
                    ("index", Json::str(name)),
                    ("n", Json::num(set.n() as f64)),
                    ("b", Json::num(set.b() as f64)),
                    ("l", Json::num(set.l() as f64)),
                    ("tau", Json::num(tau as f64)),
                    ("queries", Json::num(opts.queries as f64)),
                    ("avg_solutions", Json::num(solutions as f64 / opts.queries.max(1) as f64)),
                    ("p50_us", Json::num(p50)),
                    ("p99_us", Json::num(p99)),
                    ("mean_us", Json::num(mean)),
                    ("mqps", Json::num(mqps)),
                ]));
            }
        }
    }

    let payload = Json::obj(vec![
        ("schema", Json::str("bst-bench-v1")),
        (
            "config",
            Json::obj(vec![
                ("scale", Json::num(opts.scale)),
                ("queries", Json::num(opts.queries as f64)),
                ("seed", Json::num(opts.seed as f64)),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    (md, payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_rows_for_every_cell() {
        let opts = EvalOpts { scale: 0.005, queries: 4, ..Default::default() };
        let (md, payload) = bench(&opts, &[Dataset::Review]);
        assert!(md.contains("si-bst") && md.contains("linear"));
        let rows = payload.get("rows").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(rows.len(), 2 * 3, "2 indexes x 3 taus");
        for row in rows {
            assert!(row.get("p50_us").and_then(Json::as_f64).is_some());
            assert!(row.get("mqps").and_then(Json::as_f64).unwrap() >= 0.0);
        }
        assert_eq!(
            payload.get("schema").and_then(Json::as_str),
            Some("bst-bench-v1")
        );
    }
}
