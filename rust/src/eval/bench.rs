//! Perf-trajectory experiment (`bst bench`): machine-readable per-query
//! latency points comparing bST against the linear-scan floor, plus the
//! write path's insert throughput.
//!
//! Every PR that touches a hot path re-runs this and commits/uploads the
//! resulting `BENCH_*.json`, so the repo accumulates a comparable series
//! of perf measurements (schema `bst-bench-v5`): one row per
//! `(dataset, index, tau)` with `n`, `b`, `L`, p50/p99 latency in µs and
//! throughput in M queries/s; one `blocked-vs-serial` row per
//! `(dataset, block width)` measuring the engine's blocked batch path
//! at widths 1/4/8/16 (width 1 *is* the serial path, so the width-8 /
//! width-1 Mq/s ratio is the blocking speedup); one `delta-insert`
//! row per dataset with per-batch latency percentiles and append
//! throughput in Mops/s (rows/µs into the engine's delta segments,
//! auto-merge disabled); one `wal-commit` row per
//! `(dataset, writer count, grouped)` — acknowledged writes/s through a
//! `--wal-sync always` log at 1/8/64 concurrent writers, group commit
//! on (auto window) vs off (inline fsync per append), with the fsync
//! count so the coalescing factor is visible (CI asserts grouped ≥
//! ungrouped at 8 writers); and one `cold-start` row per dataset timing
//! `Engine::load` in both serving modes (best-of-3, page cache warmed):
//! `owned_ms` vs `mapped_ms` wall clock plus `owned_rss_mib` /
//! `mapped_rss_mib` — the engine's tracked assembly-time heap, the
//! deterministic proxy for resident memory (the mapped figure excludes
//! the borrowed payload bytes, which stay in the shared page cache).
//! Absolute numbers are testbed-specific — the trajectory (and the
//! bST-vs-linear gap) is the signal.

use super::EvalOpts;
use crate::coordinator::engine::{Engine, QueryMode, ShardIndexKind};
use crate::data::{self, Dataset, GenConfig};
use crate::index::{LinearScan, SearchIndex, SingleBst};
use crate::query::{CollectIds, QueryCtx};
use crate::store::WalSync;
use crate::trie::bst::BstConfig;
use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};
use std::sync::Arc;

/// Rows appended per `insert_batch` call in the write-path measurement.
const INSERT_BATCH: usize = 512;

/// Queries per batch in the blocked-vs-serial measurement.
const BLOCK_BATCH: usize = 32;

/// Block widths swept by the blocked-vs-serial rows (1 = serial).
const BLOCK_WIDTHS: [usize; 4] = [1, 4, 8, 16];

/// Concurrent writer counts swept by the wal-commit rows.
const WAL_WRITERS: [usize; 3] = [1, 8, 64];

/// Rows per acknowledged write in the wal-commit measurement.
const WAL_COMMIT_BATCH: usize = 8;

/// Acked writes each writer issues per wal-commit cell (kept small:
/// ungrouped cells pay one fsync per write).
const WAL_COMMIT_WRITES: usize = 8;

/// Runs the experiment; returns `(markdown report, json payload)`.
pub fn bench(opts: &EvalOpts, datasets: &[Dataset]) -> (String, Json) {
    let mut md = String::from("# bench — perf trajectory (bST vs linear + write path)\n\n");
    md.push_str("| dataset | index | n | b | L | tau | p50 us | p99 us | Mq/s | Mops/s |\n");
    md.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
    let mut rows: Vec<Json> = Vec::new();

    for &ds in datasets {
        let cfg = GenConfig::for_dataset(ds, opts.scale, opts.seed, opts.threads);
        let w = data::generate_workload(ds, &cfg);
        let set = &w.sketches;
        let bst = SingleBst::build(set, BstConfig::default());
        let linear = LinearScan::build(set);
        let indexes: [(&str, &dyn SearchIndex); 2] = [("si-bst", &bst), ("linear", &linear)];

        for (name, idx) in indexes {
            for &tau in &[1usize, 2, 4] {
                let mut ctx = QueryCtx::new();
                let mut out: Vec<u32> = Vec::new();
                // warm-up: size the scratch, touch the structure
                for q in w.queries.iter().take(8) {
                    out.clear();
                    let mut coll = CollectIds::new(tau, &mut out);
                    idx.run(q, &mut ctx, &mut coll);
                }
                let mut lat = Stats::new();
                let mut solutions = 0usize;
                for qi in 0..opts.queries {
                    let q = &w.queries[qi % w.queries.len()];
                    let t = Timer::start();
                    out.clear();
                    let mut coll = CollectIds::new(tau, &mut out);
                    idx.run(q, &mut ctx, &mut coll);
                    lat.push(t.elapsed_us());
                    solutions += out.len();
                }
                let (p50, p99, mean) = (lat.p50(), lat.p99(), lat.mean());
                let mqps = if mean > 0.0 { 1.0 / mean } else { 0.0 };
                md.push_str(&format!(
                    "| {} | {name} | {} | {} | {} | {tau} | {p50:.2} | {p99:.2} | {mqps:.3} | - |\n",
                    ds.name(),
                    set.n(),
                    set.b(),
                    set.l()
                ));
                rows.push(Json::obj(vec![
                    ("dataset", Json::str(ds.name())),
                    ("index", Json::str(name)),
                    ("n", Json::num(set.n() as f64)),
                    ("b", Json::num(set.b() as f64)),
                    ("l", Json::num(set.l() as f64)),
                    ("tau", Json::num(tau as f64)),
                    ("queries", Json::num(opts.queries as f64)),
                    ("avg_solutions", Json::num(solutions as f64 / opts.queries.max(1) as f64)),
                    ("p50_us", Json::num(p50)),
                    ("p99_us", Json::num(p99)),
                    ("mean_us", Json::num(mean)),
                    ("mqps", Json::num(mqps)),
                ]));
            }
        }

        // Blocked vs serial: the same engine and query stream executed
        // through the blocked batch path at increasing block widths.
        // Width 1 delegates to the serial run_batch, so these rows
        // measure exactly the blocking speedup (same τ, Ids mode —
        // a fully compatible batch).
        {
            let engine = Engine::build(set, 2, &ShardIndexKind::Bst(BstConfig::default()));
            let tau = 2usize;
            let batch: Vec<(Arc<[u8]>, usize, QueryMode)> = (0..BLOCK_BATCH)
                .map(|i| {
                    let q = &w.queries[i % w.queries.len()];
                    (Arc::from(q.as_slice()), tau, QueryMode::Ids)
                })
                .collect();
            for &width in &BLOCK_WIDTHS {
                let _ = engine.run_batch_blocked(&batch, width); // warm-up
                let reps = (opts.queries / BLOCK_BATCH).max(1);
                let mut lat = Stats::new();
                let mut total_q = 0usize;
                let t_all = Timer::start();
                for _ in 0..reps {
                    let t = Timer::start();
                    let _ = engine.run_batch_blocked(&batch, width);
                    lat.push(t.elapsed_us() / batch.len() as f64);
                    total_q += batch.len();
                }
                let total_us = t_all.elapsed_us();
                let mqps = if total_us > 0.0 { total_q as f64 / total_us } else { 0.0 };
                md.push_str(&format!(
                    "| {} | blocked-vs-serial (w={width}) | {} | {} | {} | {tau} | {:.2} | {:.2} | {mqps:.3} | - |\n",
                    ds.name(),
                    set.n(),
                    set.b(),
                    set.l(),
                    lat.p50(),
                    lat.p99(),
                ));
                rows.push(Json::obj(vec![
                    ("dataset", Json::str(ds.name())),
                    ("index", Json::str("blocked-vs-serial")),
                    ("block_width", Json::num(width as f64)),
                    ("n", Json::num(set.n() as f64)),
                    ("b", Json::num(set.b() as f64)),
                    ("l", Json::num(set.l() as f64)),
                    ("tau", Json::num(tau as f64)),
                    ("queries", Json::num(total_q as f64)),
                    ("p50_us", Json::num(lat.p50())),
                    ("p99_us", Json::num(lat.p99())),
                    ("mean_us", Json::num(lat.mean())),
                    ("mqps", Json::num(mqps)),
                ]));
            }
        }

        // Write path: append throughput into the delta segments. The
        // engine starts from the dataset and re-inserts rotated rows in
        // fixed-size batches; auto-merge is disabled so the measurement
        // is pure append + fan-out (merge cost has its own trajectory
        // via the CI write-path step).
        let engine = Engine::build(set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        engine.set_merge_threshold(usize::MAX);
        let n_insert = (set.n() / 2).clamp(INSERT_BATCH, 100_000);
        let mut lat = Stats::new();
        let mut inserted = 0usize;
        let mut cursor = 0usize;
        let t_all = Timer::start();
        while inserted < n_insert {
            let m = INSERT_BATCH.min(n_insert - inserted);
            let batch: Vec<Vec<u8>> =
                (0..m).map(|j| set.row((cursor + j) % set.n())).collect();
            cursor += m;
            let t = Timer::start();
            engine.insert_batch(&batch).expect("bench insert");
            lat.push(t.elapsed_us());
            inserted += m;
        }
        let total_us = t_all.elapsed_us();
        let mops = if total_us > 0.0 { inserted as f64 / total_us } else { 0.0 };
        md.push_str(&format!(
            "| {} | delta-insert | {inserted} | {} | {} | - | {:.2} | {:.2} | - | {mops:.3} |\n",
            ds.name(),
            set.b(),
            set.l(),
            lat.p50(),
            lat.p99(),
        ));
        rows.push(Json::obj(vec![
            ("dataset", Json::str(ds.name())),
            ("index", Json::str("delta-insert")),
            ("n", Json::num(inserted as f64)),
            ("b", Json::num(set.b() as f64)),
            ("l", Json::num(set.l() as f64)),
            ("batch", Json::num(INSERT_BATCH as f64)),
            ("p50_us", Json::num(lat.p50())),
            ("p99_us", Json::num(lat.p99())),
            ("mean_us", Json::num(lat.mean())),
            ("mops", Json::num(mops)),
        ]));

        // Group commit (PR 10): acknowledged-write throughput through a
        // `--wal-sync always` log under concurrent writers, group
        // window on (auto: coalesce whenever writers queue behind an
        // in-flight fsync) vs off (every append fsyncs inline under the
        // insert lock). The signal is the grouped/ungrouped writes-per-
        // second ratio as writers grow — CI asserts grouped ≥ ungrouped
        // at 8 writers — plus the recorded fsync count, which exposes
        // the coalescing factor directly.
        for &writers in &WAL_WRITERS {
            for grouped in [true, false] {
                let engine = Engine::build(set, 2, &ShardIndexKind::Bst(BstConfig::default()));
                engine.set_merge_threshold(usize::MAX);
                let mode = if grouped { "group" } else { "inline" };
                let dir = std::env::temp_dir().join(format!(
                    "bst_bench_wal_{}_{}_{writers}_{mode}",
                    std::process::id(),
                    ds.name(),
                ));
                let _ = std::fs::remove_dir_all(&dir);
                std::fs::create_dir_all(&dir).expect("bench wal dir");
                let window = if grouped { None } else { Some(0) };
                engine
                    .attach_wal_with(&dir.join("engine.wal"), WalSync::Always, window)
                    .expect("bench wal attach");
                let mut lat = Stats::new();
                let t_all = Timer::start();
                let per_thread: Vec<Vec<f64>> = std::thread::scope(|s| {
                    let handles: Vec<_> = (0..writers)
                        .map(|wi| {
                            let engine = &engine;
                            s.spawn(move || {
                                let mut lats = Vec::with_capacity(WAL_COMMIT_WRITES);
                                for i in 0..WAL_COMMIT_WRITES {
                                    let off = (wi * WAL_COMMIT_WRITES + i) * WAL_COMMIT_BATCH;
                                    let batch: Vec<Vec<u8>> = (0..WAL_COMMIT_BATCH)
                                        .map(|j| set.row((off + j) % set.n()))
                                        .collect();
                                    let t = Timer::start();
                                    engine.insert_batch(&batch).expect("bench wal insert");
                                    lats.push(t.elapsed_us());
                                }
                                lats
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().expect("wal writer")).collect()
                });
                let total_us = t_all.elapsed_us();
                for l in per_thread.into_iter().flatten() {
                    lat.push(l);
                }
                let writes = (writers * WAL_COMMIT_WRITES) as f64;
                let wps = if total_us > 0.0 { writes / (total_us / 1e6) } else { 0.0 };
                let rows_inserted = writes * WAL_COMMIT_BATCH as f64;
                let mops = if total_us > 0.0 { rows_inserted / total_us } else { 0.0 };
                let m = engine.metrics();
                let fsyncs = m.wal_fsyncs.load(std::sync::atomic::Ordering::Relaxed) as f64;
                drop(engine);
                let _ = std::fs::remove_dir_all(&dir);
                md.push_str(&format!(
                    "| {} | wal-commit (w={writers}, {mode}, {wps:.0} acked writes/s, \
                     {fsyncs:.0} fsyncs) | {} | {} | {} | - | {:.2} | {:.2} | - | {mops:.3} |\n",
                    ds.name(),
                    set.n(),
                    set.b(),
                    set.l(),
                    lat.p50(),
                    lat.p99(),
                ));
                rows.push(Json::obj(vec![
                    ("dataset", Json::str(ds.name())),
                    ("index", Json::str("wal-commit")),
                    ("writers", Json::num(writers as f64)),
                    ("grouped", Json::Bool(grouped)),
                    ("batch", Json::num(WAL_COMMIT_BATCH as f64)),
                    ("writes", Json::num(writes)),
                    ("b", Json::num(set.b() as f64)),
                    ("l", Json::num(set.l() as f64)),
                    ("p50_us", Json::num(lat.p50())),
                    ("p99_us", Json::num(lat.p99())),
                    ("mean_us", Json::num(lat.mean())),
                    ("writes_per_s", Json::num(wps)),
                    ("mops", Json::num(mops)),
                    ("fsyncs", Json::num(fsyncs)),
                ]));
            }
        }

        // Cold start: save a snapshot and time both serving load modes.
        // The mapped load parses and validates the same bytes but skips
        // every payload-sized copy; CI asserts mapped <= owned. Each
        // mode takes its best of 3 runs so the row measures the load
        // path, not scheduler noise.
        {
            let engine = Engine::build(set, 2, &ShardIndexKind::Bst(BstConfig::default()));
            let path =
                std::env::temp_dir().join(format!("bst_bench_cold_{}.snap", ds.name()));
            engine.save(&path).expect("bench save");
            drop(engine);
            // warm the page cache so both modes read from memory
            let _ = std::fs::read(&path);
            let mut best = [f64::MAX; 2];
            let mut heap_mib = [0.0f64; 2];
            for (mode, mapped) in [(0usize, false), (1, true)] {
                for _ in 0..3 {
                    let t = Timer::start();
                    let e = Engine::load_with(&path, mapped).expect("bench cold start");
                    best[mode] = best[mode].min(t.elapsed_ms());
                    heap_mib[mode] = e.heap_bytes() as f64 / (1024.0 * 1024.0);
                }
            }
            let _ = std::fs::remove_file(&path);
            md.push_str(&format!(
                "| {} | cold-start (owned {:.1} ms / mapped {:.1} ms, heap {:.1} -> {:.1} MiB) \
                 | {} | {} | {} | - | - | - | - | - |\n",
                ds.name(),
                best[0],
                best[1],
                heap_mib[0],
                heap_mib[1],
                set.n(),
                set.b(),
                set.l(),
            ));
            rows.push(Json::obj(vec![
                ("dataset", Json::str(ds.name())),
                ("index", Json::str("cold-start")),
                ("n", Json::num(set.n() as f64)),
                ("b", Json::num(set.b() as f64)),
                ("l", Json::num(set.l() as f64)),
                ("owned_ms", Json::num(best[0])),
                ("mapped_ms", Json::num(best[1])),
                ("owned_rss_mib", Json::num(heap_mib[0])),
                ("mapped_rss_mib", Json::num(heap_mib[1])),
            ]));
        }
    }

    let payload = Json::obj(vec![
        ("schema", Json::str("bst-bench-v5")),
        (
            "config",
            Json::obj(vec![
                ("scale", Json::num(opts.scale)),
                ("queries", Json::num(opts.queries as f64)),
                ("seed", Json::num(opts.seed as f64)),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    (md, payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_rows_for_every_cell() {
        let opts = EvalOpts { scale: 0.005, queries: 4, ..Default::default() };
        let (md, payload) = bench(&opts, &[Dataset::Review]);
        assert!(md.contains("si-bst") && md.contains("linear") && md.contains("delta-insert"));
        assert!(md.contains("blocked-vs-serial"));
        assert!(md.contains("wal-commit"));
        assert!(md.contains("cold-start"));
        let rows = payload.get("rows").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(
            rows.len(),
            2 * 3 + BLOCK_WIDTHS.len() + 1 + WAL_WRITERS.len() * 2 + 1,
            "2 indexes x 3 taus + blocked widths + insert row + wal-commit cells + cold-start row"
        );
        for row in rows {
            if row.get("index").and_then(Json::as_str) == Some("cold-start") {
                continue; // reports ms + MiB, not per-query percentiles
            }
            assert!(row.get("p50_us").and_then(Json::as_f64).is_some());
        }
        let query_rows: Vec<&Json> = rows
            .iter()
            .filter(|r| {
                matches!(r.get("index").and_then(Json::as_str), Some("si-bst" | "linear"))
            })
            .collect();
        assert_eq!(query_rows.len(), 6);
        for row in &query_rows {
            assert!(row.get("mqps").and_then(Json::as_f64).unwrap() >= 0.0);
        }
        let blocked_rows: Vec<&Json> = rows
            .iter()
            .filter(|r| r.get("index").and_then(Json::as_str) == Some("blocked-vs-serial"))
            .collect();
        assert_eq!(blocked_rows.len(), BLOCK_WIDTHS.len());
        let widths: Vec<f64> = blocked_rows
            .iter()
            .map(|r| r.get("block_width").and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(widths, vec![1.0, 4.0, 8.0, 16.0]);
        for row in &blocked_rows {
            assert!(row.get("mqps").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let insert_rows: Vec<&Json> = rows
            .iter()
            .filter(|r| r.get("index").and_then(Json::as_str) == Some("delta-insert"))
            .collect();
        assert_eq!(insert_rows.len(), 1);
        assert!(insert_rows[0].get("mops").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(insert_rows[0].get("n").and_then(Json::as_f64).unwrap() > 0.0);
        let wal_rows: Vec<&Json> = rows
            .iter()
            .filter(|r| r.get("index").and_then(Json::as_str) == Some("wal-commit"))
            .collect();
        assert_eq!(wal_rows.len(), WAL_WRITERS.len() * 2, "writer counts x (group, inline)");
        for row in &wal_rows {
            let writers = row.get("writers").and_then(Json::as_f64).unwrap();
            assert!(WAL_WRITERS.contains(&(writers as usize)));
            assert!(row.get("grouped").and_then(Json::as_bool).is_some());
            assert!(row.get("writes_per_s").and_then(Json::as_f64).unwrap() > 0.0);
            let fsyncs = row.get("fsyncs").and_then(Json::as_f64).unwrap();
            let writes = row.get("writes").and_then(Json::as_f64).unwrap();
            assert!(fsyncs >= 1.0 && fsyncs <= writes, "fsyncs {fsyncs} vs writes {writes}");
            if row.get("grouped").and_then(Json::as_bool) == Some(false) {
                // Inline mode accounts exactly one fsync per acked write.
                assert_eq!(fsyncs, writes, "inline fsync accounting");
            }
        }
        let cold_rows: Vec<&Json> = rows
            .iter()
            .filter(|r| r.get("index").and_then(Json::as_str) == Some("cold-start"))
            .collect();
        assert_eq!(cold_rows.len(), 1);
        let cold = cold_rows[0];
        assert!(cold.get("owned_ms").and_then(Json::as_f64).unwrap() >= 0.0);
        assert!(cold.get("mapped_ms").and_then(Json::as_f64).unwrap() >= 0.0);
        let owned_mib = cold.get("owned_rss_mib").and_then(Json::as_f64).unwrap();
        let mapped_mib = cold.get("mapped_rss_mib").and_then(Json::as_f64).unwrap();
        assert!(
            mapped_mib < owned_mib,
            "mapped serving must hold less heap: {mapped_mib} !< {owned_mib}"
        );
        assert_eq!(
            payload.get("schema").and_then(Json::as_str),
            Some("bst-bench-v5")
        );
    }
}
