//! Experiment runners for Tables I–IV, Figure 7 and the m-sweep.

use super::report::{mib_str, ms, Table};
use super::EvalOpts;
use crate::data::{generate_workload, Dataset, GenConfig, Workload};
use crate::index::{
    HmSearch, LinearScan, Mih, MultiBst, SearchIndex, Sih, SingleBst, SingleFst, SingleLouds,
};
use crate::index::sih::CappedResult;
use crate::query::{CountOnly, QueryCtx, StatsObserver};
use crate::store::persisted_bytes;
use crate::trie::bst::BstConfig;
use crate::trie::SketchTrie;
use crate::util::pool::par_chunks;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Thresholds evaluated throughout the paper.
pub const TAUS: [usize; 5] = [1, 2, 3, 4, 5];

/// Generates (or regenerates) the workload for one dataset.
pub fn load_workload(ds: Dataset, opts: &EvalOpts) -> Workload {
    let cfg = GenConfig::for_dataset(ds, opts.scale, opts.seed, opts.threads);
    generate_workload(ds, &cfg)
}

/// Mean per-query latency (ms) of `search` over the first `n_q` queries.
fn time_queries<F: Fn(&[u8]) -> Vec<u32>>(
    queries: &[Vec<u8>],
    n_q: usize,
    search: F,
) -> (f64, usize) {
    let qs = &queries[..n_q.min(queries.len())];
    let solutions = AtomicUsize::new(0);
    let timer = Timer::start();
    for q in qs {
        let hits = search(q);
        solutions.fetch_add(hits.len(), Ordering::Relaxed);
    }
    let total_ms = timer.elapsed_ms();
    (total_ms / qs.len() as f64, solutions.load(Ordering::Relaxed))
}

/// Table I: dataset summary (paper parameters + generated sizes).
pub fn table1(opts: &EvalOpts) -> String {
    let mut t = Table::new("Table I — datasets (synthetic stand-ins; see DESIGN.md §5)");
    t.header(vec![
        "dataset".into(),
        "hashing".into(),
        "L".into(),
        "b".into(),
        "n (ours)".into(),
        "n (paper)".into(),
        "D (ours)".into(),
    ]);
    for ds in Dataset::ALL {
        let n = ((ds.default_n() as f64 * opts.scale) as usize).max(1000);
        t.row(vec![
            ds.name().into(),
            if ds.uses_minhash() { "b-bit minhash".into() } else { "0-bit CWS".into() },
            ds.l().to_string(),
            ds.b().to_string(),
            n.to_string(),
            ds.paper_n().to_string(),
            ds.dim().to_string(),
        ]);
    }
    t.render()
}

/// Table II: average number of solutions per τ (linear-scan ground truth).
pub fn table2(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    let mut t = Table::new(format!(
        "Table II — average #solutions over {} queries",
        opts.queries
    ));
    let mut header = vec!["dataset".into()];
    header.extend(TAUS.iter().map(|tau| format!("tau={tau}")));
    t.header(header);
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let scan = LinearScan::build(&w.sketches);
        let n_q = opts.queries.min(w.queries.len());
        let mut row = vec![ds.name().to_string()];
        // parallel over queries: accumulate solution counts per tau
        let totals: Vec<AtomicUsize> = TAUS.iter().map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n_q, opts.threads, |range| {
            for qi in range {
                // one scan at max tau gives all smaller taus for free
                let qp = scan.vertical().pack_query(&w.queries[qi]);
                for i in 0..scan.vertical().n() {
                    let d = scan.vertical().ham(i, &qp);
                    for (ti, &tau) in TAUS.iter().enumerate() {
                        if d <= tau {
                            totals[ti].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        });
        for t_acc in &totals {
            row.push(format!("{:.0}", t_acc.load(Ordering::Relaxed) as f64 / n_q as f64));
        }
        t.row(row);
    }
    t.render()
}

/// Table III: succinct-trie comparison (bST vs LOUDS vs FST), single-index.
pub fn table3(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    let mut out = String::new();
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let n_q = opts.queries.min(w.queries.len());

        let bst = SingleBst::build(&w.sketches, BstConfig::default());
        let louds = SingleLouds::build(&w.sketches);
        let fst = SingleFst::build(&w.sketches);

        let mut t = Table::new(format!(
            "Table III — {} ({}; {} queries)",
            ds.name(),
            bst.trie().describe(),
            n_q
        ));
        let mut header = vec!["trie".into()];
        header.extend(TAUS.iter().map(|tau| format!("tau={tau} (ms)")));
        header.push("space (MiB)".into());
        header.push("disk (MiB)".into());
        t.header(header);

        let search_bst = |q: &[u8], tau: usize| bst.search(q, tau);
        let search_louds = |q: &[u8], tau: usize| louds.search(q, tau);
        let search_fst = |q: &[u8], tau: usize| fst.search(q, tau);
        let methods: Vec<(&str, &dyn Fn(&[u8], usize) -> Vec<u32>, usize, usize)> = vec![
            ("bST", &search_bst, bst.heap_bytes(), persisted_bytes(&bst)),
            ("LOUDS", &search_louds, louds.heap_bytes(), persisted_bytes(&louds)),
            ("FST", &search_fst, fst.heap_bytes(), persisted_bytes(&fst)),
        ];
        for (name, search, bytes, disk) in methods {
            let mut row = vec![name.to_string()];
            for &tau in &TAUS {
                let (mean_ms, _) = time_queries(&w.queries, n_q, |q| search(q, tau));
                row.push(ms(mean_ms));
            }
            row.push(mib_str(bytes));
            row.push(mib_str(disk));
            t.row(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Which multi-index block counts the sweep evaluates (paper: {2,3,4}).
pub const MS: [usize; 3] = [2, 3, 4];

/// Table IV: space usage of the similarity-search methods.
pub fn table4(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    let cap_bytes = (opts.mem_cap_gib * 1024.0 * 1024.0 * 1024.0) as u128;
    let mut t = Table::new("Table IV — space usage (MiB, heap/disk)");
    let mut header = vec!["method".into()];
    header.extend(datasets.iter().map(|d| d.name().to_string()));
    t.header(header);

    // Build rows method-major like the paper; datasets column-major.
    let mut cells: Vec<Vec<String>> = Vec::new();
    let mut labels: Vec<String> = vec![
        "SI-bST".into(),
        "MI-bST (m=2)".into(),
        "SIH".into(),
        "MIH (m=2)".into(),
        "MIH (m=3)".into(),
        "HmSearch (tau=1,2)".into(),
        "HmSearch (tau=3,4)".into(),
        "HmSearch (tau=5)".into(),
    ];
    for _ in &labels {
        cells.push(Vec::new());
    }

    // Both costs of each method: resident heap and serialized snapshot
    // (the cold-start artifact a production deployment ships).
    fn heap_disk(heap: usize, disk: usize) -> String {
        format!("{}/{}", mib_str(heap), mib_str(disk))
    }
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let set = &w.sketches;
        let si = SingleBst::build(set, BstConfig::default());
        cells[0].push(heap_disk(si.heap_bytes(), persisted_bytes(&si)));
        let mi = MultiBst::build(set, 2);
        cells[1].push(heap_disk(SearchIndex::heap_bytes(&mi), persisted_bytes(&mi)));
        let sih = Sih::build(set);
        cells[2].push(heap_disk(SearchIndex::heap_bytes(&sih), persisted_bytes(&sih)));
        let mih2 = Mih::build(set, 2);
        cells[3].push(heap_disk(SearchIndex::heap_bytes(&mih2), persisted_bytes(&mih2)));
        let mih3 = Mih::build(set, 3);
        cells[4].push(heap_disk(SearchIndex::heap_bytes(&mih3), persisted_bytes(&mih3)));
        for (slot, tau_max) in [(5usize, 2usize), (6, 4), (7, 5)] {
            let est = HmSearch::estimate_postings(set, tau_max) * 8; // ≥8 B/posting
            if est > cap_bytes {
                cells[slot].push(format!("OOM(>{:.0}GiB est)", est as f64 / (1u64 << 30) as f64));
            } else {
                let hm = HmSearch::build(set, tau_max);
                cells[slot].push(heap_disk(SearchIndex::heap_bytes(&hm), persisted_bytes(&hm)));
            }
        }
    }
    for (label, row) in labels.drain(..).zip(cells) {
        let mut r = vec![label];
        r.extend(row);
        t.row(r);
    }
    t.render()
}

/// Figure 7: average search time of the five methods.
pub fn fig7(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    let mut out = String::new();
    let cap = Duration::from_secs_f64(opts.sih_cap_secs);
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let set = &w.sketches;
        let n_q = opts.queries.min(w.queries.len());

        let si = SingleBst::build(set, BstConfig::default());
        let mi: Vec<MultiBst> = MS.iter().map(|&m| MultiBst::build(set, m)).collect();
        let sih = Sih::build(set);
        let mih: Vec<Mih> = MS.iter().map(|&m| Mih::build(set, m)).collect();

        let cap_bytes = (opts.mem_cap_gib * 1024.0 * 1024.0 * 1024.0) as u128;
        let hmsearch: Vec<Option<HmSearch>> = [2usize, 4, 5]
            .iter()
            .map(|&tmax| {
                (HmSearch::estimate_postings(set, tmax) * 8 <= cap_bytes)
                    .then(|| HmSearch::build(set, tmax))
            })
            .collect();

        let mut t = Table::new(format!(
            "Fig. 7 — {} (avg ms/query over {} queries; SIH capped at {:.0} s)",
            ds.name(),
            n_q,
            opts.sih_cap_secs
        ));
        let mut header = vec!["method".into()];
        header.extend(TAUS.iter().map(|tau| format!("tau={tau}")));
        t.header(header);

        // SI-bST
        let mut row = vec!["SI-bST".to_string()];
        for &tau in &TAUS {
            let (m, _) = time_queries(&w.queries, n_q, |q| si.search(q, tau));
            row.push(ms(m));
        }
        t.row(row);

        // MI-bST: best m per tau
        let mut row = vec!["MI-bST (best m)".to_string()];
        for &tau in &TAUS {
            let best = mi
                .iter()
                .map(|idx| time_queries(&w.queries, n_q, |q| idx.search(q, tau)).0)
                .fold(f64::INFINITY, f64::min);
            row.push(ms(best));
        }
        t.row(row);

        // SIH with cap
        let mut row = vec![format!("SIH (cap {:.0}s)", opts.sih_cap_secs)];
        for &tau in &TAUS {
            let mut timed_out = false;
            let timer = Timer::start();
            let mut done = 0usize;
            for q in w.queries.iter().take(n_q) {
                match sih.search_capped(q, tau, cap) {
                    CappedResult::Done(_) => done += 1,
                    CappedResult::TimedOut => {
                        timed_out = true;
                        break;
                    }
                }
            }
            if timed_out {
                row.push(format!(">{:.0}s", opts.sih_cap_secs));
            } else {
                row.push(ms(timer.elapsed_ms() / done.max(1) as f64));
            }
        }
        t.row(row);

        // MIH: best m per tau
        let mut row = vec!["MIH (best m)".to_string()];
        for &tau in &TAUS {
            let best = mih
                .iter()
                .map(|idx| time_queries(&w.queries, n_q, |q| idx.search(q, tau)).0)
                .fold(f64::INFINITY, f64::min);
            row.push(ms(best));
        }
        t.row(row);

        // HmSearch: bucket per tau
        let mut row = vec!["HmSearch".to_string()];
        for &tau in &TAUS {
            let bucket = match tau {
                1 | 2 => &hmsearch[0],
                3 | 4 => &hmsearch[1],
                _ => &hmsearch[2],
            };
            match bucket {
                Some(hm) => {
                    let (m, _) = time_queries(&w.queries, n_q, |q| hm.search(q, tau));
                    row.push(ms(m));
                }
                None => row.push("OOM".into()),
            }
        }
        t.row(row);

        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// §VI-C m-sweep: MI-bST and MIH for every m ∈ {2,3,4}.
pub fn msweep(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    let mut out = String::new();
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let set = &w.sketches;
        let n_q = opts.queries.min(w.queries.len());
        let mut t = Table::new(format!("m-sweep — {} (avg ms/query)", ds.name()));
        let mut header = vec!["method".into()];
        header.extend(TAUS.iter().map(|tau| format!("tau={tau}")));
        t.header(header);
        for &m in &MS {
            let mi = MultiBst::build(set, m);
            let mut row = vec![format!("MI-bST m={m}")];
            for &tau in &TAUS {
                row.push(ms(time_queries(&w.queries, n_q, |q| mi.search(q, tau)).0));
            }
            t.row(row);
        }
        for &m in &MS {
            let mih = Mih::build(set, m);
            let mut row = vec![format!("MIH m={m}")];
            for &tau in &TAUS {
                row.push(ms(time_queries(&w.queries, n_q, |q| mih.search(q, tau)).0));
            }
            t.row(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Pruning effectiveness of the bST traversal: average nodes visited /
/// children pruned / ids emitted per query, via the `StatsObserver`
/// collector (the node-visit accounting of Algorithm 1, per τ).
pub fn pruning(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    let mut out = String::new();
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let n_q = opts.queries.min(w.queries.len());
        let bst = SingleBst::build(&w.sketches, BstConfig::default());
        let total_nodes = bst.trie().node_count();
        let mut t = Table::new(format!(
            "Pruning — {} ({}; {} queries; t={} nodes)",
            ds.name(),
            bst.trie().describe(),
            n_q,
            total_nodes
        ));
        t.header(vec![
            "tau".into(),
            "visited/query".into(),
            "pruned/query".into(),
            "emitted/query".into(),
            "visited/t".into(),
        ]);
        let mut ctx = QueryCtx::new();
        for &tau in &TAUS {
            let (mut visited, mut pruned, mut emitted) = (0usize, 0usize, 0usize);
            for q in w.queries.iter().take(n_q) {
                let mut obs = StatsObserver::new(CountOnly::new(tau));
                bst.trie().run(q, &mut ctx, &mut obs);
                visited += obs.stats.visited;
                pruned += obs.stats.pruned;
                emitted += obs.stats.emitted;
            }
            let nq = n_q.max(1) as f64;
            t.row(vec![
                tau.to_string(),
                format!("{:.0}", visited as f64 / nq),
                format!("{:.0}", pruned as f64 / nq),
                format!("{:.1}", emitted as f64 / nq),
                format!("{:.4}", visited as f64 / nq / total_nodes.max(1) as f64),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Top-k (nearest-neighbor) timing: the adaptive `TopK` collector over
/// SI-bST vs brute-force k-NN over the linear scanner, k ∈ {1, 10, 100}.
pub fn topk(opts: &EvalOpts, datasets: &[Dataset]) -> String {
    const KS: [usize; 3] = [1, 10, 100];
    let mut out = String::new();
    for &ds in datasets {
        let w = load_workload(ds, opts);
        let set = &w.sketches;
        let n_q = opts.queries.min(w.queries.len());
        let si = SingleBst::build(set, BstConfig::default());
        let scan = LinearScan::build(set);
        let l = set.l();

        let mut t = Table::new(format!(
            "Top-k — {} (avg ms/query over {} queries; unbounded radius)",
            ds.name(),
            n_q
        ));
        let mut header = vec!["method".into()];
        header.extend(KS.iter().map(|k| format!("k={k}")));
        t.header(header);

        for (name, idx) in [
            ("SI-bST (adaptive τ)", &si as &dyn SearchIndex),
            ("LinearScan", &scan as &dyn SearchIndex),
        ] {
            let mut row = vec![name.to_string()];
            for &k in &KS {
                let timer = Timer::start();
                for q in w.queries.iter().take(n_q) {
                    let hits = idx.top_k(q, k, l);
                    std::hint::black_box(&hits);
                }
                row.push(ms(timer.elapsed_ms() / n_q.max(1) as f64));
            }
            t.row(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> EvalOpts {
        EvalOpts {
            scale: 0.01,
            queries: 10,
            sih_cap_secs: 0.2,
            mem_cap_gib: 1.0,
            seed: 7,
            threads: 2,
        }
    }

    #[test]
    fn table1_lists_all_datasets() {
        let s = table1(&tiny_opts());
        for ds in Dataset::ALL {
            assert!(s.contains(ds.name()), "{s}");
        }
    }

    #[test]
    fn table2_runs_on_review() {
        let s = table2(&tiny_opts(), &[Dataset::Review]);
        assert!(s.contains("review"));
        assert!(s.contains("tau=5"));
    }

    #[test]
    fn table3_runs_on_review() {
        let s = table3(&tiny_opts(), &[Dataset::Review]);
        assert!(s.contains("bST"));
        assert!(s.contains("LOUDS"));
        assert!(s.contains("FST"));
    }

    #[test]
    fn pruning_and_topk_run_on_review() {
        let opts = tiny_opts();
        let s = pruning(&opts, &[Dataset::Review]);
        assert!(s.contains("visited/query"), "{s}");
        let s = topk(&opts, &[Dataset::Review]);
        assert!(s.contains("SI-bST"), "{s}");
        assert!(s.contains("k=100"), "{s}");
    }

    #[test]
    fn fig7_and_table4_run_on_review() {
        let opts = tiny_opts();
        let s4 = table4(&opts, &[Dataset::Review]);
        assert!(s4.contains("SI-bST"));
        assert!(s4.contains("HmSearch"));
        let s7 = fig7(&opts, &[Dataset::Review]);
        assert!(s7.contains("SI-bST"));
        assert!(s7.contains("MIH"));
    }
}
