//! Appendix A cost model and Figure 8.
//!
//! `cost_S` (Eq. 2) — single-index hashing cost per query:
//! `sigs(b,L,τ)·L + |I|` with `|I| = sigs·n/2^{bL}` under the uniform
//! assumption. `cost_M` (Eq. 4) — multi-index cost: per-block signature
//! cost + verification `L·Σ|C_j|`.

use super::report::Table;
use crate::index::blocks::{block_ranges, block_thresholds};
use crate::index::signature::count_signatures;

/// `sigs(b, L, τ)` as f64 (Eq. 3; values overflow u128 quickly for b=8).
pub fn sigs_f64(b: usize, l: usize, tau: usize) -> f64 {
    let c = count_signatures(b, l, tau);
    if c == u128::MAX {
        f64::INFINITY
    } else {
        c as f64
    }
}

/// Eq. 2: single-index cost per query (uniform-database assumption).
pub fn cost_single(b: usize, l: usize, tau: usize, n: f64) -> f64 {
    let sigs = sigs_f64(b, l, tau);
    let space = 2f64.powi((b * l) as i32);
    let expected_hits = sigs * n / space;
    sigs * l as f64 + expected_hits
}

/// Eq. 4: multi-index cost per query with the tight threshold split.
pub fn cost_multi(b: usize, l: usize, tau: usize, m: usize, n: f64) -> f64 {
    let ranges = block_ranges(l, m);
    let thresholds = block_thresholds(tau, m);
    let mut total = 0f64;
    for (j, &(lo, hi)) in ranges.iter().enumerate() {
        let Some(tau_j) = thresholds[j] else { continue };
        let lj = hi - lo;
        let sigs = sigs_f64(b, lj, tau_j);
        let space = 2f64.powi((b * lj) as i32);
        let candidates = sigs * n / space;
        total += sigs * lj as f64 + l as f64 * candidates;
    }
    total
}

/// Figure 8: cost curves for `b ∈ {2,4}`, `L = 32`, `n = 2^32`,
/// `m ∈ {2,3,4}`, `τ ∈ 1..=5`. Returns one Markdown table per `b`.
pub fn fig8() -> String {
    let n = 2f64.powi(32);
    let l = 32;
    let mut out = String::new();
    out.push_str("## Figure 8 — cost model `cost_S` / `cost_M` (L=32, n=2^32)\n\n");
    for &b in &[2usize, 4] {
        let mut t = Table::new(format!("b = {b}"));
        t.header(vec![
            "tau".into(),
            "cost_S".into(),
            "cost_M m=2".into(),
            "cost_M m=3".into(),
            "cost_M m=4".into(),
        ]);
        for tau in 1..=5usize {
            let mut row = vec![tau.to_string(), format!("{:.3e}", cost_single(b, l, tau, n))];
            for m in 2..=4usize {
                row.push(format!("{:.3e}", cost_multi(b, l, tau, m, n)));
            }
            t.row(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_single_grows_exponentially_in_tau_and_b() {
        let n = 2f64.powi(32);
        for b in [2usize, 4] {
            let mut prev = 0.0;
            for tau in 1..=5 {
                let c = cost_single(b, 32, tau, n);
                assert!(c > prev, "monotone in tau");
                prev = c;
            }
        }
        // paper: cost_S explodes with b
        assert!(cost_single(4, 32, 3, n) > 50.0 * cost_single(2, 32, 3, n));
    }

    #[test]
    fn cost_multi_beats_single_for_large_tau() {
        // The crossover: for b=4 the signature blow-up makes cost_S lose
        // from τ=3 on; for b=2 verification cost keeps cost_M above until
        // τ=5 (the paper's Fig. 8 shows exactly this b-dependence, and
        // Fig. 7 mirrors it: SIH competitive at small τ/b only).
        let n = 2f64.powi(32);
        for tau in 3..=5 {
            assert!(
                cost_multi(4, 32, tau, 4, n) < cost_single(4, 32, tau, n),
                "b=4 tau={tau}"
            );
        }
        assert!(cost_multi(2, 32, 5, 4, n) < cost_single(2, 32, 5, n));
        // …and single-index wins at τ=1 for b=2 (for b=4 the block key
        // space is so large that even τ=1 favors multi — candidates ≈ 0).
        assert!(cost_single(2, 32, 1, n) < cost_multi(2, 32, 1, 4, n));
    }

    #[test]
    fn larger_m_softens_tau_growth() {
        // paper: "the increase is relatively small when large m is used"
        let n = 2f64.powi(32);
        let growth = |m: usize| cost_multi(4, 32, 5, m, n) / cost_multi(4, 32, 1, m, n);
        assert!(growth(4) < growth(2));
    }

    #[test]
    fn fig8_renders() {
        let s = fig8();
        assert!(s.contains("cost_S"));
        assert!(s.contains("b = 2"));
        assert!(s.contains("b = 4"));
    }
}
