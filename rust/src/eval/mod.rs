//! Evaluation harness: regenerates every table and figure of the paper
//! (§VI + Appendix A) on the synthetic workloads.
//!
//! | experiment | runner |
//! |---|---|
//! | Table I (datasets)            | [`tables::table1`] |
//! | Table II (avg #solutions)     | [`tables::table2`] |
//! | Table III (succinct tries)    | [`tables::table3`] |
//! | Table IV (space usage)        | [`tables::table4`] |
//! | Fig. 7 (search time, 5 methods) | [`tables::fig7`] |
//! | Fig. 8 (cost model)           | [`cost::fig8`] |
//! | §VI-C m-sweep                 | [`tables::msweep`] |
//! | pruning stats (beyond-paper)  | [`tables::pruning`] |
//! | top-k timing (beyond-paper)   | [`tables::topk`] |
//! | perf trajectory (`BENCH_*.json`) | [`bench::bench`] |
//!
//! Output is Markdown (piped into EXPERIMENTS.md). Absolute numbers are
//! testbed-specific; the *shapes* (who wins, by what factor, where the
//! crossovers sit) are the reproduction targets — see EXPERIMENTS.md.

pub mod bench;
pub mod cost;
pub mod report;
pub mod tables;

/// Options shared by the experiment runners.
#[derive(Debug, Clone)]
pub struct EvalOpts {
    /// Dataset scale multiplier (1.0 = DESIGN.md defaults).
    pub scale: f64,
    /// Number of queries per (dataset, τ) cell (paper: 1000).
    pub queries: usize,
    /// Per-query wall-clock cap for SIH, seconds (paper: 10).
    pub sih_cap_secs: f64,
    /// Memory cap in GiB for index construction — indexes whose size
    /// estimate exceeds it report "OOM" (reproducing the paper's SIFT
    /// HmSearch cell).
    pub mem_cap_gib: f64,
    /// Master seed.
    pub seed: u64,
    /// Worker threads for data generation / query timing.
    pub threads: usize,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            scale: 1.0,
            queries: 200,
            sih_cap_secs: 2.0,
            mem_cap_gib: 8.0,
            seed: 42,
            threads: std::thread::available_parallelism().map_or(4, |p| p.get()),
        }
    }
}
