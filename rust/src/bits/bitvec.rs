//! Plain word-backed bit vector with unaligned multi-bit reads.

use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, Words};
use crate::util::HeapSize;

/// A growable bit vector backed by `u64` words (LSB-first within a word).
///
/// The word storage is a [`Words`] dual representation: built or mutated
/// vectors own their words, while vectors loaded from a mapped snapshot
/// borrow them from the mapping. Mutators go through `Words::to_mut`, so
/// a mapped vector transparently converts to owned on first write (only
/// delta/write-path vectors are ever mutated; mapped base segments stay
/// borrowed for their whole serving life).
#[derive(Debug, Clone, Default)]
pub struct BitVec {
    words: Words,
    len: usize,
}

impl BitVec {
    pub fn new() -> Self {
        BitVec::default()
    }

    /// All-zero bit vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { words: vec![0; len.div_ceil(64)].into(), len }
    }

    pub fn with_capacity(bits: usize) -> Self {
        BitVec { words: Vec::with_capacity(bits.div_ceil(64)).into(), len: 0 }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Underlying words (the last word's high bits beyond `len` are zero).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Appends a single bit.
    #[inline]
    pub fn push(&mut self, bit: bool) {
        let (w, o) = (self.len / 64, self.len % 64);
        let words = self.words.to_mut();
        if o == 0 {
            words.push(0);
        }
        if bit {
            words[w] |= 1u64 << o;
        }
        self.len += 1;
    }

    /// Appends the low `width` bits of `value` (LSB first). `width <= 64`;
    /// bits of `value` above `width` are ignored.
    pub fn push_bits(&mut self, value: u64, width: usize) {
        debug_assert!(width <= 64);
        if width == 0 {
            return;
        }
        let value = if width == 64 { value } else { value & ((1u64 << width) - 1) };
        let (w, o) = (self.len / 64, self.len % 64);
        let words = self.words.to_mut();
        if o == 0 {
            words.push(0);
        }
        words[w] |= value << o;
        if o + width > 64 {
            words.push(value >> (64 - o));
        }
        self.len += width;
    }

    /// Bit at position `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Sets bit `i` to 1.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words.to_mut()[i / 64] |= 1u64 << (i % 64);
    }

    /// Reads `width <= 64` bits starting at bit offset `pos` (unaligned).
    /// Bits beyond `len` read as zero (caller may over-read the tail).
    #[inline]
    pub fn get_bits(&self, pos: usize, width: usize) -> u64 {
        debug_assert!(width <= 64);
        if width == 0 {
            return 0;
        }
        let (w, o) = (pos / 64, pos % 64);
        let lo = self.words.get(w).copied().unwrap_or(0) >> o;
        let val = if o + width > 64 {
            lo | (self.words.get(w + 1).copied().unwrap_or(0) << (64 - o))
        } else {
            lo
        };
        if width == 64 {
            val
        } else {
            val & ((1u64 << width) - 1)
        }
    }

    /// Total number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterates positions of set bits.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let tz = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + tz)
                }
            })
        })
    }

    /// Number of set bits in `[0, i)` computed by scanning — used only for
    /// testing and tiny vectors; real queries go through [`super::RsBitVec`].
    pub fn rank1_slow(&self, i: usize) -> usize {
        debug_assert!(i <= self.len);
        let (w, o) = (i / 64, i % 64);
        let mut r: usize = self.words[..w].iter().map(|x| x.count_ones() as usize).sum();
        if o > 0 {
            r += (self.words[w] & ((1u64 << o) - 1)).count_ones() as usize;
        }
        r
    }
}

impl HeapSize for BitVec {
    fn heap_bytes(&self) -> usize {
        self.words.heap_bytes()
    }
}

impl Persist for BitVec {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.len);
        w.put_u64s(&self.words);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let len = r.get_usize()?;
        let words = r.get_u64s_ref()?;
        ensure(words.len() == len.div_ceil(64), || {
            format!("BitVec: {} words cannot hold {len} bits", words.len())
        })?;
        // push/get_bits rely on the tail bits beyond `len` being zero.
        if len % 64 != 0 {
            ensure(words[len / 64] >> (len % 64) == 0, || {
                "BitVec: nonzero bits beyond len".to_string()
            })?;
        }
        Ok(BitVec { words, len })
    }
}

impl FromIterator<bool> for BitVec {
    fn from_iter<I: IntoIterator<Item = bool>>(iter: I) -> Self {
        let mut bv = BitVec::new();
        for b in iter {
            bv.push(b);
        }
        bv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn push_get_roundtrip() {
        let mut bv = BitVec::new();
        let pattern = [true, false, true, true, false, false, true];
        for _ in 0..20 {
            for &b in &pattern {
                bv.push(b);
            }
        }
        assert_eq!(bv.len(), 140);
        for i in 0..bv.len() {
            assert_eq!(bv.get(i), pattern[i % 7], "bit {i}");
        }
    }

    #[test]
    fn push_bits_matches_push() {
        let mut rng = Rng::new(1);
        let mut a = BitVec::new();
        let mut b = BitVec::new();
        for _ in 0..500 {
            let width = rng.below_usize(65);
            let value = if width == 64 {
                rng.next_u64()
            } else if width == 0 {
                0
            } else {
                rng.next_u64() & ((1u64 << width) - 1)
            };
            a.push_bits(value, width);
            for i in 0..width {
                b.push((value >> i) & 1 == 1);
            }
        }
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert_eq!(a.get(i), b.get(i), "bit {i}");
        }
    }

    #[test]
    fn get_bits_unaligned() {
        let mut bv = BitVec::new();
        let mut rng = Rng::new(2);
        let vals: Vec<u64> = (0..100).map(|_| rng.next_u64()).collect();
        for &v in &vals {
            bv.push_bits(v, 64);
        }
        for _ in 0..2000 {
            let width = 1 + rng.below_usize(64);
            let pos = rng.below_usize(bv.len() - width);
            let got = bv.get_bits(pos, width);
            let mut expect = 0u64;
            for i in 0..width {
                if bv.get(pos + i) {
                    expect |= 1u64 << i;
                }
            }
            assert_eq!(got, expect, "pos={pos} width={width}");
        }
    }

    #[test]
    fn get_bits_tail_overread_is_zero() {
        let mut bv = BitVec::new();
        bv.push_bits(u64::MAX, 10);
        assert_eq!(bv.get_bits(5, 20), 0b11111);
        assert_eq!(bv.get_bits(70, 10), 0);
    }

    #[test]
    fn iter_ones_and_count() {
        let mut bv = BitVec::zeros(300);
        let ones = [0usize, 1, 63, 64, 65, 128, 200, 299];
        for &i in &ones {
            bv.set(i);
        }
        assert_eq!(bv.count_ones(), ones.len());
        assert_eq!(bv.iter_ones().collect::<Vec<_>>(), ones);
    }

    #[test]
    fn persist_roundtrip_and_rejects_tail_garbage() {
        let mut rng = Rng::new(4);
        let bv: BitVec = (0..777).map(|_| rng.f64() < 0.4).collect();
        let bytes = crate::store::to_payload(&bv);
        let got: BitVec =
            crate::store::from_payload(&mut crate::store::ByteReader::new(&bytes)).unwrap();
        assert_eq!(got.len(), bv.len());
        assert_eq!(got.words(), bv.words());
        // nonzero bits beyond len must be rejected
        let mut bad = bv.clone();
        bad.words.to_mut()[777 / 64] |= 1u64 << 63;
        let bytes = crate::store::to_payload(&bad);
        assert!(
            crate::store::from_payload::<BitVec>(&mut crate::store::ByteReader::new(&bytes))
                .is_err()
        );
    }

    #[test]
    fn rank1_slow_matches() {
        let mut rng = Rng::new(3);
        let bv: BitVec = (0..1000).map(|_| rng.f64() < 0.3).collect();
        let mut expected = 0;
        for i in 0..=bv.len() {
            assert_eq!(bv.rank1_slow(i), expected);
            if i < bv.len() && bv.get(i) {
                expected += 1;
            }
        }
    }
}
