//! Rank/select bit vector.
//!
//! Design (space/speed balance chosen for the bST workload, where `rank` is
//! the hot operation — one per TABLE `children()` — and `select` drives the
//! LIST / sparse layers):
//!
//! * rank: absolute `u32` count per 512-bit block (6.25% overhead), query
//!   scans at most 7 words with hardware popcount.
//! * select: every `SELECT_SAMPLE`-th result position is sampled (`u32`),
//!   queries jump to the sampled block and scan forward block-by-block
//!   using the rank directory, then finish with broadword in-word select.
//!
//! Matches the paper's use of sdsl's `rank_support_v`/`select_support_mcl`:
//! `O(1)` rank, `O(1)` amortized select, o(n) space.

use super::broadword::select64;
use super::BitVec;
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, U32s};
use crate::util::HeapSize;

const BLOCK_BITS: usize = 512;
const WORDS_PER_BLOCK: usize = BLOCK_BITS / 64;
const SELECT_SAMPLE: usize = 512;

/// Global count of directory constructions ([`RsBitVec::new`] calls).
/// Diagnostics only: the snapshot tests use it to prove that loading a
/// serialized vector skips re-indexing entirely.
static DIRECTORY_BUILDS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// How many rank/select directories have been built in this process.
pub fn directory_builds() -> u64 {
    DIRECTORY_BUILDS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Which select directories to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelectMode {
    /// rank only (no select queries).
    None,
    /// select over set bits (LIST `B`, sparse `D`).
    #[default]
    Ones,
    /// select over both set and unset bits (LOUDS navigation).
    Both,
}

/// Immutable bit vector with rank/select support.
#[derive(Debug, Clone)]
pub struct RsBitVec {
    bits: BitVec,
    /// Absolute number of ones before each 512-bit block (+ final total).
    block_ranks: U32s,
    /// Sampled positions of every SELECT_SAMPLE-th one.
    select1_samples: U32s,
    /// Sampled positions of every SELECT_SAMPLE-th zero.
    select0_samples: U32s,
    ones: usize,
}

impl RsBitVec {
    /// Builds the directories over `bits`.
    pub fn new(bits: BitVec, mode: SelectMode) -> Self {
        DIRECTORY_BUILDS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        assert!(
            bits.len() < u32::MAX as usize,
            "RsBitVec supports < 2^32 bits per vector"
        );
        let words = bits.words();
        let n_blocks = bits.len().div_ceil(BLOCK_BITS);
        let mut block_ranks = Vec::with_capacity(n_blocks + 1);
        let mut acc: u32 = 0;
        for b in 0..n_blocks {
            block_ranks.push(acc);
            let lo = b * WORDS_PER_BLOCK;
            let hi = (lo + WORDS_PER_BLOCK).min(words.len());
            for &w in &words[lo..hi] {
                acc += w.count_ones();
            }
        }
        block_ranks.push(acc);
        let ones = acc as usize;

        let mut select1_samples = Vec::new();
        let mut select0_samples = Vec::new();
        if mode != SelectMode::None {
            select1_samples = Self::sample_positions(&bits, true);
            if mode == SelectMode::Both {
                select0_samples = Self::sample_positions(&bits, false);
            }
        }
        RsBitVec {
            bits,
            block_ranks: block_ranks.into(),
            select1_samples: select1_samples.into(),
            select0_samples: select0_samples.into(),
            ones,
        }
    }

    fn sample_positions(bits: &BitVec, ones: bool) -> Vec<u32> {
        let mut samples = Vec::new();
        let mut count = 0usize;
        for (wi, &word) in bits.words().iter().enumerate() {
            let mut w = if ones { word } else { !word };
            // Mask tail bits of the final word when sampling zeros.
            if !ones && (wi + 1) * 64 > bits.len() {
                let valid = bits.len() - wi * 64;
                if valid < 64 {
                    w &= (1u64 << valid) - 1;
                }
            }
            while w != 0 {
                let tz = w.trailing_zeros() as usize;
                if count % SELECT_SAMPLE == 0 {
                    samples.push((wi * 64 + tz) as u32);
                }
                count += 1;
                w &= w - 1;
            }
        }
        samples
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Total set bits.
    #[inline]
    pub fn count_ones(&self) -> usize {
        self.ones
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        self.bits.get(i)
    }

    /// Raw words (for windowed scans in the TABLE representation).
    #[inline]
    pub fn words(&self) -> &[u64] {
        self.bits.words()
    }

    /// Unaligned multi-bit read.
    #[inline]
    pub fn get_bits(&self, pos: usize, width: usize) -> u64 {
        self.bits.get_bits(pos, width)
    }

    /// Whether `select1` queries are answerable (directory built, or no
    /// set bits to select). Used by snapshot validation: a loaded
    /// structure must not reach `select1` with a missing directory.
    #[inline]
    pub fn select1_enabled(&self) -> bool {
        !self.select1_samples.is_empty() || self.ones == 0
    }

    /// Whether `select0` queries are answerable.
    #[inline]
    pub fn select0_enabled(&self) -> bool {
        !self.select0_samples.is_empty() || self.len() == self.ones
    }

    /// Number of 1s in `[0, i)`.
    #[inline]
    pub fn rank1(&self, i: usize) -> usize {
        debug_assert!(i <= self.len());
        let block = i / BLOCK_BITS;
        let mut r = self.block_ranks[block] as usize;
        let words = self.bits.words();
        let first_word = block * WORDS_PER_BLOCK;
        let target_word = i / 64;
        for &w in &words[first_word..target_word] {
            r += w.count_ones() as usize;
        }
        let o = i % 64;
        if o > 0 {
            r += (words[target_word] & ((1u64 << o) - 1)).count_ones() as usize;
        }
        r
    }

    /// Number of 0s in `[0, i)`.
    #[inline]
    pub fn rank0(&self, i: usize) -> usize {
        i - self.rank1(i)
    }

    /// Position of the `k`-th (0-based) set bit. `k < count_ones()`.
    pub fn select1(&self, k: usize) -> usize {
        debug_assert!(k < self.ones, "select1 k={k} ones={}", self.ones);
        debug_assert!(!self.select1_samples.is_empty(), "select not enabled");
        // Bracket the block between the surrounding samples, then binary
        // search the rank directory (linear walks were ~60x slower on
        // 1/4096-density vectors; EXPERIMENTS.md §Perf).
        let si = k / SELECT_SAMPLE;
        let mut lo = self.select1_samples[si] as usize / BLOCK_BITS;
        let mut hi = if si + 1 < self.select1_samples.len() {
            self.select1_samples[si + 1] as usize / BLOCK_BITS + 1
        } else {
            self.block_ranks.len() - 1
        };
        // invariant: block_ranks[lo] <= k < block_ranks[hi]
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.block_ranks[mid] as usize <= k {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let block = lo;
        let mut remaining = k - self.block_ranks[block] as usize;
        let words = self.bits.words();
        let lo = block * WORDS_PER_BLOCK;
        let hi = (lo + WORDS_PER_BLOCK).min(words.len());
        for wi in lo..hi {
            let c = words[wi].count_ones() as usize;
            if remaining < c {
                return wi * 64 + select64(words[wi], remaining as u32) as usize;
            }
            remaining -= c;
        }
        unreachable!("select1: rank directory inconsistent")
    }

    /// Position of the `k`-th (0-based) unset bit. Requires `SelectMode::Both`.
    pub fn select0(&self, k: usize) -> usize {
        let zeros = self.len() - self.ones;
        debug_assert!(k < zeros, "select0 k={k} zeros={zeros}");
        debug_assert!(!self.select0_samples.is_empty() || zeros == 0);
        // zeros before block boundary b = min(b*512, len) - block_ranks[b]
        let zeros_before = |b: usize| -> usize {
            (b * BLOCK_BITS).min(self.len()) - self.block_ranks[b] as usize
        };
        let si = k / SELECT_SAMPLE;
        let mut lo = self.select0_samples[si] as usize / BLOCK_BITS;
        let mut hi = if si + 1 < self.select0_samples.len() {
            self.select0_samples[si + 1] as usize / BLOCK_BITS + 1
        } else {
            self.block_ranks.len() - 1
        };
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if zeros_before(mid) <= k {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let block = lo;
        let mut remaining = k - zeros_before(block);
        let words = self.bits.words();
        let lo = block * WORDS_PER_BLOCK;
        let hi = (lo + WORDS_PER_BLOCK).min(words.len());
        for wi in lo..hi {
            let inv = !words[wi];
            let c = inv.count_ones() as usize;
            if remaining < c {
                return wi * 64 + select64(inv, remaining as u32) as usize;
            }
            remaining -= c;
        }
        unreachable!("select0: rank directory inconsistent")
    }
}

/// The rank/select directories are part of the payload, so a loaded
/// vector answers `rank`/`select` immediately — no re-indexing pass.
/// Validation is structural (lengths, monotonicity, sampled positions
/// hitting bits of the right parity, total popcount): cheap linear scans
/// that never rebuild a directory.
impl Persist for RsBitVec {
    fn write_into(&self, w: &mut ByteWriter) {
        self.bits.write_into(w);
        w.put_u32s(&self.block_ranks);
        w.put_u32s(&self.select1_samples);
        w.put_u32s(&self.select0_samples);
        w.put_usize(self.ones);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let bits = BitVec::read_from(r)?;
        let block_ranks = r.get_u32s_ref()?;
        let select1_samples = r.get_u32s_ref()?;
        let select0_samples = r.get_u32s_ref()?;
        let ones = r.get_usize()?;
        let len = bits.len();
        ensure(len < u32::MAX as usize, || "RsBitVec: length >= 2^32".into())?;
        let n_blocks = len.div_ceil(BLOCK_BITS);
        ensure(block_ranks.len() == n_blocks + 1, || {
            format!(
                "RsBitVec: rank directory has {} entries, expected {}",
                block_ranks.len(),
                n_blocks + 1
            )
        })?;
        // Verify every rank entry against the actual words — one popcount
        // pass, no directory rebuilt. rank1/select1/select0 assume the
        // directory is exact; with this check a crafted-but-checksummed
        // snapshot cannot steer a query into the `unreachable!` scans.
        {
            let words = bits.words();
            let mut acc: u32 = 0;
            for (blk, &stored) in block_ranks[..n_blocks].iter().enumerate() {
                ensure(stored == acc, || {
                    format!("RsBitVec: rank directory wrong at block {blk}")
                })?;
                let lo = blk * WORDS_PER_BLOCK;
                let hi = (lo + WORDS_PER_BLOCK).min(words.len());
                for &w in &words[lo..hi] {
                    acc += w.count_ones();
                }
            }
            ensure(block_ranks[n_blocks] == acc && acc as usize == ones, || {
                format!("RsBitVec: stored ones {ones} != actual popcount {acc}")
            })?;
        }
        let zeros = len - ones;
        for (samples, expected_count, want_set) in [
            (&select1_samples, ones.div_ceil(SELECT_SAMPLE), true),
            (&select0_samples, zeros.div_ceil(SELECT_SAMPLE), false),
        ] {
            // empty = that select directory was not built (SelectMode).
            if samples.is_empty() {
                continue;
            }
            ensure(samples.len() == expected_count, || {
                format!(
                    "RsBitVec: {} select samples, expected {expected_count}",
                    samples.len()
                )
            })?;
            ensure(
                samples.windows(2).all(|w| w[0] < w[1])
                    && samples.iter().all(|&p| (p as usize) < len),
                || "RsBitVec: select samples not increasing in-range positions".into(),
            )?;
            ensure(
                samples.iter().all(|&p| bits.get(p as usize) == want_set),
                || "RsBitVec: select sample points at a bit of the wrong parity".into(),
            )?;
        }
        let rs = RsBitVec { bits, block_ranks, select1_samples, select0_samples, ones };
        // Each sample must be the (i·512)-th bit of its parity exactly —
        // rank1/rank0 are trustworthy now that the directory is verified.
        for (i, &p) in rs.select1_samples.iter().enumerate() {
            ensure(rs.rank1(p as usize) == i * SELECT_SAMPLE, || {
                format!("RsBitVec: select1 sample {i} is not the {}-th set bit", i * SELECT_SAMPLE)
            })?;
        }
        for (i, &p) in rs.select0_samples.iter().enumerate() {
            ensure(rs.rank0(p as usize) == i * SELECT_SAMPLE, || {
                format!(
                    "RsBitVec: select0 sample {i} is not the {}-th unset bit",
                    i * SELECT_SAMPLE
                )
            })?;
        }
        Ok(rs)
    }
}

impl HeapSize for RsBitVec {
    fn heap_bytes(&self) -> usize {
        self.bits.heap_bytes()
            + self.block_ranks.heap_bytes()
            + self.select1_samples.heap_bytes()
            + self.select0_samples.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_bv(n: usize, density: f64, seed: u64) -> BitVec {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f64() < density).collect()
    }

    #[test]
    fn rank_matches_slow() {
        for &density in &[0.01, 0.3, 0.5, 0.9, 0.999] {
            let bv = random_bv(5000, density, 42);
            let rs = RsBitVec::new(bv.clone(), SelectMode::None);
            for i in (0..=5000).step_by(7) {
                assert_eq!(rs.rank1(i), bv.rank1_slow(i), "i={i} d={density}");
                assert_eq!(rs.rank0(i), i - bv.rank1_slow(i));
            }
        }
    }

    #[test]
    fn select1_inverts_rank() {
        for &density in &[0.02, 0.5, 0.97] {
            let bv = random_bv(20_000, density, 7);
            let rs = RsBitVec::new(bv, SelectMode::Ones);
            for k in (0..rs.count_ones()).step_by(13) {
                let pos = rs.select1(k);
                assert!(rs.get(pos), "k={k}");
                assert_eq!(rs.rank1(pos), k, "k={k} d={density}");
            }
        }
    }

    #[test]
    fn select0_inverts_rank0() {
        for &density in &[0.02, 0.5, 0.97] {
            let bv = random_bv(20_000, density, 9);
            let rs = RsBitVec::new(bv, SelectMode::Both);
            let zeros = rs.len() - rs.count_ones();
            for k in (0..zeros).step_by(13) {
                let pos = rs.select0(k);
                assert!(!rs.get(pos), "k={k}");
                assert_eq!(rs.rank0(pos), k, "k={k} d={density}");
            }
        }
    }

    #[test]
    fn edge_cases() {
        // All ones.
        let bv: BitVec = (0..700).map(|_| true).collect();
        let rs = RsBitVec::new(bv, SelectMode::Both);
        assert_eq!(rs.count_ones(), 700);
        assert_eq!(rs.select1(699), 699);
        assert_eq!(rs.rank1(700), 700);
        // All zeros.
        let bv: BitVec = (0..700).map(|_| false).collect();
        let rs = RsBitVec::new(bv, SelectMode::Both);
        assert_eq!(rs.count_ones(), 0);
        assert_eq!(rs.select0(699), 699);
        // Single bit at the very end.
        let mut bv = BitVec::zeros(1025);
        bv.set(1024);
        let rs = RsBitVec::new(bv, SelectMode::Ones);
        assert_eq!(rs.select1(0), 1024);
        assert_eq!(rs.rank1(1024), 0);
        assert_eq!(rs.rank1(1025), 1);
    }

    #[test]
    fn empty_vector() {
        let rs = RsBitVec::new(BitVec::new(), SelectMode::Both);
        assert_eq!(rs.len(), 0);
        assert_eq!(rs.count_ones(), 0);
        assert_eq!(rs.rank1(0), 0);
    }

    #[test]
    fn persist_roundtrip_preserves_directories() {
        for mode in [SelectMode::None, SelectMode::Ones, SelectMode::Both] {
            let bv = random_bv(10_000, 0.3, 21);
            let rs = RsBitVec::new(bv, mode);
            let bytes = crate::store::to_payload(&rs);
            let got: RsBitVec =
                crate::store::from_payload(&mut crate::store::ByteReader::new(&bytes))
                    .unwrap();
            assert_eq!(got.block_ranks, rs.block_ranks);
            assert_eq!(got.select1_samples, rs.select1_samples);
            assert_eq!(got.select0_samples, rs.select0_samples);
            assert_eq!(got.ones, rs.ones);
            for i in (0..=got.len()).step_by(97) {
                assert_eq!(got.rank1(i), rs.rank1(i));
            }
        }
    }

    #[test]
    fn persist_rejects_inconsistent_directories() {
        let rs = RsBitVec::new(random_bv(5000, 0.5, 22), SelectMode::Ones);
        // wrong ones count
        let mut bad = rs.clone();
        bad.ones += 1;
        let bytes = crate::store::to_payload(&bad);
        assert!(crate::store::from_payload::<RsBitVec>(
            &mut crate::store::ByteReader::new(&bytes)
        )
        .is_err());
        // non-monotone rank directory
        let mut bad = rs.clone();
        bad.block_ranks.to_mut()[1] = u32::MAX;
        let bytes = crate::store::to_payload(&bad);
        assert!(crate::store::from_payload::<RsBitVec>(
            &mut crate::store::ByteReader::new(&bytes)
        )
        .is_err());
        // select sample pointing at a zero bit
        let mut bad = rs;
        if let Some(first_zero) = (0..bad.len()).find(|&i| !bad.get(i)) {
            bad.select1_samples.to_mut()[0] = first_zero as u32;
            let bytes = crate::store::to_payload(&bad);
            assert!(crate::store::from_payload::<RsBitVec>(
                &mut crate::store::ByteReader::new(&bytes)
            )
            .is_err());
        }
    }

    #[test]
    fn sparse_select_crosses_many_blocks() {
        // ones every 4096 bits: select must skip multiple blocks per query.
        let mut bv = BitVec::zeros(1 << 18);
        let mut expected = Vec::new();
        let mut i = 0;
        while i < bv.len() {
            bv.set(i);
            expected.push(i);
            i += 4096;
        }
        let rs = RsBitVec::new(bv, SelectMode::Ones);
        for (k, &pos) in expected.iter().enumerate() {
            assert_eq!(rs.select1(k), pos);
        }
    }
}
