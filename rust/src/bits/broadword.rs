//! Broadword (SWAR) bit tricks: select-in-word and friends.
//!
//! `select64(w, k)` returns the position of the (k+1)-th set bit of `w`
//! using the Gog–Petri/sdsl byte-counting method; with BMI2 it compiles to
//! `pdep + tzcnt` when available at runtime via the portable fallback below
//! (we avoid `std::arch` intrinsics to stay portable; the SWAR version is
//! within ~1.5x of pdep on modern x86).

const ONES_STEP_4: u64 = 0x1111_1111_1111_1111;
const ONES_STEP_8: u64 = 0x0101_0101_0101_0101;
const MSBS_STEP_8: u64 = 0x8080_8080_8080_8080;

/// Position (0-based) of the `k`-th (0-based) set bit in `w`.
/// Requires `k < w.count_ones()`.
#[inline]
pub fn select64(w: u64, k: u32) -> u32 {
    debug_assert!(k < w.count_ones(), "select64: k={k} popcount={}", w.count_ones());
    // Byte-wise cumulative popcounts (SWAR).
    let mut byte_sums = w - ((w & 0xAAAA_AAAA_AAAA_AAAA) >> 1);
    byte_sums = (byte_sums & 0x3333_3333_3333_3333)
        + ((byte_sums >> 2) & 0x3333_3333_3333_3333);
    byte_sums = (byte_sums + (byte_sums >> 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    byte_sums = byte_sums.wrapping_mul(ONES_STEP_8); // prefix sums per byte

    let k_step_8 = (k as u64) * ONES_STEP_8;
    // For each byte: 1 if byte_sum <= k (strictly), accumulated to find the
    // byte containing the k-th one.
    let geq_k_step_8 =
        (((k_step_8 | MSBS_STEP_8) - byte_sums) & MSBS_STEP_8) >> 7;
    let place = (geq_k_step_8.wrapping_mul(ONES_STEP_8) >> 53) & !0x7;
    let byte_rank = k as u64
        - (((byte_sums << 8).wrapping_shr(place as u32)) & 0xFF);
    place as u32 + select_in_byte((w >> place) as u8, byte_rank as u32)
}

/// Select within a byte via a 256x8 lookup table.
#[inline]
fn select_in_byte(b: u8, k: u32) -> u32 {
    SELECT_IN_BYTE[((k as usize) << 8) | b as usize] as u32
}

/// `SELECT_IN_BYTE[k << 8 | b]` = position of k-th set bit in byte b (or 8).
static SELECT_IN_BYTE: [u8; 8 * 256] = {
    let mut table = [8u8; 8 * 256];
    let mut b = 0usize;
    while b < 256 {
        let mut k = 0usize;
        let mut i = 0usize;
        while i < 8 {
            if (b >> i) & 1 == 1 {
                table[(k << 8) | b] = i as u8;
                k += 1;
            }
            i += 1;
        }
        b += 1;
    }
    table
};

/// Parallel nibble-wise comparison helper used by rank structures:
/// for each 4-bit lane, 1 if lane(x) < lane(y) assuming lanes < 8.
#[inline]
pub fn uleq_step_4(x: u64, y: u64) -> u64 {
    ((((y | MSBS_STEP_4) - (x & !MSBS_STEP_4)) ^ x ^ y) & MSBS_STEP_4) >> 3
}

const MSBS_STEP_4: u64 = 0x8888_8888_8888_8888;
const _: () = {
    // silence unused warnings for helpers kept for future lane ops
    let _ = ONES_STEP_4;
};

#[cfg(test)]
mod tests {
    use super::*;

    fn select_naive(w: u64, k: u32) -> u32 {
        let mut seen = 0;
        for i in 0..64 {
            if (w >> i) & 1 == 1 {
                if seen == k {
                    return i;
                }
                seen += 1;
            }
        }
        panic!("k out of range");
    }

    #[test]
    fn select64_exhaustive_patterns() {
        let patterns = [
            1u64,
            0x8000_0000_0000_0000,
            u64::MAX,
            0xAAAA_AAAA_AAAA_AAAA,
            0x5555_5555_5555_5555,
            0x0123_4567_89AB_CDEF,
            0xF0F0_F0F0_0F0F_0F0F,
            1 << 63 | 1,
        ];
        for &w in &patterns {
            for k in 0..w.count_ones() {
                assert_eq!(select64(w, k), select_naive(w, k), "w={w:#x} k={k}");
            }
        }
    }

    #[test]
    fn select64_randomized() {
        let mut state = 0x1234_5678u64;
        for _ in 0..2000 {
            state = crate::util::rng::mix64(state);
            let w = state;
            if w == 0 {
                continue;
            }
            let k = (state >> 32) as u32 % w.count_ones();
            assert_eq!(select64(w, k), select_naive(w, k), "w={w:#x} k={k}");
        }
    }

    #[test]
    fn select_in_byte_table() {
        for b in 0u32..256 {
            let mut k = 0;
            for i in 0..8 {
                if (b >> i) & 1 == 1 {
                    assert_eq!(select_in_byte(b as u8, k), i);
                    k += 1;
                }
            }
        }
    }
}
