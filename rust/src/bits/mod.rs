//! Succinct bit-vector substrate.
//!
//! The paper's bST is built on *rank/select* data structures (Jacobson
//! 1989); the original implementation used sdsl. This module provides our
//! own engineered equivalents:
//!
//! * [`BitVec`] — growable, word-backed bit vector with unaligned reads.
//! * [`broadword`] — in-word popcount/select primitives.
//! * [`RsBitVec`] — rank9-style rank directory + position-sampled select
//!   (both for 1s and 0s), `O(1)` rank, `O(1)` amortized select.
//! * [`IntVec`] — fixed-width packed integer vector (edge labels, ids).

pub mod bitvec;
pub mod broadword;
pub mod intvec;
pub mod rsvec;

pub use bitvec::BitVec;
pub use intvec::IntVec;
pub use rsvec::RsBitVec;
