//! Fixed-width packed integer vector.
//!
//! Stores `n` integers of `width` bits each contiguously; the LIST label
//! arrays `C_ℓ` use `width = b`, postings offsets use wider entries.

use super::BitVec;
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::HeapSize;

/// Immutable-width, growable packed integer vector.
#[derive(Debug, Clone)]
pub struct IntVec {
    bits: BitVec,
    width: usize,
    len: usize,
}

impl IntVec {
    /// Creates an empty vector of `width`-bit entries (`1 <= width <= 64`).
    pub fn new(width: usize) -> Self {
        assert!((1..=64).contains(&width));
        IntVec { bits: BitVec::new(), width, len: 0 }
    }

    /// Smallest width that can hold `max_value`.
    pub fn width_for(max_value: u64) -> usize {
        (64 - max_value.leading_zeros() as usize).max(1)
    }

    pub fn with_capacity(width: usize, n: usize) -> Self {
        assert!((1..=64).contains(&width));
        IntVec { bits: BitVec::with_capacity(width * n), width, len: 0 }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    /// Appends `value` (must fit in `width` bits).
    #[inline]
    pub fn push(&mut self, value: u64) {
        debug_assert!(self.width == 64 || value < (1u64 << self.width));
        self.bits.push_bits(value, self.width);
        self.len += 1;
    }

    /// Entry at index `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        debug_assert!(i < self.len);
        self.bits.get_bits(i * self.width, self.width)
    }

    /// Iterates all entries.
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

impl Persist for IntVec {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.width);
        w.put_usize(self.len);
        self.bits.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let width = r.get_usize()?;
        let len = r.get_usize()?;
        let bits = BitVec::read_from(r)?;
        ensure((1..=64).contains(&width), || {
            format!("IntVec: invalid width {width}")
        })?;
        let need = len
            .checked_mul(width)
            .ok_or_else(|| StoreError::Corrupt(format!("IntVec: {len}x{width} overflows")))?;
        ensure(bits.len() == need, || {
            format!("IntVec: {} bits != len*width = {need}", bits.len())
        })?;
        Ok(IntVec { bits, width, len })
    }
}

impl HeapSize for IntVec {
    fn heap_bytes(&self) -> usize {
        self.bits.heap_bytes()
    }
}

impl FromIterator<u64> for IntVec {
    /// Builds with the minimal width for the maximum element (two passes).
    fn from_iter<I: IntoIterator<Item = u64>>(iter: I) -> Self {
        let items: Vec<u64> = iter.into_iter().collect();
        let width = IntVec::width_for(items.iter().copied().max().unwrap_or(0));
        let mut iv = IntVec::with_capacity(width, items.len());
        for x in items {
            iv.push(x);
        }
        iv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(5);
        for width in 1..=64usize {
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..200).map(|_| rng.next_u64() & mask).collect();
            let mut iv = IntVec::new(width);
            for &v in &vals {
                iv.push(v);
            }
            assert_eq!(iv.len(), 200);
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(iv.get(i), v, "width={width} i={i}");
            }
        }
    }

    #[test]
    fn width_for_values() {
        assert_eq!(IntVec::width_for(0), 1);
        assert_eq!(IntVec::width_for(1), 1);
        assert_eq!(IntVec::width_for(2), 2);
        assert_eq!(IntVec::width_for(3), 2);
        assert_eq!(IntVec::width_for(255), 8);
        assert_eq!(IntVec::width_for(256), 9);
        assert_eq!(IntVec::width_for(u64::MAX), 64);
    }

    #[test]
    fn from_iter_minimal_width() {
        let iv: IntVec = vec![1u64, 5, 200].into_iter().collect();
        assert_eq!(iv.width(), 8);
        assert_eq!(iv.iter().collect::<Vec<_>>(), vec![1, 5, 200]);
    }

    #[test]
    fn space_is_compact() {
        let mut iv = IntVec::with_capacity(2, 1000);
        for i in 0..1000u64 {
            iv.push(i % 4);
        }
        // 2000 bits ≈ 250 bytes; allow word-granularity slack.
        assert!(iv.heap_bytes() <= 260 + 8, "heap={}", iv.heap_bytes());
    }
}
