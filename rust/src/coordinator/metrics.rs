//! Service metrics: lock-free counters and a log-bucketed latency
//! histogram (p50/p99 without storing samples).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of histogram buckets: bucket `i` covers `[2^i, 2^{i+1})` µs.
const BUCKETS: usize = 32;

/// Shared, thread-safe service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub solutions: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Rows appended through the write path.
    pub inserts: AtomicU64,
    /// Ids tombstoned.
    pub deletes: AtomicU64,
    /// Shard merges completed (background installs + force merges).
    pub merges: AtomicU64,
    /// Shard workers restarted by the supervisor after a panic
    /// (rebuilt from snapshot + WAL replay). A nonzero value means the
    /// server kept serving through at least one isolated failure.
    pub worker_restarts: AtomicU64,
    /// Shards parked by the supervisor after exhausting their restart
    /// budget (too many panics inside one window). A parked shard fails
    /// its queries instead of looping rebuilds; nonzero means the
    /// engine is serving degraded and needs operator attention.
    pub shards_parked: AtomicU64,
    /// Fsync syscalls issued to cover write acknowledgements under
    /// `--wal-sync always`: one per record on the inline path, one per
    /// *group* under group commit. Stays 0 under `batch`/`off`, whose
    /// acks never wait on an fsync.
    pub wal_fsyncs: AtomicU64,
    /// WAL records those fsyncs made durable. The ratio
    /// `wal_group_records / wal_fsyncs` is the group-commit coalescing
    /// factor (1.0 = no grouping happened).
    pub wal_group_records: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Records one query with its latency and solution count.
    pub fn record_query(&self, latency_us: u64, solutions: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.solutions.fetch_add(solutions as u64, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        let bucket = (64 - latency_us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Records `n` rows inserted.
    pub fn record_inserts(&self, n: usize) {
        self.inserts.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Records write-path fsyncs and the WAL records they covered.
    pub fn record_wal_fsync(&self, fsyncs: u64, records: u64) {
        self.wal_fsyncs.fetch_add(fsyncs, Ordering::Relaxed);
        self.wal_group_records.fetch_add(records, Ordering::Relaxed);
    }

    /// Approximate percentile from the histogram (upper bucket bound).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }

    pub fn mean_latency_us(&self) -> f64 {
        let q = self.queries.load(Ordering::Relaxed);
        if q == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / q as f64
        }
    }

    /// JSON snapshot for the `stats` endpoint.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("queries", Json::num(self.queries.load(Ordering::Relaxed) as f64)),
            ("solutions", Json::num(self.solutions.load(Ordering::Relaxed) as f64)),
            ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
            ("inserts", Json::num(self.inserts.load(Ordering::Relaxed) as f64)),
            ("deletes", Json::num(self.deletes.load(Ordering::Relaxed) as f64)),
            ("merges", Json::num(self.merges.load(Ordering::Relaxed) as f64)),
            (
                "worker_restarts",
                Json::num(self.worker_restarts.load(Ordering::Relaxed) as f64),
            ),
            (
                "shards_parked",
                Json::num(self.shards_parked.load(Ordering::Relaxed) as f64),
            ),
            ("wal_fsyncs", Json::num(self.wal_fsyncs.load(Ordering::Relaxed) as f64)),
            (
                "wal_group_records",
                Json::num(self.wal_group_records.load(Ordering::Relaxed) as f64),
            ),
            ("mean_latency_us", Json::num(self.mean_latency_us())),
            ("p50_latency_us", Json::num(self.latency_percentile_us(50.0) as f64)),
            ("p99_latency_us", Json::num(self.latency_percentile_us(99.0) as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_query(100, 5);
        m.record_query(200, 1);
        m.record_query(10_000, 0);
        assert_eq!(m.queries.load(Ordering::Relaxed), 3);
        assert_eq!(m.solutions.load(Ordering::Relaxed), 6);
        let snap = m.snapshot();
        assert_eq!(snap.get("queries").unwrap().as_usize(), Some(3));
        assert!(m.mean_latency_us() > 1000.0);
    }

    #[test]
    fn percentiles_are_monotone() {
        let m = Metrics::new();
        for i in 1..=1000u64 {
            m.record_query(i * 10, 0);
        }
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!(p50 <= p99);
        assert!(p50 >= 4096, "p50 bucket bound for ~5000us: {p50}");
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
    }
}
