//! Dynamic request batcher.
//!
//! Connection threads submit queries and block on their reply channel;
//! the batcher thread drains the queue into batches bounded by
//! `max_batch` / `max_delay_us` and runs each batch through the engine as
//! one fan-out round. Under load batches fill instantly (throughput
//! mode); a lone request waits at most `max_delay_us` (latency mode) —
//! the standard dynamic-batching contract.
//!
//! All three query modes (id search, count, top-k) flow through the
//! batcher: a batch is mixed-mode and executes via
//! [`Engine::run_batch_blocked`], which groups compatible queries (same
//! τ, same mode; `ServeConfig::block_width` caps the block size) so each
//! block shares one pass over every shard's trie and plane-word stream.
//! Results are identical to serial execution, and every served query —
//! whatever its mode — still records real per-query wall time: a block's
//! elapsed time is attributed to its queries by share of live work (see
//! the protocol docs). `block_width = 1` falls back to
//! [`Engine::run_batch`].
//!
//! The engine is read through an [`EngineSlot`] at the start of each
//! batch, so a `reload` (snapshot swap) takes effect on the next batch
//! without restarting the batcher.

use super::engine::{Engine, EngineSlot, QueryMode, QueryResult, QuerySpec};
use super::ServeConfig;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One queued request. The spec holds the query as `Arc<[u8]>` so the
/// engine's shard fan-out shares the bytes instead of cloning them per
/// shard.
struct Pending {
    spec: QuerySpec,
    reply: Sender<QueryResult>,
}

enum Msg {
    Req(Pending),
    /// Explicit shutdown: connection threads may still hold submitters
    /// (blocked on idle sockets), so channel-closure alone cannot signal
    /// termination — see the deadlock regression test below.
    Quit,
}

/// Handle used by connection threads.
#[derive(Clone)]
pub struct BatchSubmitter {
    tx: Sender<Msg>,
}

impl BatchSubmitter {
    /// Submits a fully specified query and blocks until its result
    /// arrives — the unified entry point mirroring [`Engine::query`].
    /// `None` when the batcher has shut down mid-flight.
    pub fn query(&self, spec: QuerySpec) -> Option<QueryResult> {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(Msg::Req(Pending { spec, reply: reply_tx })).ok()?;
        reply_rx.recv().ok()
    }

    /// Submits an id search and blocks until its result arrives. `None`
    /// when the batcher has shut down or the query failed.
    pub fn search(&self, q: Vec<u8>, tau: usize) -> Option<Vec<u32>> {
        let spec = QuerySpec { q: q.into(), tau, mode: QueryMode::Ids };
        match self.query(spec)? {
            QueryResult::Ids(ids) => Some(ids),
            _ => None,
        }
    }

    /// Submits a counting query.
    pub fn count(&self, q: Vec<u8>, tau: usize) -> Option<usize> {
        let spec = QuerySpec { q: q.into(), tau, mode: QueryMode::Count };
        match self.query(spec)? {
            QueryResult::Count(c) => Some(c),
            _ => None,
        }
    }

    /// Submits a top-k query (radius `tau`).
    pub fn topk(&self, q: Vec<u8>, k: usize, tau: usize) -> Option<Vec<(u32, usize)>> {
        let spec = QuerySpec { q: q.into(), tau, mode: QueryMode::TopK(k) };
        match self.query(spec)? {
            QueryResult::TopK(hits) => Some(hits),
            _ => None,
        }
    }
}

/// The batcher thread plus its submitter handle.
pub struct Batcher {
    submitter: BatchSubmitter,
    handle: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(slot: Arc<EngineSlot>, cfg: &ServeConfig) -> Self {
        let (tx, rx) = channel::<Msg>();
        let max_batch = cfg.max_batch.max(1);
        let max_delay = Duration::from_micros(cfg.max_delay_us);
        let block_width = cfg.block_width.max(1);
        let handle = std::thread::Builder::new()
            .name("bst-batcher".into())
            .spawn(move || Self::run(slot, rx, max_batch, max_delay, block_width))
            .expect("spawn batcher");
        Batcher { submitter: BatchSubmitter { tx }, handle: Some(handle) }
    }

    /// Convenience for tests and embedded use: a batcher over a fixed
    /// engine (no reload).
    pub fn start_fixed(engine: Arc<Engine>, cfg: &ServeConfig) -> Self {
        Self::start(Arc::new(EngineSlot::new(engine)), cfg)
    }

    pub fn submitter(&self) -> BatchSubmitter {
        self.submitter.clone()
    }

    fn run(
        slot: Arc<EngineSlot>,
        rx: Receiver<Msg>,
        max_batch: usize,
        max_delay: Duration,
        block_width: usize,
    ) {
        loop {
            // Block for the first request (idle: no spinning).
            let first = match rx.recv() {
                Ok(Msg::Req(p)) => p,
                Ok(Msg::Quit) | Err(_) => return,
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + max_delay;
            let mut quit = false;
            // Fill until the batch is full or the deadline passes.
            while batch.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Req(p)) => batch.push(p),
                    Ok(Msg::Quit) => {
                        quit = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // Execute the whole batch as one round (Arc clones, no
            // copies) against the engine serving *now*.
            let engine = slot.current();
            let queries: Vec<(Arc<[u8]>, usize, QueryMode)> = batch
                .iter()
                .map(|p| (Arc::clone(&p.spec.q), p.spec.tau, p.spec.mode))
                .collect();
            let results = engine.run_batch_blocked(&queries, block_width);
            for (p, r) in batch.into_iter().zip(results) {
                let _ = p.reply.send(r);
            }
            if quit {
                return;
            }
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Explicit Quit: outstanding submitter clones in connection
        // threads must not keep the batcher alive.
        let _ = self.submitter.tx.send(Msg::Quit);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::ShardIndexKind;
    use crate::sketch::SketchSet;
    use crate::trie::bst::BstConfig;
    use crate::util::Rng;

    fn engine(n: usize) -> Arc<Engine> {
        let mut rng = Rng::new(7);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..8).map(|_| rng.below(4) as u8).collect())
            .collect();
        let set = SketchSet::from_rows(2, 8, &rows);
        Arc::new(Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default())))
    }

    #[test]
    fn single_request_round_trips() {
        let eng = engine(200);
        let cfg = ServeConfig { max_batch: 16, max_delay_us: 100, ..Default::default() };
        let batcher = Batcher::start_fixed(Arc::clone(&eng), &cfg);
        let sub = batcher.submitter();
        let q = vec![0u8; 8];
        let direct = {
            let mut v = eng.search(&q, 8);
            v.sort();
            v
        };
        let mut got = sub.search(q, 8).unwrap();
        got.sort();
        assert_eq!(got, direct);
    }

    #[test]
    fn count_and_topk_ride_the_batcher() {
        let eng = engine(400);
        let cfg = ServeConfig { max_batch: 8, max_delay_us: 200, ..Default::default() };
        let batcher = Batcher::start_fixed(Arc::clone(&eng), &cfg);
        let sub = batcher.submitter();
        let q = vec![1u8, 2, 3, 0, 1, 2, 3, 0];
        assert_eq!(sub.count(q.clone(), 3).unwrap(), eng.count(&q, 3));
        assert_eq!(sub.topk(q.clone(), 5, 8).unwrap(), eng.top_k(&q, 5, 8));
        // all three went through run_batch → batches advanced
        let batches = eng.metrics().batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches >= 2, "batches={batches}");
    }

    #[test]
    fn concurrent_submitters_get_correct_answers() {
        let eng = engine(500);
        let cfg = ServeConfig { max_batch: 8, max_delay_us: 500, ..Default::default() };
        let batcher = Batcher::start_fixed(Arc::clone(&eng), &cfg);
        let mut handles = Vec::new();
        for t in 0..16 {
            let sub = batcher.submitter();
            let eng = Arc::clone(&eng);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..20 {
                    let q: Vec<u8> = (0..8).map(|_| rng.below(4) as u8).collect();
                    let tau = rng.below_usize(4);
                    let mut got = sub.search(q.clone(), tau).unwrap();
                    got.sort();
                    let mut expect = eng.search(&q, tau);
                    expect.sort();
                    assert_eq!(got, expect);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let batches = eng.metrics().batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches >= 1);
    }

    #[test]
    fn blocked_and_serial_batchers_agree() {
        let eng = engine(400);
        let mut rng = Rng::new(11);
        let queries: Vec<(Vec<u8>, usize)> = (0..20)
            .map(|_| {
                let q: Vec<u8> = (0..8).map(|_| rng.below(4) as u8).collect();
                (q, rng.below_usize(4))
            })
            .collect();
        let run = |width: usize| {
            let cfg = ServeConfig {
                max_batch: 32,
                max_delay_us: 2000,
                block_width: width,
                ..Default::default()
            };
            let batcher = Batcher::start_fixed(Arc::clone(&eng), &cfg);
            let sub = batcher.submitter();
            queries
                .iter()
                .map(|(q, tau)| {
                    let mut v = sub.search(q.clone(), *tau).unwrap();
                    v.sort_unstable();
                    v
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(8), "blocked batcher must match serial");
    }

    /// Regression: dropping the batcher while submitter clones are still
    /// held (idle connections) must not deadlock.
    #[test]
    fn drop_with_live_submitters_terminates() {
        let eng = engine(100);
        let cfg = ServeConfig::default();
        let batcher = Batcher::start_fixed(eng, &cfg);
        let _held: Vec<BatchSubmitter> = (0..4).map(|_| batcher.submitter()).collect();
        let t = std::time::Instant::now();
        drop(batcher); // must return promptly despite `_held`
        assert!(t.elapsed() < Duration::from_secs(2));
        // held submitters now observe shutdown
        assert!(_held[0].search(vec![0; 8], 1).is_none());
    }

    #[test]
    fn slot_swap_is_picked_up_by_next_batch() {
        let a = engine(100);
        let b = engine(300);
        let slot = Arc::new(EngineSlot::new(Arc::clone(&a)));
        let cfg = ServeConfig { max_batch: 4, max_delay_us: 100, ..Default::default() };
        let batcher = Batcher::start(Arc::clone(&slot), &cfg);
        let sub = batcher.submitter();
        let q = vec![0u8; 8];
        let _ = sub.search(q.clone(), 8).unwrap();
        let a_queries = a.metrics().queries.load(std::sync::atomic::Ordering::Relaxed);
        assert!(a_queries >= 1);
        slot.replace(Arc::clone(&b));
        let hits = sub.search(q.clone(), 8).unwrap();
        assert_eq!(hits.len(), 300, "served by the swapped-in engine");
        assert!(b.metrics().queries.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }
}
