//! Segmented shards: the engine's write path.
//!
//! Kanda & Tabei's follow-up (*Dynamic Similarity Search on Integer
//! Sketches*, 2020) makes the static bST updatable by pairing every
//! immutable index with a small mutable buffer. This module is that
//! pairing for one engine shard:
//!
//! * **base segment** — the existing immutable [`ShardIndex`] (SI-bST or
//!   MI-bST) over the shard's settled rows, plus the raw [`SketchSet`]
//!   it was built from (kept so a merge can rebuild without re-reading
//!   cold storage) and an [`IdMap`] from local postings to global ids;
//! * **delta segment** — an append-only, uncompressed buffer of freshly
//!   inserted sketches ([`DeltaSegment`]): raw characters for merging
//!   and persistence, plus a vertical [`PlaneStore`] searched with the
//!   PR 3 `ham_range_leq` streaming kernel;
//! * **tombstones** — deleted global ids, consulted at emit time so
//!   every query mode (ids / count / top-k) excludes them without
//!   touching the immutable structures;
//! * **background merge** — once the delta passes a threshold it is
//!   sealed (immutable, still searched) and an off-thread rebuild folds
//!   base + sealed into a fresh immutable segment, installed atomically
//!   back on the owning worker (epoch-checked, so a racing force-merge
//!   simply wins and the stale result is dropped).
//!
//! Queries fan across base + sealed + active through the same
//! [`Collector`] machinery as everything else: the base traversal is
//! wrapped in [`Remap`] (local→global ids + tombstone filter) and the
//! delta scans emit global ids directly, so the engine-level merge by
//! `(dist, id)` is unchanged. Global ids are assigned in insertion order
//! and never renumbered — results are byte-identical to a from-scratch
//! build of the same rows, whatever the merge history.

use super::engine::{QueryMode, ShardIndex, ShardIndexKind, ShardReply};
use crate::index::SearchIndex;
use crate::query::{
    live_mask, BlockCollector, CollectIds, Collector, CountOnly, QueryCtx, TopK, MAX_BLOCK,
};
use crate::sketch::hamming::ham_chars_leq;
use crate::sketch::plane_store::PlaneStore;
use crate::sketch::SketchSet;
use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::HeapSize;
use std::collections::HashSet;
use std::sync::Arc;

/// Local-posting → global-id mapping of one base segment.
///
/// Freshly striped shards are contiguous (`Contig`); once a merge folds
/// round-robin-routed delta rows into the base, the map goes `Explicit`.
/// Either way it is **strictly increasing**, so per-shard `(dist, local
/// id)` ordering equals `(dist, global id)` ordering and the engine's
/// exact top-k merge keeps working.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IdMap {
    /// Locals `0..n` map to globals `offset..offset + n`.
    Contig { offset: u32, n: u32 },
    /// Strictly increasing explicit ids, one per local row.
    Explicit(Vec<u32>),
}

impl IdMap {
    /// Rows covered by the map.
    pub fn len(&self) -> usize {
        match self {
            IdMap::Contig { n, .. } => *n as usize,
            IdMap::Explicit(ids) => ids.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Global id of local row `local`.
    #[inline]
    pub fn get(&self, local: u32) -> u32 {
        match self {
            IdMap::Contig { offset, n } => {
                debug_assert!(local < *n);
                offset + local
            }
            IdMap::Explicit(ids) => ids[local as usize],
        }
    }

    /// Largest mapped global id (`None` when empty).
    pub fn max(&self) -> Option<u32> {
        match self {
            IdMap::Contig { offset, n } => n.checked_sub(1).map(|last| offset + last),
            IdMap::Explicit(ids) => ids.last().copied(),
        }
    }

    /// Whether global id `g` is mapped (range check / binary search).
    pub fn contains(&self, g: u32) -> bool {
        match self {
            IdMap::Contig { offset, n } => g >= *offset && g - *offset < *n,
            IdMap::Explicit(ids) => ids.binary_search(&g).is_ok(),
        }
    }

    /// All mapped global ids, ascending.
    pub fn iter(&self) -> Box<dyn Iterator<Item = u32> + '_> {
        match self {
            IdMap::Contig { offset, n } => Box::new(*offset..*offset + *n),
            IdMap::Explicit(ids) => Box::new(ids.iter().copied()),
        }
    }

    /// The map after appending `extra` rows (all ids in `extra` are
    /// strictly increasing and greater than [`IdMap::max`] — enforced by
    /// the insert path, validated on snapshot load).
    pub fn extend(&self, extra: &[u32]) -> IdMap {
        if extra.is_empty() {
            return self.clone();
        }
        let contiguous = extra
            .iter()
            .enumerate()
            .all(|(i, &g)| g == extra[0] + i as u32);
        if let IdMap::Contig { offset, n } = self {
            if contiguous && extra[0] == offset + n {
                return IdMap::Contig { offset: *offset, n: n + extra.len() as u32 };
            }
        }
        if self.is_empty() && contiguous {
            return IdMap::Contig { offset: extra[0], n: extra.len() as u32 };
        }
        let mut ids: Vec<u32> = self.iter().collect();
        ids.extend_from_slice(extra);
        IdMap::Explicit(ids)
    }
}

impl Persist for IdMap {
    fn write_into(&self, w: &mut ByteWriter) {
        match self {
            IdMap::Contig { offset, n } => {
                w.put_u8(0);
                w.put_u32(*offset);
                w.put_u32(*n);
            }
            IdMap::Explicit(ids) => {
                w.put_u8(1);
                w.put_u32s(ids);
            }
        }
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        match r.get_u8()? {
            0 => {
                let offset = r.get_u32()?;
                let n = r.get_u32()?;
                ensure(offset.checked_add(n).is_some(), || {
                    format!("IdMap: contiguous range {offset}+{n} overflows u32")
                })?;
                Ok(IdMap::Contig { offset, n })
            }
            1 => {
                let ids = r.get_u32s()?;
                ensure(ids.windows(2).all(|w| w[0] < w[1]), || {
                    "IdMap: explicit ids must be strictly increasing".to_string()
                })?;
                Ok(IdMap::Explicit(ids))
            }
            t => Err(StoreError::Corrupt(format!("IdMap: unknown tag {t}"))),
        }
    }
}

/// The append-only mutable segment: freshly inserted sketches, searched
/// uncompressed until a merge folds them into the base.
///
/// Rows are held twice, both O(delta) and cheap: raw characters (the
/// merge/persistence source of truth) and — when `L <= 64` — a vertical
/// [`PlaneStore`] scanned with the streaming `ham_range_leq` kernel
/// exactly like the linear baseline. Longer sketches fall back to a
/// character scan with the running-distance early exit.
#[derive(Debug, Clone)]
pub struct DeltaSegment {
    b: usize,
    l: usize,
    /// Global ids, strictly increasing (insertion order).
    ids: Vec<u32>,
    /// Raw characters, `l` per row.
    chars: Vec<u8>,
    /// Vertical planes (`L <= 64` only).
    planes: Option<PlaneStore>,
}

impl DeltaSegment {
    pub fn new(b: usize, l: usize) -> Self {
        assert!(matches!(b, 1..=8) && l >= 1);
        let planes = (l <= 64).then(|| PlaneStore::with_dims(b, l));
        DeltaSegment { b, l, ids: Vec::new(), chars: Vec::new(), planes }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Raw characters of delta row `i`.
    pub fn row(&self, i: usize) -> &[u8] {
        &self.chars[i * self.l..(i + 1) * self.l]
    }

    /// Appends one sketch under global id `id` (ids must arrive strictly
    /// increasing — the engine assigns them from a monotone counter).
    pub fn push(&mut self, id: u32, row: &[u8]) {
        assert_eq!(row.len(), self.l, "delta insert: row length != L");
        debug_assert!(row.iter().all(|&c| (c as usize) < (1 << self.b)));
        debug_assert!(self.ids.last().is_none_or(|&last| last < id));
        self.ids.push(id);
        self.chars.extend_from_slice(row);
        if let Some(planes) = &mut self.planes {
            let mut fields = [0u64; 8];
            for (p, &c) in row.iter().enumerate() {
                for (k, f) in fields[..self.b].iter_mut().enumerate() {
                    *f |= (((c >> k) & 1) as u64) << p;
                }
            }
            planes.push_fields(&fields[..self.b]);
        }
    }

    /// Runs a query over the delta rows, emitting **global** ids for
    /// every non-tombstoned row within the collector's live threshold.
    /// Accounting mirrors the linear scan: every row visited once, one
    /// batched prune count.
    pub fn run(&self, q: &[u8], ctx: &mut QueryCtx, tombs: &HashSet<u32>, c: &mut dyn Collector) {
        if self.is_empty() {
            return;
        }
        assert_eq!(q.len(), self.l, "query length mismatch");
        if let Some(planes) = &self.planes {
            let qp = &mut ctx.q_planes;
            qp.clear();
            for k in 0..self.b {
                let mut field = 0u64;
                for (p, &ch) in q.iter().enumerate() {
                    field |= (((ch >> k) & 1) as u64) << p;
                }
                qp.push(field);
            }
            c.on_visit_many(self.len());
            let mut pruned = 0usize;
            planes.ham_range_leq(0, self.len(), &ctx.q_planes, c.tau(), |i, verdict| {
                match verdict {
                    Some(d) => {
                        let g = self.ids[i];
                        if !tombs.contains(&g) {
                            c.emit(&[g], d);
                        }
                    }
                    None => pruned += 1,
                }
                Some(c.tau())
            });
            c.on_prune_many(pruned);
        } else {
            // L > 64: character scan through the shared early-exit kernel
            // (`ham_chars_leq` bails the moment the running mismatch
            // count exceeds the live threshold — the char-row analogue of
            // the plane kernels' between-plane early exit).
            c.on_visit_many(self.len());
            let mut pruned = 0usize;
            for (i, &g) in self.ids.iter().enumerate() {
                match ham_chars_leq(self.row(i), q, c.tau()) {
                    Some(d) => {
                        if !tombs.contains(&g) {
                            c.emit(&[g], d);
                        }
                    }
                    None => pruned += 1,
                }
            }
            c.on_prune_many(pruned);
        }
    }

    /// Blocked delta scan: one pass over the delta rows serves the whole
    /// query block. The planes path streams every plane word once through
    /// the multi-query kernel; the `L > 64` char fallback loads each row
    /// once and compares it against every query with the same early-exit
    /// kernel, so hot delta shards do not regress under blocking.
    /// Per-query results and stats are identical to [`Self::run`].
    pub fn run_block(
        &self,
        qs: &[&[u8]],
        ctx: &mut QueryCtx,
        tombs: &HashSet<u32>,
        bc: &mut BlockCollector,
    ) {
        let m = bc.len();
        assert_eq!(qs.len(), m, "query block / collector slot mismatch");
        if self.is_empty() {
            return;
        }
        for q in qs {
            assert_eq!(q.len(), self.l, "query length mismatch");
        }
        let n = self.len();
        let mut pruned = [0usize; MAX_BLOCK];
        if let Some(planes) = &self.planes {
            let bq = &mut ctx.block_q;
            bq.clear();
            for q in qs {
                for k in 0..self.b {
                    let mut field = 0u64;
                    for (p, &ch) in q.iter().enumerate() {
                        field |= (((ch >> k) & 1) as u64) << p;
                    }
                    bq.push(field);
                }
            }
            let mut taus = [0usize; MAX_BLOCK];
            for (j, t) in taus.iter_mut().take(m).enumerate() {
                bc.on_visit_many(j, n);
                *t = bc.tau(j);
            }
            planes.ham_range_leq_multi(
                0,
                n,
                &ctx.block_q,
                &taus[..m],
                live_mask(m),
                |j, i, verdict| {
                    match verdict {
                        Some(d) => {
                            let g = self.ids[i];
                            if !tombs.contains(&g) {
                                bc.emit(j, &[g], d);
                            }
                        }
                        None => pruned[j] += 1,
                    }
                    // the serial scan never stops early; no query is
                    // ever dropped from the block's live mask here
                    Some(bc.tau(j))
                },
            );
        } else {
            for j in 0..m {
                bc.on_visit_many(j, n);
            }
            for (i, &g) in self.ids.iter().enumerate() {
                let row = self.row(i);
                for (j, q) in qs.iter().enumerate() {
                    match ham_chars_leq(row, q, bc.tau(j)) {
                        Some(d) => {
                            if !tombs.contains(&g) {
                                bc.emit(j, &[g], d);
                            }
                        }
                        None => pruned[j] += 1,
                    }
                }
            }
        }
        for (j, &p) in pruned.iter().take(m).enumerate() {
            bc.on_prune_many(j, p);
        }
    }

    /// Appends another delta's rows (used to fold sealed + active into
    /// one persisted section; `other`'s ids all exceed this delta's).
    pub fn append(&mut self, other: &DeltaSegment) {
        for (i, &g) in other.ids.iter().enumerate() {
            self.push(g, other.row(i));
        }
    }

    /// Rebuilds a delta from persisted parts, validating every field.
    pub fn from_parts(
        b: usize,
        l: usize,
        ids: Vec<u32>,
        chars: Vec<u8>,
    ) -> Result<Self, StoreError> {
        ensure(matches!(b, 1..=8) && l >= 1, || {
            format!("delta: bad dims b={b} L={l}")
        })?;
        ensure(chars.len() == ids.len().saturating_mul(l), || {
            format!("delta: {} chars for {} rows of L={l}", chars.len(), ids.len())
        })?;
        ensure(chars.iter().all(|&c| (c as usize) < (1 << b)), || {
            format!("delta: character out of the 2^{b} alphabet")
        })?;
        ensure(ids.windows(2).all(|w| w[0] < w[1]), || {
            "delta: ids must be strictly increasing".to_string()
        })?;
        let mut delta = DeltaSegment::new(b, l);
        for (i, &g) in ids.iter().enumerate() {
            delta.push(g, &chars[i * l..(i + 1) * l]);
        }
        Ok(delta)
    }

    pub fn heap_bytes(&self) -> usize {
        self.ids.heap_bytes()
            + self.chars.capacity()
            + self.planes.as_ref().map_or(0, |p| p.heap_bytes())
    }
}

/// Collector adapter for the base segment: maps emitted local ids
/// through the shard's [`IdMap`] and drops tombstoned rows, forwarding
/// everything else (live threshold, visit/prune accounting) unchanged.
struct Remap<'a> {
    inner: &'a mut dyn Collector,
    map: &'a IdMap,
    tombstones: &'a HashSet<u32>,
}

impl Collector for Remap<'_> {
    #[inline]
    fn tau(&self) -> usize {
        self.inner.tau()
    }

    #[inline]
    fn emit(&mut self, ids: &[u32], dist: usize) {
        // Remap into a stack chunk and forward in bulk: one inner emit
        // (vtable hop + vector extend) per 64 ids instead of per id, no
        // allocation, and the tombstone probe is skipped entirely on the
        // common no-deletes path.
        let mut buf = [0u32; 64];
        let no_tombs = self.tombstones.is_empty();
        for chunk in ids.chunks(buf.len()) {
            let mut live = 0usize;
            for &id in chunk {
                let g = self.map.get(id);
                if no_tombs || !self.tombstones.contains(&g) {
                    buf[live] = g;
                    live += 1;
                }
            }
            if live > 0 {
                self.inner.emit(&buf[..live], dist);
            }
        }
    }

    #[inline]
    fn on_visit(&mut self) {
        self.inner.on_visit()
    }

    #[inline]
    fn on_prune(&mut self) {
        self.inner.on_prune()
    }

    #[inline]
    fn on_visit_many(&mut self, n: usize) {
        self.inner.on_visit_many(n)
    }

    #[inline]
    fn on_prune_many(&mut self, n: usize) {
        self.inner.on_prune_many(n)
    }
}

/// Everything an off-thread merge needs, captured at seal time. The
/// base structures travel as `Arc`s (no copies); `epoch` pins the shard
/// state the rebuild is based on.
pub struct MergeJob {
    kind: ShardIndexKind,
    rows: Arc<SketchSet>,
    map: IdMap,
    sealed: Arc<DeltaSegment>,
    epoch: u64,
}

impl MergeJob {
    /// The expensive part, run off the worker thread: rebuild base +
    /// sealed into a fresh immutable segment.
    pub fn build(self) -> MergeResult {
        let (rows, map) = combine(&self.rows, &self.sealed, &self.map);
        let index = self.kind.build_index(&rows);
        MergeResult { epoch: self.epoch, index: Arc::new(index), rows: Arc::new(rows), map }
    }
}

/// A finished merge, sent back to the owning worker for installation.
pub struct MergeResult {
    epoch: u64,
    index: Arc<ShardIndex>,
    rows: Arc<SketchSet>,
    map: IdMap,
}

/// Outcome of a force-merge request on one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeOutcome {
    /// Base + delta rebuilt; the shard is all-immutable again.
    Merged,
    /// Nothing pending — the shard was already all-immutable.
    Clean,
    /// The shard has delta rows but no base rows to fold them into
    /// (legacy v1 snapshot); the delta stays mutable.
    Skipped,
}

/// The state each shard worker owns: one immutable base segment, at most
/// one sealed delta (merge in flight), one active delta, and the
/// tombstone set. All access is serialized through the worker's message
/// loop — no locks anywhere on the query or write path.
pub struct SegmentedShard {
    kind: ShardIndexKind,
    base: Arc<ShardIndex>,
    map: IdMap,
    /// Raw rows behind `base` (`None` for legacy v1 snapshots, which
    /// then cannot merge — inserts still work, deltas just never fold).
    rows: Option<Arc<SketchSet>>,
    /// Frozen delta being merged off-thread (still searched).
    sealed: Option<Arc<DeltaSegment>>,
    /// Mutable delta receiving inserts.
    active: DeltaSegment,
    /// Deleted global ids, consulted at emit time.
    tombstones: HashSet<u32>,
    /// Bumped on every install/force-merge; stale off-thread results
    /// (older epoch) are discarded.
    epoch: u64,
    b: usize,
    l: usize,
}

/// Serializable view of one shard, handed to `Engine::save` (sealed and
/// active deltas folded into one section; they reload as active).
pub struct ShardParts {
    pub index: Arc<ShardIndex>,
    pub map: IdMap,
    pub rows: Option<Arc<SketchSet>>,
    pub delta: DeltaSegment,
    pub tombstones: Vec<u32>,
}

impl SegmentedShard {
    /// A freshly built (or just merged) all-immutable shard. `b` and `L`
    /// come from the base index.
    pub fn new(
        kind: ShardIndexKind,
        base: Arc<ShardIndex>,
        map: IdMap,
        rows: Option<Arc<SketchSet>>,
    ) -> Self {
        debug_assert_eq!(map.len(), base.n_rows());
        let (b, l) = (base.b(), base.l());
        let active = DeltaSegment::new(b, l);
        SegmentedShard {
            kind,
            base,
            map,
            rows,
            sealed: None,
            active,
            tombstones: HashSet::new(),
            epoch: 0,
            b,
            l,
        }
    }

    /// Restores a shard from snapshot sections.
    pub fn from_snapshot(
        kind: ShardIndexKind,
        base: Arc<ShardIndex>,
        map: IdMap,
        rows: Option<Arc<SketchSet>>,
        delta: DeltaSegment,
        tombstones: Vec<u32>,
    ) -> Self {
        let mut shard = SegmentedShard::new(kind, base, map, rows);
        shard.active = delta;
        shard.tombstones = tombstones.into_iter().collect();
        shard
    }

    /// Every global id this shard owns, ascending within each segment
    /// (snapshot-load cross-validation).
    pub fn owned_ids(&self) -> impl Iterator<Item = u32> + '_ {
        let sealed: &[u32] = self.sealed.as_deref().map_or(&[], |s| s.ids());
        self.map
            .iter()
            .chain(sealed.iter().copied())
            .chain(self.active.ids().iter().copied())
    }

    /// The tombstoned global ids (unordered).
    pub fn tombstone_ids(&self) -> impl Iterator<Item = &u32> {
        self.tombstones.iter()
    }

    /// Whether this shard owns global id `g` (any segment).
    pub fn owns_id(&self, g: u32) -> bool {
        self.owns(g)
    }

    /// Rows this shard owns (base + pending deltas, tombstones included).
    pub fn n_rows(&self) -> usize {
        self.map.len() + self.sealed.as_ref().map_or(0, |s| s.len()) + self.active.len()
    }

    /// Pending (not yet merged) delta rows.
    pub fn delta_len(&self) -> usize {
        self.sealed.as_ref().map_or(0, |s| s.len()) + self.active.len()
    }

    pub fn heap_bytes(&self) -> usize {
        self.base.heap_bytes()
            + self.rows.as_ref().map_or(0, |r| r.heap_bytes())
            + self.sealed.as_ref().map_or(0, |s| s.heap_bytes())
            + self.active.heap_bytes()
    }

    /// Executes one query across base + sealed + active, returning
    /// global ids. The collector order is irrelevant to the result —
    /// every mode's semantics are order-independent — so segments are
    /// visited base-first for cache friendliness.
    pub fn query(&self, q: &[u8], tau: usize, mode: QueryMode, ctx: &mut QueryCtx) -> ShardReply {
        match mode {
            QueryMode::Ids => {
                let mut hits = Vec::new();
                let mut coll = CollectIds::new(tau, &mut hits);
                self.run_all(q, ctx, &mut coll);
                ShardReply::Ids(hits)
            }
            QueryMode::Count => {
                let mut coll = CountOnly::new(tau);
                self.run_all(q, ctx, &mut coll);
                ShardReply::Count(coll.count())
            }
            QueryMode::TopK(k) => {
                let mut hits = Vec::new();
                let mut coll = TopK::with_heap(k, tau, ctx.take_topk_heap());
                self.run_all(q, ctx, &mut coll);
                coll.drain_into(&mut hits);
                ctx.put_topk_heap(coll.into_heap());
                ShardReply::TopK(hits)
            }
        }
    }

    fn run_all(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut dyn Collector) {
        {
            let mut remap = Remap { inner: c, map: &self.map, tombstones: &self.tombstones };
            self.base.run(q, ctx, &mut remap);
        }
        if let Some(sealed) = &self.sealed {
            sealed.run(q, ctx, &self.tombstones, c);
        }
        self.active.run(q, ctx, &self.tombstones, c);
    }

    /// Executes a compatible query block (one τ, one mode) across base +
    /// sealed + active in one pass per segment. Returns one reply per
    /// query plus each query's share of the traversal work (visits +
    /// prunes), which the engine uses to attribute the block's wall time.
    /// Results and per-query stats are identical to calling
    /// [`Self::query`] once per query.
    pub fn query_block(
        &self,
        qs: &[&[u8]],
        taus: &[usize],
        mode: QueryMode,
        ctx: &mut QueryCtx,
    ) -> (Vec<ShardReply>, Vec<u64>) {
        let m = qs.len();
        assert_eq!(taus.len(), m, "query block / tau mismatch");
        match mode {
            QueryMode::Ids => {
                let mut hits: Vec<Vec<u32>> = vec![Vec::new(); m];
                let mut colls: Vec<CollectIds> = hits
                    .iter_mut()
                    .zip(taus)
                    .map(|(h, &tau)| CollectIds::new(tau, h))
                    .collect();
                let mut slots: Vec<&mut dyn Collector> =
                    colls.iter_mut().map(|c| c as &mut dyn Collector).collect();
                let work = self.run_all_block(qs, ctx, &mut slots);
                drop(slots);
                drop(colls);
                (hits.into_iter().map(ShardReply::Ids).collect(), work)
            }
            QueryMode::Count => {
                let mut colls: Vec<CountOnly> =
                    taus.iter().map(|&tau| CountOnly::new(tau)).collect();
                let mut slots: Vec<&mut dyn Collector> =
                    colls.iter_mut().map(|c| c as &mut dyn Collector).collect();
                let work = self.run_all_block(qs, ctx, &mut slots);
                drop(slots);
                (colls.iter().map(|c| ShardReply::Count(c.count())).collect(), work)
            }
            QueryMode::TopK(k) => {
                let mut colls: Vec<TopK> =
                    taus.iter().map(|&tau| TopK::new(k, tau)).collect();
                let mut slots: Vec<&mut dyn Collector> =
                    colls.iter_mut().map(|c| c as &mut dyn Collector).collect();
                let work = self.run_all_block(qs, ctx, &mut slots);
                drop(slots);
                let replies = colls
                    .into_iter()
                    .map(|mut c| {
                        let mut hits = Vec::new();
                        c.drain_into(&mut hits);
                        ShardReply::TopK(hits)
                    })
                    .collect();
                (replies, work)
            }
        }
    }

    /// Blocked analogue of [`Self::run_all`]: one base descent, one
    /// sealed scan, one active scan for the whole block. Returns the
    /// per-query work totals accumulated across all three passes.
    fn run_all_block(
        &self,
        qs: &[&[u8]],
        ctx: &mut QueryCtx,
        slots: &mut [&mut dyn Collector],
    ) -> Vec<u64> {
        let m = slots.len();
        assert_eq!(qs.len(), m, "query block / collector slot mismatch");
        let mut work = vec![0u64; m];
        {
            // Base pass: each slot is wrapped in its own Remap
            // (local → global ids + tombstone filter), exactly as in the
            // serial path, then fanned back out through a BlockCollector.
            let mut remaps: Vec<Remap> = slots
                .iter_mut()
                .map(|s| Remap {
                    inner: &mut **s,
                    map: &self.map,
                    tombstones: &self.tombstones,
                })
                .collect();
            let mut rslots: Vec<&mut dyn Collector> =
                remaps.iter_mut().map(|r| r as &mut dyn Collector).collect();
            let mut bc = BlockCollector::new(&mut rslots);
            self.base.run_block(qs, ctx, &mut bc);
            for (j, w) in work.iter_mut().enumerate() {
                *w += bc.work(j);
            }
        }
        if let Some(sealed) = &self.sealed {
            let mut bc = BlockCollector::new(slots);
            sealed.run_block(qs, ctx, &self.tombstones, &mut bc);
            for (j, w) in work.iter_mut().enumerate() {
                *w += bc.work(j);
            }
        }
        {
            let mut bc = BlockCollector::new(slots);
            self.active.run_block(qs, ctx, &self.tombstones, &mut bc);
            for (j, w) in work.iter_mut().enumerate() {
                *w += bc.work(j);
            }
        }
        work
    }

    /// Appends pre-assigned `(global id, row)` pairs to the active delta.
    pub fn insert(&mut self, items: &[(u32, Vec<u8>)]) {
        for (id, row) in items {
            self.active.push(*id, row);
        }
    }

    /// Whether global id `g` lives in this shard (any segment).
    fn owns(&self, g: u32) -> bool {
        self.map.contains(g)
            || self.active.ids.binary_search(&g).is_ok()
            || self
                .sealed
                .as_ref()
                .is_some_and(|s| s.ids.binary_search(&g).is_ok())
    }

    /// Tombstones `g` if this shard owns it; returns whether the id was
    /// newly deleted here.
    pub fn delete(&mut self, g: u32) -> bool {
        if self.owns(g) {
            self.tombstones.insert(g)
        } else {
            false
        }
    }

    /// Seals the active delta and captures a [`MergeJob`] when the merge
    /// threshold is reached (and no merge is already in flight, and the
    /// shard has base rows to fold into).
    pub fn seal_for_merge(&mut self, threshold: usize) -> Option<MergeJob> {
        if self.sealed.is_some() || self.active.len() < threshold.max(1) {
            return None;
        }
        let rows = self.rows.clone()?;
        let sealed = Arc::new(std::mem::replace(
            &mut self.active,
            DeltaSegment::new(self.b, self.l),
        ));
        self.sealed = Some(Arc::clone(&sealed));
        Some(MergeJob {
            kind: self.kind.clone(),
            rows,
            map: self.map.clone(),
            sealed,
            epoch: self.epoch,
        })
    }

    /// Installs a finished off-thread merge. Rejected (returns `false`)
    /// when the shard moved on — a force-merge already folded the sealed
    /// delta — in which case the result is simply dropped.
    pub fn install(&mut self, result: MergeResult) -> bool {
        if result.epoch != self.epoch {
            return false;
        }
        debug_assert!(self.sealed.is_some());
        self.base = result.index;
        self.rows = Some(result.rows);
        self.map = result.map;
        self.sealed = None;
        self.epoch += 1;
        true
    }

    /// Synchronously folds every pending delta row (sealed + active)
    /// into a fresh immutable base. Any in-flight background merge is
    /// subsumed: the epoch bump makes its later install a no-op.
    pub fn force_merge(&mut self) -> MergeOutcome {
        if self.delta_len() == 0 {
            return MergeOutcome::Clean;
        }
        let Some(rows) = self.rows.clone() else {
            return MergeOutcome::Skipped;
        };
        let mut pending = match self.sealed.take() {
            Some(sealed) => (*sealed).clone(),
            None => DeltaSegment::new(self.b, self.l),
        };
        pending.append(&self.active);
        let (new_rows, new_map) = combine(&rows, &pending, &self.map);
        self.base = Arc::new(self.kind.build_index(&new_rows));
        self.rows = Some(Arc::new(new_rows));
        self.map = new_map;
        self.active = DeltaSegment::new(self.b, self.l);
        self.epoch += 1;
        MergeOutcome::Merged
    }

    /// A consistent serializable view for `Engine::save` (sealed +
    /// active folded into one delta; tombstones sorted).
    pub fn parts(&self) -> ShardParts {
        let mut delta = match &self.sealed {
            Some(sealed) => (**sealed).clone(),
            None => DeltaSegment::new(self.b, self.l),
        };
        delta.append(&self.active);
        let mut tombstones: Vec<u32> = self.tombstones.iter().copied().collect();
        tombstones.sort_unstable();
        ShardParts {
            index: Arc::clone(&self.base),
            map: self.map.clone(),
            rows: self.rows.clone(),
            delta,
            tombstones,
        }
    }
}

/// Concatenates base rows + delta rows (in id order) and extends the id
/// map accordingly — the input of every merge rebuild.
fn combine(rows: &SketchSet, delta: &DeltaSegment, map: &IdMap) -> (SketchSet, IdMap) {
    let n0 = rows.n();
    let combined = SketchSet::from_fn(rows.b(), rows.l(), n0 + delta.len(), |i, p| {
        if i < n0 {
            rows.get_char(i, p)
        } else {
            delta.row(i - n0)[p]
        }
    });
    (combined, map.extend(delta.ids()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::QueryMode;
    use crate::sketch::hamming::ham_chars;
    use crate::trie::bst::BstConfig;
    use crate::util::Rng;

    fn rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect()
    }

    fn bst_shard(data: &[Vec<u8>], b: usize, l: usize, offset: u32) -> SegmentedShard {
        let set = SketchSet::from_rows(b, l, data);
        let kind = ShardIndexKind::Bst(BstConfig::default());
        let base = Arc::new(kind.build_index(&set));
        let map = IdMap::Contig { offset, n: data.len() as u32 };
        SegmentedShard::new(kind, base, map, Some(Arc::new(set)))
    }

    fn sorted_ids(reply: ShardReply) -> Vec<u32> {
        match reply {
            ShardReply::Ids(mut v) => {
                v.sort_unstable();
                v
            }
            _ => panic!("expected ids"),
        }
    }

    #[test]
    fn idmap_contig_and_explicit() {
        let c = IdMap::Contig { offset: 10, n: 4 };
        assert_eq!(c.len(), 4);
        assert_eq!(c.get(0), 10);
        assert_eq!(c.get(3), 13);
        assert_eq!(c.max(), Some(13));
        assert!(c.contains(12) && !c.contains(14) && !c.contains(9));
        assert_eq!(c.iter().collect::<Vec<_>>(), vec![10, 11, 12, 13]);

        // contiguous extension stays Contig; gapped goes Explicit
        assert_eq!(c.extend(&[14, 15]), IdMap::Contig { offset: 10, n: 6 });
        let e = c.extend(&[20, 25]);
        assert_eq!(e, IdMap::Explicit(vec![10, 11, 12, 13, 20, 25]));
        assert_eq!(e.get(4), 20);
        assert!(e.contains(25) && !e.contains(24));
        assert_eq!(e.max(), Some(25));
        assert_eq!(e.extend(&[]), e);

        let empty = IdMap::Contig { offset: 0, n: 0 };
        assert_eq!(empty.max(), None);
        assert_eq!(empty.extend(&[7, 8]), IdMap::Contig { offset: 7, n: 2 });

        // persistence roundtrip + monotonicity validation
        for m in [c, e] {
            let bytes = crate::store::to_payload(&m);
            let got: IdMap =
                crate::store::from_payload(&mut ByteReader::new(&bytes)).unwrap();
            assert_eq!(got, m);
        }
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u32s(&[5, 5]);
        assert!(crate::store::from_payload::<IdMap>(&mut ByteReader::new(&w.into_bytes()))
            .is_err());
    }

    #[test]
    fn delta_scan_matches_oracle_all_b() {
        // (2, 64) hits the widest plane fields; (2, 80) exercises the
        // L > 64 character-scan fallback (no vertical planes).
        for &(b, l) in &[(1usize, 16usize), (2, 12), (4, 8), (8, 6), (2, 64), (2, 80)] {
            let data = rows(b, l, 60, (b * l) as u64);
            let mut delta = DeltaSegment::new(b, l);
            for (i, row) in data.iter().enumerate() {
                delta.push(100 + i as u32, row);
            }
            assert_eq!(delta.len(), data.len());
            let tombs: HashSet<u32> = [101u32, 130].into_iter().collect();
            let mut ctx = QueryCtx::new();
            for qi in [0usize, 7, 59] {
                let q = &data[qi];
                for tau in [0usize, 1, 3] {
                    let mut hits = Vec::new();
                    let mut coll = CollectIds::new(tau, &mut hits);
                    delta.run(q, &mut ctx, &tombs, &mut coll);
                    hits.sort_unstable();
                    let expect: Vec<u32> = (0..data.len())
                        .filter(|&i| ham_chars(&data[i], q) <= tau)
                        .map(|i| 100 + i as u32)
                        .filter(|g| !tombs.contains(g))
                        .collect();
                    assert_eq!(hits, expect, "b={b} l={l} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn delta_roundtrips_through_parts() {
        let data = rows(2, 10, 25, 77);
        let mut delta = DeltaSegment::new(2, 10);
        for (i, row) in data.iter().enumerate() {
            delta.push(3 * i as u32, row);
        }
        let rebuilt =
            DeltaSegment::from_parts(2, 10, delta.ids.clone(), delta.chars.clone()).unwrap();
        assert_eq!(rebuilt.ids, delta.ids);
        assert_eq!(rebuilt.chars, delta.chars);
        // out-of-alphabet and non-monotone inputs are rejected
        assert!(DeltaSegment::from_parts(2, 10, vec![0], vec![9; 10]).is_err());
        assert!(DeltaSegment::from_parts(2, 2, vec![1, 1], vec![0; 4]).is_err());
        assert!(DeltaSegment::from_parts(2, 10, vec![0], vec![0; 7]).is_err());
    }

    #[test]
    fn shard_query_spans_base_delta_and_tombstones() {
        let (b, l) = (2usize, 12usize);
        let data = rows(b, l, 150, 5);
        let mut shard = bst_shard(&data[..100], b, l, 0);
        shard.insert(
            &data[100..]
                .iter()
                .enumerate()
                .map(|(i, r)| (100 + i as u32, r.clone()))
                .collect::<Vec<_>>(),
        );
        assert!(shard.delete(3), "base row");
        assert!(shard.delete(120), "delta row");
        assert!(!shard.delete(3), "already tombstoned");
        assert!(!shard.delete(999), "not owned");

        let alive = |i: usize| i != 3 && i != 120;
        let mut ctx = QueryCtx::new();
        for qi in [0usize, 50, 120] {
            let q = &data[qi];
            for tau in [0usize, 2, 4] {
                let got = sorted_ids(shard.query(q, tau, QueryMode::Ids, &mut ctx));
                let expect: Vec<u32> = (0..data.len())
                    .filter(|&i| alive(i) && ham_chars(&data[i], q) <= tau)
                    .map(|i| i as u32)
                    .collect();
                assert_eq!(got, expect, "qi={qi} tau={tau}");
                match shard.query(q, tau, QueryMode::Count, &mut ctx) {
                    ShardReply::Count(n) => assert_eq!(n, expect.len()),
                    _ => panic!("expected count"),
                }
            }
            // top-k equals the brute-force (dist, id) order over live rows
            let tau = 4usize;
            let mut all: Vec<(usize, u32)> = (0..data.len())
                .filter(|&i| alive(i))
                .map(|i| (ham_chars(&data[i], q), i as u32))
                .filter(|&(d, _)| d <= tau)
                .collect();
            all.sort_unstable();
            match shard.query(q, tau, QueryMode::TopK(5), &mut ctx) {
                ShardReply::TopK(got) => {
                    let expect: Vec<(u32, usize)> =
                        all.iter().take(5).map(|&(d, id)| (id, d)).collect();
                    assert_eq!(got, expect, "qi={qi}");
                }
                _ => panic!("expected topk"),
            }
        }
    }

    #[test]
    fn force_merge_preserves_results_and_goes_immutable() {
        let (b, l) = (2usize, 10usize);
        let data = rows(b, l, 120, 9);
        let mut shard = bst_shard(&data[..80], b, l, 0);
        assert_eq!(shard.force_merge(), MergeOutcome::Clean);
        let items: Vec<(u32, Vec<u8>)> = data[80..]
            .iter()
            .enumerate()
            .map(|(i, r)| (80 + i as u32, r.clone()))
            .collect();
        shard.insert(&items);
        shard.delete(90);

        let mut ctx = QueryCtx::new();
        let q = &data[85];
        let before = sorted_ids(shard.query(q, 3, QueryMode::Ids, &mut ctx));
        assert_eq!(shard.force_merge(), MergeOutcome::Merged);
        assert_eq!(shard.delta_len(), 0);
        assert_eq!(shard.n_rows(), 120);
        let after = sorted_ids(shard.query(q, 3, QueryMode::Ids, &mut ctx));
        assert_eq!(before, after, "merge must not change results");
        // tombstone survives the merge; the id is never resurrected
        assert!(!after.contains(&90));
    }

    #[test]
    fn background_merge_seal_install_and_stale_drop() {
        let (b, l) = (2usize, 10usize);
        let data = rows(b, l, 100, 11);
        let mut shard = bst_shard(&data[..60], b, l, 0);
        let items: Vec<(u32, Vec<u8>)> = data[60..90]
            .iter()
            .enumerate()
            .map(|(i, r)| (60 + i as u32, r.clone()))
            .collect();
        shard.insert(&items);
        assert!(shard.seal_for_merge(usize::MAX).is_none(), "below threshold");
        let job = shard.seal_for_merge(10).expect("threshold reached");
        assert!(shard.seal_for_merge(1).is_none(), "merge already in flight");
        // sealed rows stay searchable while the merge runs
        let mut ctx = QueryCtx::new();
        let pre = sorted_ids(shard.query(&data[70], 2, QueryMode::Ids, &mut ctx));
        assert!(pre.contains(&70));
        // inserts keep landing in the fresh active delta meanwhile
        shard.insert(&[(95, data[95].clone())]);

        let result = job.build();
        assert!(shard.install(result), "epoch matches");
        assert_eq!(shard.n_rows(), 91);
        assert_eq!(shard.delta_len(), 1, "post-seal insert survives the install");
        let post = sorted_ids(shard.query(&data[70], 2, QueryMode::Ids, &mut ctx));
        assert_eq!(pre, post);

        // A stale result (older epoch) is dropped: force-merge wins.
        shard.insert(&items.iter().map(|(g, r)| (g + 100, r.clone())).collect::<Vec<_>>());
        let stale = shard.seal_for_merge(1).expect("seal again");
        assert_eq!(shard.force_merge(), MergeOutcome::Merged);
        let n_before = shard.n_rows();
        assert!(!shard.install(stale.build()), "stale epoch rejected");
        assert_eq!(shard.n_rows(), n_before);
    }

    #[test]
    fn legacy_shard_without_rows_skips_merge_but_serves_inserts() {
        let (b, l) = (2usize, 10usize);
        let data = rows(b, l, 50, 13);
        let set = SketchSet::from_rows(b, l, &data[..40]);
        let kind = ShardIndexKind::Bst(BstConfig::default());
        let base = Arc::new(kind.build_index(&set));
        let mut shard =
            SegmentedShard::new(kind, base, IdMap::Contig { offset: 0, n: 40 }, None);
        shard.insert(&[(40, data[40].clone()), (41, data[41].clone())]);
        assert!(shard.seal_for_merge(1).is_none(), "no base rows to fold into");
        assert_eq!(shard.force_merge(), MergeOutcome::Skipped);
        let mut ctx = QueryCtx::new();
        let got = sorted_ids(shard.query(&data[41], 0, QueryMode::Ids, &mut ctx));
        assert!(got.contains(&41), "delta still serves");
    }
}
