//! The serving layer: a sharded similarity-search service.
//!
//! The paper's contribution is the index; serving it at scale needs the
//! machinery every retrieval system (vLLM-router-style) carries:
//!
//! * [`engine`] — sharded query engine: the database is striped over `S`
//!   shards, each owning one [`segment::SegmentedShard`] (immutable base
//!   index + mutable delta segment + tombstones) plus a persistent
//!   per-worker `QueryCtx`; a query fans out to all shards as one shared
//!   `Arc<[u8]>` and merges id sets / counts / top-k results (workers
//!   answer with global ids).
//! * [`segment`] — the write path: append-only delta segments searched
//!   with the streaming verification kernels, emit-time tombstones, and
//!   the epoch-checked background merge that folds deltas back into
//!   fresh immutable segments.
//! * [`batcher`] — dynamic batching: requests (search, count *and*
//!   top-k) queue up to `max_batch` or `max_delay`, then execute as one
//!   mixed-mode fan-out round (amortizes shard wake-ups under load;
//!   single requests still cut through on timeout).
//! * [`server`] — TCP front-end, line-delimited JSON protocol (versioned
//!   envelope + structured errors; see [`protocol`]), including the
//!   `reload` op that swaps in an engine loaded from a snapshot and the
//!   replication ops (`snapshot.fetch` / `wal.fetch` / `repl.status`).
//! * [`replica`] — WAL-shipping read replicas: a follower bootstraps
//!   from the primary's snapshot over the wire, then tails its WAL and
//!   applies records through the engine's idempotent replay path.
//! * [`engine::Engine::save`] / [`engine::Engine::load`] — snapshot
//!   persistence: build once, serve many, restart in seconds (see
//!   [`crate::store`]).
//! * [`metrics`] — atomic counters + log-bucketed latency histogram.
//! * [`config`] — serving configuration.
//!
//! Python is never involved: the engine serves from memory-resident
//! indexes; ingestion (feature→sketch) ran through the PJRT runtime at
//! build time.

pub mod batcher;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod replica;
pub mod segment;
pub mod server;

pub use config::ServeConfig;
pub use engine::{Engine, EngineSlot};
pub use metrics::Metrics;
