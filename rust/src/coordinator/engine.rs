//! Sharded query engine with a write path.
//!
//! The database is striped into `S` shards; each shard worker thread
//! owns one [`SegmentedShard`] — an immutable base index plus a mutable
//! delta segment and tombstone set (see [`super::segment`]) — and one
//! persistent [`QueryCtx`], the per-worker scratch pool that keeps the
//! per-shard hot path allocation-free after warm-up. A query fans out to
//! all shards as one shared `Arc<[u8]>` (no per-shard copies); workers
//! answer with **global** ids (the shard state maps local postings and
//! filters tombstones at emit), so the engine-level merge is a plain
//! concatenation / sum / `(dist, id)` sort.
//!
//! Three query modes ride the same fan-out machinery: id collection
//! ([`Engine::search`] / [`Engine::run_batch`]), counting
//! ([`Engine::count`]) and top-k ([`Engine::top_k`]). Mixed-mode batches
//! execute as one pipelined round with real per-query wall time.
//!
//! **Writes** ride the same worker channels, so they serialize naturally
//! against queries without any locking:
//!
//! * [`Engine::insert_batch`] assigns global ids from a monotone counter
//!   and stripes the rows over shards by `id % S`; each shard appends to
//!   its active delta. When a delta passes the merge threshold the
//!   worker seals it and rebuilds base + sealed **off-thread**, swapping
//!   the fresh immutable segment in atomically (epoch-checked install
//!   message — the same swap discipline as [`EngineSlot::replace`]).
//! * [`Engine::delete`] broadcasts a tombstone; the owning shard records
//!   it and every query mode excludes the id at emit time.
//! * [`Engine::merge`] force-folds all pending deltas synchronously
//!   (the CLI/CI hook for deterministic all-immutable snapshots).
//!
//! **Persistence** ([`Engine::save`] / [`Engine::load`]): snapshots are
//! format v2 — `meta` + per shard `shard.N` (immutable index), `rows.N`
//! (raw rows behind it), `delta.N` (id map + pending delta rows) and
//! `tombstones.N`. v1 snapshots (PR 2) still load, as all-immutable
//! engines without raw rows: they serve and accept inserts/deletes, but
//! cannot merge until rebuilt. Loading stays parse-only — no sorting, no
//! trie construction, no rank/select re-indexing.
//!
//! **Durability** ([`Engine::attach_wal`]): with a write-ahead log
//! attached, every insert/delete appends one record *under the insert
//! lock, before the rows are enqueued on any shard*, so the log's
//! record order equals the shards' apply order. Under `--wal-sync
//! always` the fsync itself rides **group commit**
//! ([`crate::store::wal::GroupCommit`]): the append only buffers, the
//! writer collects its shard acks, and then blocks on the durable-LSN
//! watermark — the first blocked writer fsyncs once for every record
//! buffered so far, so K concurrent writes cost one fsync, and a write
//! is still acknowledged only after its record is on disk. A failed
//! group fsync fails every write in the group — the rows stay applied
//! in memory unacknowledged, and their records stay staged so the next
//! group's fsync retries them (the id sequence in the log must remain
//! gap-free for replay; a retried record that later reaches disk is a
//! false NACK, never a false ack). `Engine::save` rotates the log
//! under the same lock (the PR 6 save fence), draining the in-flight
//! group first: a fresh segment opens before the parts fan-out and the
//! old segments are deleted only after the snapshot has durably
//! renamed into place. On the next [`Engine::load`] + `attach_wal`,
//! records past the snapshot's id high-water mark replay (torn tails
//! truncate at a record boundary, never error).
//!
//! **Failure isolation**: each shard worker runs its message loop under
//! `catch_unwind`. A panic discards the (possibly half-mutated) shard
//! state, bumps `worker_restarts`, and rebuilds the shard from the last
//! snapshot + WAL replay while every other shard keeps serving;
//! in-flight requests touching the dead shard get an error
//! ([`QueryResult::Failed`] / `Err`), never a hang. Writes redelivered
//! from the queue after a rebuild are deduplicated by id, so the
//! at-least-once channel delivery stays exactly-once in effect.

use super::metrics::Metrics;
use super::segment::{DeltaSegment, IdMap, MergeOutcome, SegmentedShard, ShardParts};
use crate::index::{MultiBst, SearchIndex, SingleBst};
use crate::query::{BlockCollector, Collector, QueryCtx, MAX_BLOCK};
use crate::sketch::SketchSet;
use crate::store::wal::{self, Wal, WalCursor, WalRecord, WalSync};
use crate::store::{
    ensure, from_payload, to_payload, ByteReader, ByteWriter, Mmap, Persist, Snapshot,
    SnapshotStreamWriter, StoreError, FORMAT_VERSION_V1,
};
use crate::trie::bst::BstConfig;
use crate::util::failpoint;
use crate::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How a fanned-out query collects results on each shard.
#[derive(Debug, Clone, Copy)]
pub enum QueryMode {
    /// Collect matching ids (classic threshold search).
    Ids,
    /// Count matches only.
    Count,
    /// Per-shard top-k by `(dist, id)`; merged globally by the caller.
    TopK(usize),
}

/// One shard's result payload (global ids).
pub enum ShardReply {
    Ids(Vec<u32>),
    Count(usize),
    TopK(Vec<(u32, usize)>),
}

/// A globally merged query result (one per batch entry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryResult {
    Ids(Vec<u32>),
    Count(usize),
    TopK(Vec<(u32, usize)>),
    /// A shard worker died (panic mid-rebuild or unrecoverable) before
    /// answering: the query failed rather than hanging or returning a
    /// silently partial result. The batcher's typed accessors map this
    /// to `None`, which the server answers as an error line.
    Failed,
}

/// One fully specified query: sketch, radius and collection mode. This
/// is the single argument of [`Engine::query`], the unified entry point
/// the server, the batcher and the CLI all route through; the legacy
/// per-mode helpers ([`Engine::search`] / [`Engine::count`] /
/// [`Engine::top_k`]) are thin wrappers kept for compatibility.
#[derive(Debug, Clone)]
pub struct QuerySpec {
    pub q: Arc<[u8]>,
    pub tau: usize,
    pub mode: QueryMode,
}

impl QuerySpec {
    /// Threshold search collecting matching ids.
    pub fn ids(q: &[u8], tau: usize) -> QuerySpec {
        QuerySpec { q: Arc::from(q), tau, mode: QueryMode::Ids }
    }

    /// Threshold search counting matches only.
    pub fn count(q: &[u8], tau: usize) -> QuerySpec {
        QuerySpec { q: Arc::from(q), tau, mode: QueryMode::Count }
    }

    /// Top-`k` by `(dist, id)` within radius `tau`.
    pub fn top_k(q: &[u8], k: usize, tau: usize) -> QuerySpec {
        QuerySpec { q: Arc::from(q), tau, mode: QueryMode::TopK(k) }
    }
}

/// What [`Engine::query`] returns. An alias today; named separately so
/// the output side of the unified API can grow (e.g. per-query stats)
/// without touching every caller's signature.
pub type QueryOutput = QueryResult;

/// Totals of one [`Engine::merge`] sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MergeSummary {
    /// Shards that are now all-immutable (freshly merged or already so).
    pub merged: usize,
    /// Legacy shards with pending deltas but no base rows to fold into.
    pub skipped: usize,
}

/// One shard's answer to a [`ShardMsg::QueryBlock`]: per-query replies
/// plus each query's share of the shard's traversal work (visits +
/// prunes), used by the engine to attribute the block's wall time.
struct BlockShardReply {
    replies: Vec<ShardReply>,
    work: Vec<u64>,
}

enum ShardMsg {
    Query {
        q: Arc<[u8]>,
        tau: usize,
        mode: QueryMode,
        reply: Sender<(usize, ShardReply)>,
        shard_no: usize,
    },
    /// A compatible query block (one mode, per-query τ): the shard
    /// descends its trie / scans its deltas once for the whole block.
    QueryBlock {
        qs: Vec<Arc<[u8]>>,
        taus: Vec<usize>,
        mode: QueryMode,
        reply: Sender<(usize, BlockShardReply)>,
        shard_no: usize,
    },
    Insert {
        items: Vec<(u32, Vec<u8>)>,
        merge_threshold: usize,
        reply: Sender<usize>,
    },
    Delete {
        id: u32,
        reply: Sender<bool>,
    },
    ForceMerge {
        reply: Sender<MergeOutcome>,
    },
    /// A finished background merge returning to its owner.
    Install(Box<super::segment::MergeResult>),
    /// Consistent serializable view for `Engine::save`.
    Parts {
        reply: Sender<(usize, ShardParts)>,
        shard_no: usize,
    },
    Shutdown,
}

struct Shard {
    tx: Sender<ShardMsg>,
    handle: Option<JoinHandle<()>>,
}

/// Builder: which index each shard uses.
#[derive(Debug, Clone)]
pub enum ShardIndexKind {
    /// SI-bST (default).
    Bst(BstConfig),
    /// MI-bST with `m` blocks.
    MultiBst(usize),
}

impl ShardIndexKind {
    /// Builds one shard's index over its stripe — shared by the initial
    /// engine build and every merge rebuild.
    pub fn build_index(&self, stripe: &SketchSet) -> ShardIndex {
        match self {
            ShardIndexKind::Bst(cfg) => ShardIndex::Bst(SingleBst::build(stripe, *cfg)),
            ShardIndexKind::MultiBst(m) => ShardIndex::MultiBst(MultiBst::build(stripe, *m)),
        }
    }
}

/// A shard's index, concretely tagged so snapshots can restore it. All
/// variants answer queries through [`SearchIndex`].
pub enum ShardIndex {
    Bst(SingleBst),
    MultiBst(MultiBst),
}

impl ShardIndex {
    /// Rows in this shard's stripe.
    pub fn n_rows(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.trie().post_id_count(),
            ShardIndex::MultiBst(idx) => idx.n(),
        }
    }

    /// Sketch length the shard serves.
    pub fn l(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.trie().sketch_len(),
            ShardIndex::MultiBst(idx) => idx.l(),
        }
    }

    /// Alphabet bits `b`.
    pub fn b(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.trie().alphabet_bits(),
            ShardIndex::MultiBst(idx) => idx.b(),
        }
    }

    /// The rebuild recipe a merge uses to reconstruct this kind of
    /// index. (bST construction parameters are re-derived from the data;
    /// the engine build path passes the caller's exact config instead.)
    fn recipe(&self) -> ShardIndexKind {
        match self {
            ShardIndex::Bst(_) => ShardIndexKind::Bst(BstConfig::default()),
            ShardIndex::MultiBst(idx) => ShardIndexKind::MultiBst(idx.m()),
        }
    }
}

impl SearchIndex for ShardIndex {
    fn run(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut dyn Collector) {
        match self {
            ShardIndex::Bst(idx) => idx.run(q, ctx, c),
            ShardIndex::MultiBst(idx) => idx.run(q, ctx, c),
        }
    }

    fn run_block(&self, qs: &[&[u8]], ctx: &mut QueryCtx, bc: &mut BlockCollector) {
        match self {
            ShardIndex::Bst(idx) => idx.run_block(qs, ctx, bc),
            ShardIndex::MultiBst(idx) => idx.run_block(qs, ctx, bc),
        }
    }

    fn heap_bytes(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.heap_bytes(),
            ShardIndex::MultiBst(idx) => SearchIndex::heap_bytes(idx),
        }
    }

    fn name(&self) -> String {
        match self {
            ShardIndex::Bst(idx) => idx.name(),
            ShardIndex::MultiBst(idx) => SearchIndex::name(idx),
        }
    }
}

impl Persist for ShardIndex {
    fn write_into(&self, w: &mut ByteWriter) {
        match self {
            ShardIndex::Bst(idx) => {
                w.put_u8(0);
                idx.write_into(w);
            }
            ShardIndex::MultiBst(idx) => {
                w.put_u8(1);
                idx.write_into(w);
            }
        }
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        match r.get_u8()? {
            0 => Ok(ShardIndex::Bst(SingleBst::read_from(r)?)),
            1 => Ok(ShardIndex::MultiBst(MultiBst::read_from(r)?)),
            t => Err(StoreError::Corrupt(format!("shard index: unknown kind tag {t}"))),
        }
    }
}

/// Process-wide engine counter backing [`Engine::instance_tag`].
static ENGINE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Rides inside the insert lock: the attached WAL (if any) appends
/// under the very guard that orders id reservation and shard enqueue,
/// so the log's record order equals the shards' apply order and a
/// record is durable before its write is acknowledged.
#[derive(Default)]
struct WalCell {
    wal: Option<Wal>,
}

/// How a write finishes its durability contract after the insert lock
/// is released (see [`Engine::settle_commit`]).
enum WriteCommit {
    /// No WAL, or a deferred-sync policy (`batch`/`off`): nothing to
    /// wait for.
    None,
    /// Inline `always` fsync already happened inside `Wal::append`;
    /// only the fsync accounting remains.
    Inline,
    /// Group commit: block until the durable-LSN watermark covers this
    /// write's record (possibly leading the group's single fsync).
    Group(Arc<wal::GroupCommit>, u64),
}

/// What [`Engine::attach_wal`] recovered.
#[derive(Debug, Default)]
pub struct WalReport {
    /// WAL segment files scanned.
    pub segments: usize,
    /// Rows replayed into the engine (records past the snapshot's id
    /// high-water mark).
    pub replayed_inserts: usize,
    /// Tombstones replayed.
    pub replayed_deletes: usize,
    /// Records skipped as already covered by the snapshot.
    pub skipped_records: usize,
    /// Torn/corrupt bytes truncated off the newest segment.
    pub truncated_bytes: u64,
}

/// Where a panicked shard worker rebuilds itself from: the last durable
/// snapshot plus the WAL. Updated by [`Engine::load_with`] /
/// [`Engine::attach_wal`] / [`Engine::save`]; read by the worker
/// supervisor. The generation counter detects a save racing a rebuild
/// (snapshot renamed / WAL rotated mid-read) — the rebuild retries on a
/// mismatch instead of trusting a torn view.
#[derive(Default)]
struct RecoveryPlan {
    inner: Mutex<PlanState>,
}

#[derive(Default, Clone)]
struct PlanState {
    /// Last durable snapshot (always reopened owned — a restarted shard
    /// of a mapped engine serves owned memory until the next reload).
    snapshot: Option<PathBuf>,
    /// WAL segment base, when a log is attached.
    wal: Option<PathBuf>,
    /// Bumped by every committed save.
    generation: u64,
}

impl RecoveryPlan {
    fn state(&self) -> PlanState {
        self.inner.lock().unwrap().clone()
    }

    fn set_snapshot(&self, path: &Path) {
        self.inner.lock().unwrap().snapshot = Some(path.to_path_buf());
    }

    fn set_wal(&self, base: &Path) {
        self.inner.lock().unwrap().wal = Some(base.to_path_buf());
    }

    /// A save has durably renamed `path` into place (called *before*
    /// the old WAL segments are deleted, so a rebuild that reads the
    /// old snapshot still finds the records covering it — or notices
    /// the generation moved and retries).
    fn committed_save(&self, path: &Path) {
        let mut st = self.inner.lock().unwrap();
        st.snapshot = Some(path.to_path_buf());
        st.generation += 1;
    }

    fn generation(&self) -> u64 {
        self.inner.lock().unwrap().generation
    }

    fn wal_path(&self) -> Option<PathBuf> {
        self.inner.lock().unwrap().wal.clone()
    }
}

/// The sharded engine.
pub struct Engine {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    l: usize,
    b: usize,
    /// Next global id to assign (== total rows ever inserted; ids are
    /// never reused or renumbered, tombstoned ones included).
    next_id: AtomicU32,
    /// Active-delta row count that triggers a background merge.
    merge_threshold: AtomicUsize,
    /// Serializes id reservation + per-shard enqueue so concurrent
    /// insert batches reach every shard in global id order (the delta
    /// segments require strictly increasing ids), and carries the
    /// attached WAL so append-before-ack rides the same ordering.
    /// Waiting for the shard acks happens outside this lock.
    insert_lock: Mutex<WalCell>,
    /// Shared with every worker's supervisor.
    recovery: Arc<RecoveryPlan>,
    /// Process-unique engine tag — the failpoint context for this
    /// engine's worker/merge sites, so concurrent tests can scope
    /// injected faults to their own engine.
    instance: u64,
    /// The snapshot mapping of a `--mmap` load, kept alive so the stats
    /// endpoint can probe page residency (`mincore`).
    mapping: Option<Arc<Mmap>>,
    /// Bytes of page-level advice (`madvise`) issued over the mapping at
    /// load time; `None` when not mapped or the platform has no madvise.
    advised_bytes: Option<usize>,
    heap_bytes: usize,
}

impl Engine {
    /// Most shards an engine will build or load — keeps `save`/`load`
    /// symmetric (anything `build` produces, `load` accepts) and bounds
    /// the allocation a corrupt snapshot header can request.
    pub const MAX_SHARDS: usize = 65_536;

    /// Default active-delta size that triggers a background merge.
    pub const DEFAULT_MERGE_THRESHOLD: usize = 4096;

    /// Stripes `set` over `n_shards` shards and builds per-shard indexes
    /// in parallel.
    pub fn build(set: &SketchSet, n_shards: usize, kind: &ShardIndexKind) -> Self {
        let n = set.n();
        let n_shards = n_shards.clamp(1, n.max(1)).min(Self::MAX_SHARDS);
        let per = n.div_ceil(n_shards);

        // Build indexes in parallel with scoped threads, then move each
        // into its worker thread.
        let stripes: Vec<(u32, SketchSet)> = (0..n_shards)
            .map(|s| {
                let lo = s * per;
                let hi = ((s + 1) * per).min(n);
                let mut stripe = SketchSet::zeros(set.b(), set.l(), hi - lo);
                for i in lo..hi {
                    for p in 0..set.l() {
                        stripe.set_char(i - lo, p, set.get_char(i, p));
                    }
                }
                (lo as u32, stripe)
            })
            .collect();

        let states: Vec<SegmentedShard> = std::thread::scope(|scope| {
            let handles: Vec<_> = stripes
                .into_iter()
                .map(|(offset, stripe)| {
                    let kind = kind.clone();
                    scope.spawn(move || {
                        let index = Arc::new(kind.build_index(&stripe));
                        let map = IdMap::Contig { offset, n: stripe.n() as u32 };
                        SegmentedShard::new(kind, index, map, Some(Arc::new(stripe)))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard build")).collect()
        });

        Engine::assemble(set.l(), set.b(), n as u32, states)
    }

    /// Spawns the shard workers over already-built (or loaded) states.
    fn assemble(l: usize, b: usize, next_id: u32, states: Vec<SegmentedShard>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let recovery = Arc::new(RecoveryPlan::default());
        let instance = ENGINE_SEQ.fetch_add(1, Ordering::Relaxed);
        let n_shards = states.len();
        let mut shards = Vec::with_capacity(n_shards);
        let mut heap_bytes = 0usize;
        for (no, state) in states.into_iter().enumerate() {
            heap_bytes += state.heap_bytes();
            let (tx, rx) = channel::<ShardMsg>();
            // Workers hold a clone of their own sender so background
            // merge threads can message the finished segment back.
            let cfg = WorkerCfg {
                rx,
                self_tx: tx.clone(),
                metrics: Arc::clone(&metrics),
                shard_no: no,
                n_shards,
                plan: Arc::clone(&recovery),
                ctx: format!("engine-{instance}/shard-{no}"),
            };
            let handle = std::thread::Builder::new()
                .name(format!("bst-shard-{no}"))
                .spawn(move || worker_loop(state, cfg))
                .expect("spawn shard worker");
            shards.push(Shard { tx, handle: Some(handle) });
        }

        Engine {
            shards,
            metrics,
            l,
            b,
            next_id: AtomicU32::new(next_id),
            merge_threshold: AtomicUsize::new(Self::DEFAULT_MERGE_THRESHOLD),
            insert_lock: Mutex::new(WalCell::default()),
            recovery,
            instance,
            mapping: None,
            advised_bytes: None,
            heap_bytes,
        }
    }

    /// Writes a snapshot: one `meta` section plus `shard.N` / `rows.N` /
    /// `delta.N` / `tombstones.N` per shard (see
    /// [`crate::store::container`] for the file format). Shards are
    /// serialized and streamed one at a time.
    ///
    /// **Write barrier**: the `Parts` fan-out happens under the insert
    /// lock, and every write (insert or delete) enqueues on its shards
    /// under that same lock before returning. Per-shard channels are
    /// FIFO, so each shard's `Parts` snapshot sits at the *same* point
    /// of the write stream — a save taken mid-traffic captures exactly
    /// the writes enqueued before the fence, none after, on every shard
    /// alike. The recorded id high-water mark is read inside the fence
    /// for the same reason. Waiting for the parts (and streaming them
    /// out) happens after the lock is released, so writers only stall
    /// for the S channel sends, not the serialization.
    ///
    /// With a WAL attached the same fence rotates the log: a fresh
    /// segment opens inside the critical section (so it holds exactly
    /// the writes after the fence) and the old segments are deleted only
    /// once the snapshot has durably renamed into place — a crash at any
    /// point leaves either the old snapshot + full log, or the new
    /// snapshot plus stale segments whose records replay idempotently
    /// below the recorded id high-water mark.
    pub fn save(&self, path: &Path) -> Result<(), StoreError> {
        self.save_with_cursor(path).map(|_| ())
    }

    /// [`Engine::save`], additionally reporting the WAL frontier the
    /// snapshot corresponds to: the cursor of the fresh segment opened
    /// inside the save fence (`None` without a WAL). Every record at or
    /// past the cursor post-dates the snapshot — this is exactly the
    /// `wal.fetch` position a replica should tail from after fetching
    /// this snapshot, captured atomically with it.
    pub fn save_with_cursor(&self, path: &Path) -> Result<Option<WalCursor>, StoreError> {
        let (reply_tx, reply_rx) = channel();
        let (next_id, cursor) = {
            let mut fence = self.insert_lock.lock().unwrap();
            for (no, s) in self.shards.iter().enumerate() {
                s.tx
                    .send(ShardMsg::Parts { reply: reply_tx.clone(), shard_no: no })
                    .map_err(|_| {
                        StoreError::corrupt(format!("save: shard {no} worker is gone"))
                    })?;
            }
            let cursor = match fence.wal.as_mut() {
                Some(w) => {
                    w.rotate_begin()?;
                    Some(w.cursor())
                }
                None => None,
            };
            (self.next_id.load(Ordering::SeqCst), cursor)
        };
        drop(reply_tx);
        let mut parts: Vec<Option<ShardParts>> = (0..self.shards.len()).map(|_| None).collect();
        for (no, p) in reply_rx {
            parts[no] = Some(p);
        }
        let parts: Vec<ShardParts> = parts
            .into_iter()
            .enumerate()
            .map(|(no, p)| {
                p.ok_or_else(|| {
                    StoreError::corrupt(format!(
                        "save: shard {no} did not report its parts (worker dead)"
                    ))
                })
            })
            .collect::<Result<_, _>>()?;

        let n_sections =
            1 + parts.len() * 3 + parts.iter().filter(|p| p.rows.is_some()).count();
        let mut out = SnapshotStreamWriter::create(path, n_sections)?;
        let mut w = ByteWriter::new();
        w.put_usize(self.l);
        w.put_usize(self.b);
        w.put_u64(next_id as u64);
        w.put_usize(parts.len());
        for p in &parts {
            w.put_u8(u8::from(p.rows.is_some()));
        }
        out.add_section("meta", &w.into_bytes())?;
        for (i, p) in parts.iter().enumerate() {
            out.add_section(&format!("shard.{i}"), &to_payload(&*p.index))?;
            if let Some(rows) = &p.rows {
                out.add_section(&format!("rows.{i}"), &to_payload(&**rows))?;
            }
            let mut w = ByteWriter::new();
            p.map.write_into(&mut w);
            w.put_usize(self.b);
            w.put_usize(self.l);
            w.put_u32s(p.delta.ids());
            let mut chars = Vec::with_capacity(p.delta.len() * self.l);
            for r in 0..p.delta.len() {
                chars.extend_from_slice(p.delta.row(r));
            }
            w.put_bytes(&chars);
            out.add_section(&format!("delta.{i}"), &w.into_bytes())?;
            let mut w = ByteWriter::new();
            w.put_u32s(&p.tombstones);
            out.add_section(&format!("tombstones.{i}"), &w.into_bytes())?;
        }
        out.finish()?;
        // The snapshot is durably in place: publish it to the recovery
        // plan (bumping the generation so an in-flight shard rebuild
        // retries) *before* deleting the WAL segments it supersedes.
        self.recovery.committed_save(path);
        if let Some(w) = self.insert_lock.lock().unwrap().wal.as_mut() {
            // A failed cleanup is not a failed save: stale segments only
            // hold records below the high-water mark, which replay as
            // no-ops on the next load.
            let _ = w.rotate_commit();
        }
        Ok(cursor)
    }

    /// Restores an engine from a snapshot and spawns its workers. The
    /// load path is parse + validate only: no sorting, no trie
    /// construction, no rank/select re-indexing. v1 snapshots load as
    /// all-immutable engines (no raw rows — see the module docs).
    pub fn load(path: &Path) -> Result<Self, StoreError> {
        Self::load_with(path, false)
    }

    /// [`Engine::load`] with an explicit serving mode. With
    /// `mapped = true` the snapshot is `mmap`ed read-only and every
    /// immutable payload array (trie postings, plane words, rank
    /// directories, …) borrows the mapping instead of copying —
    /// validation still runs in full. Write-path state (delta rows,
    /// tombstones, id maps) is always rebuilt owned, and merges fold
    /// into owned memory, so the engine stays fully writable; the
    /// mapping is released when the last borrowing structure drops.
    /// If the platform cannot map the file the open falls back to the
    /// owned read transparently.
    pub fn load_with(path: &Path, mapped: bool) -> Result<Self, StoreError> {
        let snap = if mapped {
            Snapshot::open_mapped(path)?
        } else {
            Snapshot::open(path)?
        };
        let mut engine = if snap.version() == FORMAT_VERSION_V1 {
            Self::load_v1(&snap)?
        } else {
            Self::load_v2(&snap)?
        };
        engine.mapping = snap.mapping().cloned();
        if let Some(m) = &engine.mapping {
            // Page-level advice for the cold-start period: trie descent
            // and plane-word probes touch scattered pages, so readahead
            // over the whole snapshot only evicts hotter pages
            // (MADV_RANDOM) — but the shard index sections *are* the hot
            // set, so pre-fault those (MADV_WILLNEED) to spare the first
            // queries a cold fault per probe. Best-effort: a failed
            // advice changes performance, never correctness.
            let mut advised = m.advise_random().unwrap_or(0);
            for (name, off, len) in snap.section_ranges() {
                if name.starts_with("shard.") {
                    advised += m.advise_willneed(off, len).unwrap_or(0);
                }
            }
            engine.advised_bytes = Some(advised);
        }
        // The source snapshot doubles as the shard-rebuild source until
        // the next save supersedes it.
        engine.recovery.set_snapshot(path);
        Ok(engine)
    }

    /// PR 2 snapshots: `meta` (L, n, shard offsets) + `shard.N`.
    fn load_v1(snap: &Snapshot) -> Result<Self, StoreError> {
        ensure(
            snap.section_names().all(|n| {
                !n.starts_with("rows.")
                    && !n.starts_with("delta.")
                    && !n.starts_with("tombstones.")
            }),
            || "v1 snapshot carries write-path sections (delta/rows/tombstones)".to_string(),
        )?;
        let mut r = snap.section("meta")?;
        let l = r.get_usize()?;
        let n = r.get_usize()?;
        let n_shards = r.get_usize()?;
        ensure(l >= 1 && (1..=Self::MAX_SHARDS).contains(&n_shards), || {
            format!("engine meta: bad shape L={l} shards={n_shards}")
        })?;
        let mut offsets = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let o = r.get_u64()?;
            offsets.push(u32::try_from(o).map_err(|_| {
                StoreError::Corrupt(format!("engine meta: shard offset {o} exceeds u32"))
            })?);
        }
        r.expect_end()?;
        ensure(u32::try_from(n).is_ok(), || {
            format!("engine meta: n={n} exceeds the u32 id space")
        })?;

        let mut states = Vec::with_capacity(n_shards);
        let mut covered = 0usize;
        let mut b = 0usize;
        for (i, &offset) in offsets.iter().enumerate() {
            let mut sr = snap.section(&format!("shard.{i}"))?;
            let index: ShardIndex = from_payload(&mut sr)?;
            ensure(offset as usize == covered, || {
                format!("engine meta: shard {i} offset {offset} does not tile (expected {covered})")
            })?;
            validate_shard_index(&index, i, l)?;
            ensure(i == 0 || index.b() == b, || {
                format!("shard {i}: alphabet b={} differs from shard 0's b={b}", index.b())
            })?;
            b = index.b();
            covered += index.n_rows();
            let map = IdMap::Contig { offset, n: index.n_rows() as u32 };
            let kind = index.recipe();
            states.push(SegmentedShard::new(kind, Arc::new(index), map, None));
        }
        ensure(covered == n, || {
            format!("engine meta: shards cover {covered} rows, expected n={n}")
        })?;
        Ok(Engine::assemble(l, b, n as u32, states))
    }

    /// v2 snapshots: the write path's sections, fully cross-validated —
    /// every assigned id must appear in exactly one shard (base or
    /// delta), all maps strictly increasing, tombstones owned.
    fn load_v2(snap: &Snapshot) -> Result<Self, StoreError> {
        let mut r = snap.section("meta")?;
        let l = r.get_usize()?;
        let b = r.get_usize()?;
        let next_id = r.get_u64()?;
        let n_shards = r.get_usize()?;
        ensure(
            l >= 1 && matches!(b, 1..=8) && (1..=Self::MAX_SHARDS).contains(&n_shards),
            || format!("engine meta: bad shape L={l} b={b} shards={n_shards}"),
        )?;
        let next_id = u32::try_from(next_id).map_err(|_| {
            StoreError::Corrupt(format!("engine meta: next_id {next_id} exceeds u32"))
        })?;
        let mut has_rows = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            has_rows.push(r.get_u8()? != 0);
        }
        r.expect_end()?;

        let mut states = Vec::with_capacity(n_shards);
        let mut total_rows = 0usize;
        for (i, &with_rows) in has_rows.iter().enumerate() {
            let shard = load_shard_state(snap, i, l, b, with_rows)?;
            total_rows += shard.n_rows();
            states.push(shard);
        }
        ensure(total_rows == next_id as usize, || {
            format!("engine meta: shards hold {total_rows} ids, next_id={next_id}")
        })?;

        // Global tiling: every id in [0, next_id) lives in exactly one
        // shard, and every tombstone names an id its shard owns.
        let mut seen = vec![false; next_id as usize];
        for (i, state) in states.iter().enumerate() {
            for g in state.owned_ids() {
                let slot = seen.get_mut(g as usize).ok_or_else(|| {
                    StoreError::Corrupt(format!("shard {i}: id {g} >= next_id {next_id}"))
                })?;
                ensure(!*slot, || format!("id {g} owned by two shards"))?;
                *slot = true;
            }
            for &t in state.tombstone_ids() {
                ensure(state.owns_id(t), || {
                    format!("tombstones.{i}: id {t} is not owned by shard {i}")
                })?;
            }
        }
        debug_assert!(seen.iter().all(|&s| s), "tiling checked via total_rows");

        Ok(Engine::assemble(l, b, next_id, states))
    }

    /// Attaches a write-ahead log at segment base `base`, replaying any
    /// surviving records first: inserts past the engine's current id
    /// high-water mark (everything below it is already in the snapshot
    /// this engine loaded from) and every delete (tombstoning is
    /// idempotent). After this returns, all writes append to the log —
    /// durable per `sync` — before they are applied or acknowledged.
    ///
    /// Call this on a freshly loaded (or built) engine, before serving
    /// traffic; replayed rows keep their originally assigned ids and do
    /// not count toward the insert metrics.
    pub fn attach_wal(&self, base: &Path, sync: WalSync) -> Result<WalReport, StoreError> {
        self.attach_wal_with(base, sync, None)
    }

    /// [`Engine::attach_wal`] with an explicit group-commit window:
    /// `None` is auto (group commit on under [`WalSync::Always`], the
    /// leader fsyncs as soon as it is elected), `Some(0)` disables
    /// grouping (every append fsyncs inline, under the insert lock —
    /// the pre-group-commit write path), and `Some(us)` makes the
    /// leader wait `us` microseconds for more writers to join before
    /// its fsync. `batch`/`off` never group — their appends already
    /// defer the fsync.
    pub fn attach_wal_with(
        &self,
        base: &Path,
        sync: WalSync,
        group_window_us: Option<u64>,
    ) -> Result<WalReport, StoreError> {
        let mut cell = self.insert_lock.lock().unwrap();
        ensure(cell.wal.is_none(), || "a WAL is already attached".to_string())?;
        let (mut wal, records, open) = Wal::open(base, sync)?;
        let mut report = WalReport {
            segments: open.segments,
            truncated_bytes: open.truncated_bytes,
            ..WalReport::default()
        };
        for rec in records {
            self.apply_wal_record(rec, usize::MAX, &mut report)?;
        }
        if sync == WalSync::Always && group_window_us != Some(0) {
            wal.enable_group(self.n() as u64, group_window_us.unwrap_or(0));
        }
        self.recovery.set_wal(wal.base());
        cell.wal = Some(wal);
        Ok(report)
    }

    /// Applies a stream of WAL records shipped from another engine (the
    /// replication apply path). Identical idempotent semantics to
    /// [`Engine::attach_wal`] replay — inserts entirely below the id
    /// high-water mark are skipped, partial overlaps apply only the new
    /// suffix, deletes re-tombstone harmlessly — so a follower may
    /// re-fetch an overlapping WAL span after a reconnect and converge
    /// anyway. Runs under the insert lock for the whole batch; unlike
    /// recovery replay, background merges trigger normally so a
    /// long-running follower compacts like its primary.
    pub fn apply_replicated(&self, records: Vec<WalRecord>) -> Result<WalReport, StoreError> {
        let _cell = self.insert_lock.lock().unwrap();
        let threshold = self.merge_threshold.load(Ordering::Relaxed);
        let mut report = WalReport::default();
        for rec in records {
            self.apply_wal_record(rec, threshold, &mut report)?;
        }
        Ok(report)
    }

    /// The segment base of the attached WAL, if any (what `wal.fetch`
    /// serves from).
    pub fn wal_base(&self) -> Option<PathBuf> {
        self.recovery.wal_path()
    }

    /// The attached WAL's group-commit handle, if group commit is on.
    /// Takes the insert lock only long enough to clone the `Arc`.
    fn group_commit(&self) -> Option<Arc<wal::GroupCommit>> {
        let cell = self.insert_lock.lock().unwrap();
        cell.wal.as_ref().and_then(|w| w.group().cloned())
    }

    /// The durable WAL frontier `wal.fetch` must clamp to under group
    /// commit: frames at or past it sit in the page cache awaiting the
    /// group fsync, and that fsync can still fail (the span is then
    /// NACKed and re-staged) — a follower must never apply a record
    /// its primary has not yet acknowledged as durable.
    /// `None` means no clamping (no WAL, group commit off, or a
    /// deferred-sync policy whose contract already tolerates loss).
    pub fn durable_frontier(&self) -> Option<WalCursor> {
        self.group_commit().map(|g| g.durable_cursor())
    }

    /// Row count at the durability watermark: what a primary reports
    /// to followers (`repl.status` / `wal.fetch` headers). With group
    /// commit open groups make [`Engine::n`] run ahead of the fsynced
    /// log; reporting the watermark instead keeps follower lag
    /// non-negative and measured against state that survives a crash.
    /// Without group commit the two coincide (inserts publish
    /// `next_id` only after their durable append returns).
    pub fn durable_n(&self) -> u64 {
        match self.group_commit() {
            Some(g) => g.durable_rows(),
            None => self.n() as u64,
        }
    }

    /// Applies one WAL record to the shards. Caller holds the insert
    /// lock (replay and replication both order their whole batch under
    /// it). `merge_threshold` is `usize::MAX` during recovery replay —
    /// deterministic, no background merges — and the live threshold on
    /// the replication path.
    fn apply_wal_record(
        &self,
        rec: WalRecord,
        merge_threshold: usize,
        report: &mut WalReport,
    ) -> Result<(), StoreError> {
        let n_shards = self.shards.len() as u32;
        match rec {
            WalRecord::Insert { start_id, n, chars } => {
                let n = n as usize;
                ensure(n > 0 && chars.len() == n * self.l, || {
                    format!(
                        "wal replay: insert record shape n={n} chars={}, L={}",
                        chars.len(),
                        self.l
                    )
                })?;
                ensure(chars.iter().all(|&c| (c as usize) < (1 << self.b)), || {
                    format!("wal replay: char outside the 2^{} alphabet", self.b)
                })?;
                let end = start_id
                    .checked_add(n as u32)
                    .ok_or_else(|| StoreError::corrupt("wal replay: id overflow".into()))?;
                let cur = self.next_id.load(Ordering::SeqCst);
                if end <= cur {
                    // Entirely below the high-water mark: a segment a
                    // crashed rotation left behind, or a replication
                    // re-fetch of an already-applied span.
                    report.skipped_records += 1;
                    return Ok(());
                }
                ensure(start_id <= cur, || {
                    format!(
                        "wal replay: record starts at id {start_id}, engine expects {cur} \
                         (log gap)"
                    )
                })?;
                let (reply_tx, reply_rx) = channel();
                let mut per_shard: Vec<Vec<(u32, Vec<u8>)>> =
                    (0..n_shards).map(|_| Vec::new()).collect();
                let mut replayed = 0usize;
                for (j, row) in chars.chunks_exact(self.l).enumerate() {
                    let id = start_id + j as u32;
                    if id < cur {
                        continue; // already in the snapshot
                    }
                    per_shard[(id % n_shards) as usize].push((id, row.to_vec()));
                    replayed += 1;
                }
                let mut outstanding = 0usize;
                for (s, items) in per_shard.into_iter().enumerate() {
                    if items.is_empty() {
                        continue;
                    }
                    outstanding += 1;
                    self.shards[s]
                        .tx
                        .send(ShardMsg::Insert {
                            items,
                            merge_threshold,
                            reply: reply_tx.clone(),
                        })
                        .map_err(|_| {
                            StoreError::corrupt(format!("wal replay: shard {s} is gone"))
                        })?;
                }
                drop(reply_tx);
                for _ in 0..outstanding {
                    reply_rx.recv().map_err(|_| {
                        StoreError::corrupt("wal replay: shard died mid-replay".into())
                    })?;
                }
                self.next_id.store(end, Ordering::SeqCst);
                report.replayed_inserts += replayed;
            }
            WalRecord::Delete { id } => {
                if (id as usize) >= self.n() {
                    report.skipped_records += 1;
                    return Ok(());
                }
                let (reply_tx, reply_rx) = channel();
                for s in &self.shards {
                    s.tx
                        .send(ShardMsg::Delete { id, reply: reply_tx.clone() })
                        .map_err(|_| StoreError::corrupt("wal replay: shard is gone".into()))?;
                }
                drop(reply_tx);
                let _ = reply_rx.iter().any(|d| d);
                report.replayed_deletes += 1;
            }
            WalRecord::MergeMarker => {}
        }
        Ok(())
    }

    /// This engine's process-unique failpoint context prefix; worker
    /// sites fire under `"{instance_tag}/shard-{no}"`, so tests can
    /// scope injected faults to one engine (or one shard).
    pub fn instance_tag(&self) -> String {
        format!("engine-{}", self.instance)
    }

    /// Size of the snapshot mapping this engine serves from (`None`
    /// when loaded owned).
    pub fn mapped_bytes(&self) -> Option<usize> {
        self.mapping.as_ref().map(|m| m.len())
    }

    /// Resident (page-cache-backed) bytes of the mapping, probed via
    /// `mincore`; `None` when not mapped or unsupported.
    pub fn resident_bytes(&self) -> Option<usize> {
        self.mapping.as_ref().and_then(|m| m.resident_bytes())
    }

    /// Bytes of `madvise` advice issued over the mapping at load time
    /// (`MADV_RANDOM` across the file plus `MADV_WILLNEED` over the
    /// `shard.N` index sections); `None` when loaded owned.
    pub fn advised_bytes(&self) -> Option<usize> {
        self.advised_bytes
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total ids ever assigned (tombstoned rows included — ids are never
    /// reused, so this is also the next insert's id).
    pub fn n(&self) -> usize {
        self.next_id.load(Ordering::SeqCst) as usize
    }

    pub fn l(&self) -> usize {
        self.l
    }

    /// Alphabet bits `b` of the served sketches.
    pub fn b(&self) -> usize {
        self.b
    }

    /// Heap owned by the shard states at assembly time (delta growth and
    /// merges are not tracked — this is a capacity report, not a gauge).
    pub fn heap_bytes(&self) -> usize {
        self.heap_bytes
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Sets the active-delta size that triggers a background merge
    /// (`usize::MAX` disables auto-merging; [`Engine::merge`] still
    /// works). Takes effect for subsequent inserts.
    pub fn set_merge_threshold(&self, threshold: usize) {
        self.merge_threshold.store(threshold, Ordering::SeqCst);
    }

    pub fn merge_threshold(&self) -> usize {
        self.merge_threshold.load(Ordering::SeqCst)
    }

    /// Inserts one sketch; returns its assigned global id.
    pub fn insert(&self, row: &[u8]) -> Result<u32, String> {
        let batch = [row.to_vec()];
        self.insert_batch(&batch).map(|range| range.start)
    }

    /// Inserts a batch: assigns consecutive global ids (returned as a
    /// range), stripes the rows over shards by `id % S`, and blocks
    /// until every shard has appended its share — after this returns,
    /// queries see the new rows.
    pub fn insert_batch(&self, rows: &[Vec<u8>]) -> Result<std::ops::Range<u32>, String> {
        for (i, row) in rows.iter().enumerate() {
            if row.len() != self.l {
                return Err(format!("insert row {i}: length {} != L={}", row.len(), self.l));
            }
            if let Some(&c) = row.iter().find(|&&c| (c as usize) >= (1 << self.b)) {
                return Err(format!("insert row {i}: char {c} outside the 2^{} alphabet", self.b));
            }
        }
        let n = u32::try_from(rows.len()).map_err(|_| "batch exceeds u32".to_string())?;
        if n == 0 {
            let cur = self.next_id.load(Ordering::SeqCst);
            return Ok(cur..cur);
        }
        let threshold = self.merge_threshold();
        let owned: Vec<Vec<u8>> = rows.to_vec(); // copy outside the lock
        let (reply_tx, reply_rx) = channel();
        // Reserve the id range and enqueue on the shards under the
        // insert lock: concurrent batches must reach each shard in
        // global id order. The critical section is id assignment, the
        // WAL append (when one is attached — the record lands in the
        // log before any shard sees the rows, so the log's order equals
        // the shards' apply order), plus O(n) row *moves* and the
        // channel sends — the byte copies happened above, and both
        // ack-waiting and the group-commit fsync happen after unlock.
        let (first, outstanding, commit) = {
            let mut order = self.insert_lock.lock().unwrap();
            let cur = self.next_id.load(Ordering::SeqCst);
            let end = cur
                .checked_add(n)
                .ok_or_else(|| format!("id space exhausted: {cur} + {n} exceeds u32"))?;
            let mut commit = WriteCommit::None;
            if let Some(w) = order.wal.as_mut() {
                let mut chars = Vec::with_capacity(owned.len() * self.l);
                for row in &owned {
                    chars.extend_from_slice(row);
                }
                // On failure the ids stay unreserved and no shard has
                // seen the batch: the write simply did not happen.
                let lsn = w
                    .append(&WalRecord::Insert { start_id: cur, n, chars })
                    .map_err(|e| format!("wal append failed, write not applied: {e}"))?;
                commit = match w.group() {
                    Some(g) => {
                        g.note_rows(end as u64);
                        WriteCommit::Group(Arc::clone(g), lsn)
                    }
                    None if w.sync_mode() == WalSync::Always => WriteCommit::Inline,
                    None => WriteCommit::None,
                };
            }
            self.next_id.store(end, Ordering::SeqCst);
            let n_shards = self.shards.len() as u32;
            let mut per_shard: Vec<Vec<(u32, Vec<u8>)>> =
                (0..n_shards).map(|_| Vec::new()).collect();
            for (i, row) in owned.into_iter().enumerate() {
                let id = cur + i as u32;
                per_shard[(id % n_shards) as usize].push((id, row));
            }
            let mut outstanding = 0usize;
            for (s, items) in per_shard.into_iter().enumerate() {
                if items.is_empty() {
                    continue;
                }
                outstanding += 1;
                self.shards[s]
                    .tx
                    .send(ShardMsg::Insert {
                        items,
                        merge_threshold: threshold,
                        reply: reply_tx.clone(),
                    })
                    .expect("shard worker alive");
            }
            (cur, outstanding, commit)
        };
        drop(reply_tx);
        // Collect shard acks *before* blocking on durability: the
        // in-memory apply overlaps the group leader's fsync.
        let mut acked = 0usize;
        for _ in 0..outstanding {
            match reply_rx.recv() {
                Ok(k) => acked += k,
                // A shard dropped the batch (panic with no rebuild
                // source). The write is durable if a WAL is attached —
                // it will surface on the next load — but is not fully
                // applied to this engine, so report failure.
                Err(_) => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    return Err(format!(
                        "shard worker unavailable: batch {first}..{} not fully applied",
                        first + n
                    ));
                }
            }
        }
        debug_assert_eq!(acked, rows.len());
        // Ack on the watermark: under group commit the rows are applied
        // in memory but the write is acknowledged only once the
        // durable-LSN watermark covers its record. A failed group fsync
        // reports failure here — never a false ack — while the record
        // stays staged for the next group's retry (see
        // `Wal::group_abort` for why erasing it would corrupt replay).
        self.settle_commit(commit)
            .map_err(|e| format!("wal sync failed, write not acknowledged: {e}"))?;
        self.metrics.record_inserts(rows.len());
        Ok(first..first + n)
    }

    /// Finishes a write's durability contract after the shards applied
    /// it: blocks on the group-commit watermark (possibly leading the
    /// group's single fsync) or, on the inline `always` path, just
    /// accounts for the fsync `Wal::append` already performed.
    fn settle_commit(&self, commit: WriteCommit) -> Result<(), StoreError> {
        match commit {
            WriteCommit::None => Ok(()),
            WriteCommit::Inline => {
                self.metrics.record_wal_fsync(1, 1);
                Ok(())
            }
            WriteCommit::Group(group, lsn) => {
                let out = group.wait_durable(lsn, || {
                    // A group fsync failed: re-stage the un-synced span
                    // under the insert lock so no append lands while
                    // the tail is being rewritten.
                    let mut cell = self.insert_lock.lock().unwrap();
                    if let Some(w) = cell.wal.as_mut() {
                        w.group_abort();
                    }
                })?;
                if out.fsyncs > 0 {
                    self.metrics.record_wal_fsync(out.fsyncs, out.records);
                }
                Ok(())
            }
        }
    }

    /// Deletes a global id (tombstone). Returns `true` if the id existed
    /// and was newly deleted; repeated/unknown ids return `false` — as
    /// does a delete whose WAL record failed to become durable (the
    /// tombstone may be applied in memory, but it was never
    /// acknowledged and does not survive a restart).
    pub fn delete(&self, id: u32) -> bool {
        if (id as usize) >= self.n() {
            return false;
        }
        let (reply_tx, reply_rx) = channel();
        let commit = {
            // Same write barrier as inserts: broadcast under the insert
            // lock so a concurrent `save` observes the delete on every
            // shard or on none (see [`Engine::save`]), and the WAL
            // record lands before any shard applies the tombstone.
            let mut order = self.insert_lock.lock().unwrap();
            let mut commit = WriteCommit::None;
            if let Some(w) = order.wal.as_mut() {
                match w.append(&WalRecord::Delete { id }) {
                    Ok(lsn) => {
                        commit = match w.group() {
                            Some(g) => WriteCommit::Group(Arc::clone(g), lsn),
                            None if w.sync_mode() == WalSync::Always => WriteCommit::Inline,
                            None => WriteCommit::None,
                        };
                    }
                    Err(_) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        return false;
                    }
                }
            }
            for s in &self.shards {
                s.tx
                    .send(ShardMsg::Delete { id, reply: reply_tx.clone() })
                    .expect("shard worker alive");
            }
            commit
        };
        drop(reply_tx);
        let deleted = reply_rx.iter().any(|d| d);
        if self.settle_commit(commit).is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        if deleted {
            self.metrics.deletes.fetch_add(1, Ordering::Relaxed);
        }
        deleted
    }

    /// Force-merges every shard synchronously: when this returns (and
    /// absent legacy skips), all deltas are folded and the engine is
    /// entirely immutable — the deterministic pre-save / CI hook.
    pub fn merge(&self) -> MergeSummary {
        let commit = {
            // Informational marker (explicit merges only — background
            // merges never touch the insert lock). Replay ignores it;
            // it exists so a log can be audited against the op history.
            let mut order = self.insert_lock.lock().unwrap();
            match order.wal.as_mut() {
                Some(w) => match w.append(&WalRecord::MergeMarker) {
                    Ok(lsn) => match w.group() {
                        Some(g) => WriteCommit::Group(Arc::clone(g), lsn),
                        None if w.sync_mode() == WalSync::Always => WriteCommit::Inline,
                        None => WriteCommit::None,
                    },
                    Err(_) => WriteCommit::None,
                },
                None => WriteCommit::None,
            }
        };
        // Audit-only record: wait for the watermark (keeping the log's
        // prompt-fsync cadence) but a failed group does not fail the
        // merge — replay ignores markers anyway.
        let _ = self.settle_commit(commit);
        let (reply_tx, reply_rx) = channel();
        for s in &self.shards {
            s.tx
                .send(ShardMsg::ForceMerge { reply: reply_tx.clone() })
                .expect("shard worker alive");
        }
        drop(reply_tx);
        let mut summary = MergeSummary::default();
        for outcome in reply_rx {
            match outcome {
                MergeOutcome::Merged => {
                    summary.merged += 1;
                    self.metrics.merges.fetch_add(1, Ordering::Relaxed);
                }
                MergeOutcome::Clean => summary.merged += 1,
                MergeOutcome::Skipped => summary.skipped += 1,
            }
        }
        summary
    }

    /// Enqueues `q` on every shard; the query bytes are shared via one
    /// `Arc` clone per shard, never copied.
    fn fan_out(
        &self,
        q: &Arc<[u8]>,
        tau: usize,
        mode: QueryMode,
        reply_tx: &Sender<(usize, ShardReply)>,
    ) {
        for (no, shard) in self.shards.iter().enumerate() {
            shard
                .tx
                .send(ShardMsg::Query {
                    q: Arc::clone(q),
                    tau,
                    mode,
                    reply: reply_tx.clone(),
                    shard_no: no,
                })
                .expect("shard worker alive");
        }
    }

    /// The unified single-query entry point: fans `spec` out to every
    /// shard and merges per [`QuerySpec::mode`]. The server, the
    /// batcher and the CLI all route through here (batches go through
    /// [`Engine::run_batch`] / [`Engine::run_batch_blocked`], which
    /// share the same shard protocol). Returns
    /// [`QueryResult::Failed`] — never a silently partial merge — if a
    /// shard worker died or was parked.
    pub fn query(&self, spec: &QuerySpec) -> QueryOutput {
        assert_eq!(spec.q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&spec.q, spec.tau, spec.mode, &reply_tx);
        drop(reply_tx);
        let result = Self::collect_one(&reply_rx, spec.mode, self.shards.len());
        let size = match &result {
            QueryResult::Ids(v) => v.len(),
            QueryResult::Count(c) => *c,
            QueryResult::TopK(v) => v.len(),
            QueryResult::Failed => 0,
        };
        self.metrics.record_query(timer.elapsed_us() as u64, size);
        result
    }

    /// Fans a query out to every shard and merges global ids.
    ///
    /// Deprecated shim over [`Engine::query`] with
    /// [`QuerySpec::ids`] — kept so existing callers and tests read
    /// naturally; a failed query collapses to no hits here, so callers
    /// that must distinguish failure should use [`Engine::query`].
    pub fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        match self.query(&QuerySpec::ids(q, tau)) {
            QueryResult::Ids(hits) => hits,
            _ => Vec::new(),
        }
    }

    /// Counts matches across all shards.
    ///
    /// Deprecated shim over [`Engine::query`] with
    /// [`QuerySpec::count`]; failure collapses to 0.
    pub fn count(&self, q: &[u8], tau: usize) -> usize {
        match self.query(&QuerySpec::count(q, tau)) {
            QueryResult::Count(n) => n,
            _ => 0,
        }
    }

    /// Global top-k within radius `tau`: each shard answers its local
    /// top-k over global ids (per-shard id maps are monotone, so local
    /// heap order equals global order), merged by `(dist, id)` — the
    /// merge is exact. Returns `(id, dist)` pairs.
    ///
    /// Deprecated shim over [`Engine::query`] with
    /// [`QuerySpec::top_k`]; failure collapses to no hits.
    pub fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        match self.query(&QuerySpec::top_k(q, k, tau)) {
            QueryResult::TopK(hits) => hits,
            _ => Vec::new(),
        }
    }

    fn merge_topk(
        replies: impl Iterator<Item = (usize, ShardReply)>,
        k: usize,
    ) -> Vec<(u32, usize)> {
        let mut all: Vec<(usize, u32)> = Vec::new();
        for (_no, reply) in replies {
            if let ShardReply::TopK(hits) = reply {
                all.extend(hits.into_iter().map(|(id, d)| (d, id)));
            }
        }
        all.sort_unstable();
        all.truncate(k);
        all.into_iter().map(|(d, id)| (id, d)).collect()
    }

    /// Executes a mixed-mode batch of queries as one pipelined fan-out
    /// round (the batcher's entry point — search, count *and* top-k all
    /// flow through here). All queries are enqueued on every shard
    /// *before* any result is collected, so the batch completes in
    /// (slowest shard's queue) time rather than Σ per-query latencies.
    /// Each query's latency is stamped from its own fan-out to its last
    /// shard reply — real per-query wall time, identical accounting for
    /// all three modes.
    pub fn run_batch(&self, queries: &[(Arc<[u8]>, usize, QueryMode)]) -> Vec<QueryResult> {
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        for (q, _, _) in queries {
            assert_eq!(q.len(), self.l, "query length mismatch");
        }
        // Phase 1: fan out everything.
        let pending: Vec<_> = queries
            .iter()
            .map(|(q, tau, mode)| {
                let timer = Timer::start();
                let (reply_tx, reply_rx) = channel();
                self.fan_out(q, *tau, *mode, &reply_tx);
                (*mode, timer, reply_rx)
            })
            .collect();
        // Phase 2: collect in request order.
        let n_shards = self.shards.len();
        pending
            .into_iter()
            .map(|(mode, timer, rx)| {
                let result = Self::collect_one(&rx, mode, n_shards);
                let size = match &result {
                    QueryResult::Ids(v) => v.len(),
                    QueryResult::Count(c) => *c,
                    QueryResult::TopK(v) => v.len(),
                    QueryResult::Failed => 0,
                };
                self.metrics.record_query(timer.elapsed_us() as u64, size);
                result
            })
            .collect()
    }

    /// Collects one fanned-out query's shard replies. A closed reply
    /// channel before all `n_shards` answers arrived means a shard
    /// dropped the query (worker died with no rebuild source): the
    /// query reports [`QueryResult::Failed`] instead of hanging or
    /// silently answering from a subset of the data.
    fn collect_one(
        rx: &Receiver<(usize, ShardReply)>,
        mode: QueryMode,
        n_shards: usize,
    ) -> QueryResult {
        match mode {
            QueryMode::Ids => {
                let mut merged = Vec::new();
                for _ in 0..n_shards {
                    match rx.recv() {
                        Ok((_no, ShardReply::Ids(hits))) => merged.extend(hits),
                        Ok(_) => {}
                        Err(_) => return QueryResult::Failed,
                    }
                }
                QueryResult::Ids(merged)
            }
            QueryMode::Count => {
                let mut total = 0usize;
                for _ in 0..n_shards {
                    match rx.recv() {
                        Ok((_no, ShardReply::Count(c))) => total += c,
                        Ok(_) => {}
                        Err(_) => return QueryResult::Failed,
                    }
                }
                QueryResult::Count(total)
            }
            QueryMode::TopK(k) => {
                let mut replies = Vec::with_capacity(n_shards);
                for _ in 0..n_shards {
                    match rx.recv() {
                        Ok(r) => replies.push(r),
                        Err(_) => return QueryResult::Failed,
                    }
                }
                QueryResult::TopK(Self::merge_topk(replies.into_iter(), k))
            }
        }
    }

    /// Blocked batch execution: compatible queries (same τ, same mode)
    /// are grouped into blocks of at most `block_width` and each block
    /// fans out as **one** [`ShardMsg::QueryBlock`] per shard — the
    /// shard descends its trie and streams its delta plane words once
    /// for the whole block. Results (ids, counts, top-k order by
    /// `(dist, id)`) and per-query traversal stats are identical to
    /// [`Engine::run_batch`]; `block_width <= 1` delegates to it
    /// outright.
    ///
    /// Per-query wall time stays real: each block is timed from its own
    /// fan-out to its last shard reply, and the block's elapsed time is
    /// attributed to its queries **by share of live work** (each query's
    /// visited + pruned node count, summed across shards) — an equal
    /// split when the block did no work at all. Results are returned in
    /// request order regardless of grouping.
    pub fn run_batch_blocked(
        &self,
        queries: &[(Arc<[u8]>, usize, QueryMode)],
        block_width: usize,
    ) -> Vec<QueryResult> {
        let width = block_width.min(MAX_BLOCK);
        if width <= 1 || queries.len() <= 1 {
            return self.run_batch(queries);
        }
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        for (q, _, _) in queries {
            assert_eq!(q.len(), self.l, "query length mismatch");
        }
        let blocks = group_blocks(queries, width);
        // Phase 1: fan out every block before collecting anything.
        let pending: Vec<_> = blocks
            .into_iter()
            .map(|idxs| {
                let qs: Vec<Arc<[u8]>> =
                    idxs.iter().map(|&i| Arc::clone(&queries[i].0)).collect();
                let taus: Vec<usize> = idxs.iter().map(|&i| queries[i].1).collect();
                let mode = queries[idxs[0]].2;
                let timer = Timer::start();
                let (reply_tx, reply_rx) = channel();
                for (no, shard) in self.shards.iter().enumerate() {
                    shard
                        .tx
                        .send(ShardMsg::QueryBlock {
                            qs: qs.clone(),
                            taus: taus.clone(),
                            mode,
                            reply: reply_tx.clone(),
                            shard_no: no,
                        })
                        .expect("shard worker alive");
                }
                (idxs, mode, timer, reply_rx)
            })
            .collect();
        // Phase 2: collect block by block, merge each query across
        // shards, and scatter the results back to request order.
        let n_shards = self.shards.len();
        let mut results: Vec<Option<QueryResult>> =
            (0..queries.len()).map(|_| None).collect();
        for (idxs, mode, timer, rx) in pending {
            let m = idxs.len();
            let mut per_shard: Vec<Vec<ShardReply>> = Vec::with_capacity(n_shards);
            let mut work = vec![0u64; m];
            let mut dead = false;
            for _ in 0..n_shards {
                let Ok((_no, br)) = rx.recv() else {
                    dead = true;
                    break;
                };
                debug_assert_eq!(br.replies.len(), m);
                for (w, &x) in work.iter_mut().zip(&br.work) {
                    *w += x;
                }
                per_shard.push(br.replies);
            }
            if dead {
                // A shard dropped the whole block: every query in it
                // fails (see [`Engine::collect_one`]).
                let elapsed = timer.elapsed_us() as u64;
                for &qi in &idxs {
                    self.metrics.record_query(elapsed / m as u64, 0);
                    results[qi] = Some(QueryResult::Failed);
                }
                continue;
            }
            let elapsed = timer.elapsed_us() as u64;
            let total_work: u64 = work.iter().sum();
            let mut columns: Vec<_> = per_shard.into_iter().map(|v| v.into_iter()).collect();
            for (j, &qi) in idxs.iter().enumerate() {
                let replies = columns.iter_mut().map(|it| it.next().expect("reply per query"));
                let result = match mode {
                    QueryMode::Ids => {
                        let mut merged = Vec::new();
                        for reply in replies {
                            if let ShardReply::Ids(hits) = reply {
                                merged.extend(hits);
                            }
                        }
                        QueryResult::Ids(merged)
                    }
                    QueryMode::Count => QueryResult::Count(
                        replies
                            .map(|r| if let ShardReply::Count(c) = r { c } else { 0 })
                            .sum(),
                    ),
                    QueryMode::TopK(k) => {
                        QueryResult::TopK(Self::merge_topk(replies.map(|r| (0, r)), k))
                    }
                };
                // wall-time attribution: the block's elapsed time split
                // by each query's share of the live work
                let lat = if total_work > 0 {
                    elapsed.saturating_mul(work[j]) / total_work
                } else {
                    elapsed / m as u64
                };
                let size = match &result {
                    QueryResult::Ids(v) => v.len(),
                    QueryResult::Count(c) => *c,
                    QueryResult::TopK(v) => v.len(),
                    QueryResult::Failed => 0,
                };
                self.metrics.record_query(lat, size);
                results[qi] = Some(result);
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every query answered by exactly one block"))
            .collect()
    }

    /// Id-search-only batch (compatibility wrapper over
    /// [`Engine::run_batch`]). A failed query (dead shard) collapses to
    /// an empty hit list here — callers that must distinguish failure
    /// from no-match should use [`Engine::run_batch`] directly.
    pub fn search_batch(&self, queries: &[(Arc<[u8]>, usize)]) -> Vec<Vec<u32>> {
        let with_mode: Vec<(Arc<[u8]>, usize, QueryMode)> = queries
            .iter()
            .map(|(q, tau)| (Arc::clone(q), *tau, QueryMode::Ids))
            .collect();
        self.run_batch(&with_mode)
            .into_iter()
            .map(|r| match r {
                QueryResult::Ids(v) => v,
                QueryResult::Failed => Vec::new(),
                _ => unreachable!("Ids batch returned a non-Ids result"),
            })
            .collect()
    }
}

/// Groups a batch's queries into compatible blocks: queries sharing
/// `(τ, mode)` — including `k` for top-k — are grouped together in
/// arrival order, then split into blocks of at most `width`. Every query
/// lands in exactly one block; a group of one is a block of one.
fn group_blocks(queries: &[(Arc<[u8]>, usize, QueryMode)], width: usize) -> Vec<Vec<usize>> {
    let mut groups: Vec<((usize, u8, usize), Vec<usize>)> = Vec::new();
    for (i, (_, tau, mode)) in queries.iter().enumerate() {
        let key = match mode {
            QueryMode::Ids => (*tau, 0u8, 0usize),
            QueryMode::Count => (*tau, 1, 0),
            QueryMode::TopK(k) => (*tau, 2, *k),
        };
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((key, vec![i])),
        }
    }
    let mut blocks = Vec::new();
    for (_, idxs) in groups {
        for chunk in idxs.chunks(width.max(1)) {
            blocks.push(chunk.to_vec());
        }
    }
    blocks
}

/// Everything a shard worker thread needs besides its state: its
/// channel ends, the shared metrics, and the recovery plan + failpoint
/// context its supervisor rebuilds from.
struct WorkerCfg {
    rx: Receiver<ShardMsg>,
    self_tx: Sender<ShardMsg>,
    metrics: Arc<Metrics>,
    shard_no: usize,
    n_shards: usize,
    plan: Arc<RecoveryPlan>,
    ctx: String,
}

/// One shard worker: owns its [`SegmentedShard`] outright — queries,
/// inserts, deletes, merges and snapshots all serialize through this
/// loop, so the state needs no locks. Background merges are spawned from
/// here and return via `self_tx` as [`ShardMsg::Install`].
///
/// The loop body runs under `catch_unwind`: a panic discards the
/// (possibly half-mutated) state, bumps `worker_restarts`, and rebuilds
/// the shard from the recovery plan — the thread (and its channel)
/// never dies, so the other shards keep serving and queued messages are
/// answered after the restart. The message being processed at the panic
/// unwinds with its reply sender, so its caller sees a closed channel,
/// not a hang. If there is nothing to rebuild from (no snapshot, or a
/// v1 one) the worker drains its queue as errors until shutdown.
///
/// Restarts are rate-limited: the first restart in a while is
/// immediate (a one-off panic should not add latency), repeats inside
/// [`REBUILD_WINDOW`] back off exponentially, and more than
/// [`MAX_REBUILDS_PER_WINDOW`] of them **parks** the shard — it stops
/// rebuilding and fails its queries fast (bumping `shards_parked` in
/// the stats) instead of burning CPU on a rebuild→panic loop a
/// deterministic poison pill would otherwise cause.
fn worker_loop(state: SegmentedShard, cfg: WorkerCfg) {
    let mut state = Some(state);
    let mut restarts: Vec<Instant> = Vec::new();
    loop {
        let mut st = match state.take() {
            Some(s) => s,
            None => match rebuild_shard(&cfg.plan, cfg.shard_no, cfg.n_shards) {
                Some(s) => s,
                None => return drain_dead(&cfg.rx),
            },
        };
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_messages(&mut st, &cfg)
        }));
        match run {
            Ok(()) => return, // shutdown / engine dropped
            Err(_) => {
                // `st` drops here half-mutated; the next iteration
                // rebuilds from snapshot + WAL (unless parked).
                cfg.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                let now = Instant::now();
                restarts.retain(|t| now.duration_since(*t) < REBUILD_WINDOW);
                restarts.push(now);
                if restarts.len() > MAX_REBUILDS_PER_WINDOW {
                    cfg.metrics.shards_parked.fetch_add(1, Ordering::Relaxed);
                    return drain_dead(&cfg.rx);
                }
                if restarts.len() > 1 {
                    let exp = (restarts.len() - 2).min(5) as u32;
                    std::thread::sleep(Duration::from_millis(50u64 << exp));
                }
            }
        }
    }
}

/// Sliding window for the supervisor's restart budget.
const REBUILD_WINDOW: Duration = Duration::from_secs(60);

/// Panic-triggered rebuilds tolerated inside one [`REBUILD_WINDOW`]
/// before the shard is parked.
const MAX_REBUILDS_PER_WINDOW: usize = 5;

/// The worker's message loop proper. Returns on [`ShardMsg::Shutdown`]
/// or channel close; panics unwind to the supervisor in [`worker_loop`].
fn serve_messages(state: &mut SegmentedShard, cfg: &WorkerCfg) {
    // One QueryCtx per worker incarnation: scratch buffers (including
    // the parked top-k heap) are warmed by the first query and reused
    // until the worker restarts.
    let mut qctx = QueryCtx::new();
    while let Ok(msg) = cfg.rx.recv() {
        let _ = failpoint::check("shard.worker", &cfg.ctx);
        match msg {
            ShardMsg::Query { q, tau, mode, reply, shard_no } => {
                let result = state.query(&q, tau, mode, &mut qctx);
                let _ = reply.send((shard_no, result));
            }
            ShardMsg::QueryBlock { qs, taus, mode, reply, shard_no } => {
                let qrefs: Vec<&[u8]> = qs.iter().map(|q| &**q).collect();
                let (replies, work) = state.query_block(&qrefs, &taus, mode, &mut qctx);
                let _ = reply.send((shard_no, BlockShardReply { replies, work }));
            }
            ShardMsg::Insert { mut items, merge_threshold, reply } => {
                let n = items.len();
                // A batch queued before a panic is redelivered after the
                // rebuild already replayed it from the WAL: apply only
                // the rows that are missing, ack the original count.
                items.retain(|(id, _)| !state.owns_id(*id));
                if !items.is_empty() {
                    state.insert(&items);
                }
                if let Some(job) = state.seal_for_merge(merge_threshold) {
                    let tx = cfg.self_tx.clone();
                    let mctx = cfg.ctx.clone();
                    let metrics = Arc::clone(&cfg.metrics);
                    std::thread::Builder::new()
                        .name(format!("bst-merge-{}", cfg.shard_no))
                        .spawn(move || {
                            let built =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                                    let _ = failpoint::check("shard.merge", &mctx);
                                    job.build()
                                }));
                            match built {
                                // The worker may already be gone (engine
                                // dropped); the finished merge is moot.
                                Ok(result) => {
                                    let _ = tx.send(ShardMsg::Install(Box::new(result)));
                                }
                                // A panicked merge is simply dropped:
                                // the sealed delta stays searchable and
                                // the next merge subsumes it.
                                Err(_) => {
                                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        })
                        .expect("spawn merge thread");
                }
                let _ = reply.send(n);
            }
            ShardMsg::Delete { id, reply } => {
                let _ = reply.send(state.delete(id));
            }
            ShardMsg::ForceMerge { reply } => {
                let _ = reply.send(state.force_merge());
            }
            ShardMsg::Install(result) => {
                if state.install(*result) {
                    cfg.metrics.merges.fetch_add(1, Ordering::Relaxed);
                }
            }
            ShardMsg::Parts { reply, shard_no } => {
                let _ = reply.send((shard_no, state.parts()));
            }
            ShardMsg::Shutdown => break,
        }
    }
}

/// Terminal state for a shard whose rebuild is impossible: every
/// message is dropped on receipt — its reply sender closes, so callers
/// observe an error instead of a hang — until the engine shuts down.
fn drain_dead(rx: &Receiver<ShardMsg>) {
    while let Ok(msg) = rx.recv() {
        if matches!(msg, ShardMsg::Shutdown) {
            return;
        }
    }
}

/// Shared per-shard validation (both snapshot versions): shape agreement
/// and local posting ids bounded by the stripe. MI-bST bounds its ids
/// inside `MultiIndex::read_from`; the merge paths map `local → global`,
/// so out-of-range ids from a crafted shard must be rejected here, not
/// wrap at query time.
fn validate_shard_index(index: &ShardIndex, i: usize, l: usize) -> Result<(), StoreError> {
    ensure(index.l() == l, || {
        format!("shard {i}: sketch length {} != engine L={l}", index.l())
    })?;
    if let ShardIndex::Bst(idx) = index {
        ensure(
            idx.trie()
                .max_posting()
                .is_none_or(|m| (m as usize) < index.n_rows()),
            || format!("shard {i}: posting ids exceed the stripe size"),
        )?;
    }
    Ok(())
}

/// Parses one shard's sections out of a v2 snapshot — shared by
/// [`Engine::load_v2`] and the worker supervisor's rebuild path (which
/// restores a single shard without touching its siblings).
fn load_shard_state(
    snap: &Snapshot,
    i: usize,
    l: usize,
    b: usize,
    with_rows: bool,
) -> Result<SegmentedShard, StoreError> {
    let mut sr = snap.section(&format!("shard.{i}"))?;
    let index: ShardIndex = from_payload(&mut sr)?;
    validate_shard_index(&index, i, l)?;
    ensure(index.b() == b, || {
        format!("shard {i}: alphabet b={} != engine b={b}", index.b())
    })?;

    let rows = if with_rows {
        let mut rr = snap.section(&format!("rows.{i}"))?;
        let rows: SketchSet = from_payload(&mut rr)?;
        ensure(
            rows.b() == b && rows.l() == l && rows.n() == index.n_rows(),
            || {
                format!(
                    "rows.{i}: shape {}x{} (b={}) != shard's {} rows of L={l} (b={b})",
                    rows.n(),
                    rows.l(),
                    rows.b(),
                    index.n_rows()
                )
            },
        )?;
        Some(Arc::new(rows))
    } else {
        ensure(!snap.has_section(&format!("rows.{i}")), || {
            format!("rows.{i}: present but meta declares no rows")
        })?;
        None
    };

    let mut dr = snap.section(&format!("delta.{i}"))?;
    let map = IdMap::read_from(&mut dr)?;
    let db = dr.get_usize()?;
    let dl = dr.get_usize()?;
    let delta_ids = dr.get_u32s()?;
    let delta_chars = dr.get_bytes()?.to_vec();
    dr.expect_end()?;
    ensure(db == b && dl == l, || {
        format!("delta.{i}: shape b={db} L={dl} != engine b={b} L={l}")
    })?;
    ensure(map.len() == index.n_rows(), || {
        format!("delta.{i}: id map covers {} rows, shard has {}", map.len(), index.n_rows())
    })?;
    ensure(
        delta_ids.first().is_none() || map.max().is_none_or(|m| m < delta_ids[0]),
        || format!("delta.{i}: delta ids must exceed every base id"),
    )?;
    let delta = DeltaSegment::from_parts(b, l, delta_ids, delta_chars)?;

    let mut tr = snap.section(&format!("tombstones.{i}"))?;
    let tombstones = tr.get_u32s()?;
    tr.expect_end()?;
    ensure(tombstones.windows(2).all(|w| w[0] < w[1]), || {
        format!("tombstones.{i}: must be strictly increasing")
    })?;

    let kind = index.recipe();
    Ok(SegmentedShard::from_snapshot(kind, Arc::new(index), map, rows, delta, tombstones))
}

/// Supervisor-side shard rebuild: reopen the recovery plan's snapshot
/// (owned, never mapped — the dead worker may hold the only other
/// reference to a mapping), parse this shard's sections, and replay the
/// WAL records it owns. Retries when a concurrent save bumps the plan
/// generation mid-read (the snapshot/WAL pair it read may have been
/// torn by the rotation); gives up — returning `None`, the dead mode —
/// when there is nothing to rebuild from.
fn rebuild_shard(plan: &RecoveryPlan, shard_no: usize, n_shards: usize) -> Option<SegmentedShard> {
    for _attempt in 0..3 {
        let gen = plan.generation();
        let st = plan.state();
        let snapshot = st.snapshot.as_deref()?;
        match try_rebuild(snapshot, st.wal.as_deref(), shard_no, n_shards) {
            Ok(state) if plan.generation() == gen => return Some(state),
            Ok(_) => {} // a save landed mid-rebuild: retry on the new pair
            Err(_) if plan.generation() != gen => {}
            Err(_) => return None,
        }
    }
    None
}

fn try_rebuild(
    snapshot: &Path,
    wal_base: Option<&Path>,
    shard_no: usize,
    n_shards: usize,
) -> Result<SegmentedShard, StoreError> {
    let snap = Snapshot::open(snapshot)?;
    ensure(snap.version() != FORMAT_VERSION_V1, || {
        "cannot rebuild a shard from a v1 snapshot (no write-path sections)".to_string()
    })?;
    let mut r = snap.section("meta")?;
    let l = r.get_usize()?;
    let b = r.get_usize()?;
    let hwm = r.get_u64()?;
    let snap_shards = r.get_usize()?;
    ensure(snap_shards == n_shards, || {
        format!("snapshot holds {snap_shards} shards, engine runs {n_shards}")
    })?;
    let mut has_rows = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        has_rows.push(r.get_u8()? != 0);
    }
    r.expect_end()?;
    let hwm = u32::try_from(hwm)
        .map_err(|_| StoreError::corrupt(format!("rebuild: next_id {hwm} exceeds u32")))?;

    let mut state = load_shard_state(&snap, shard_no, l, b, has_rows[shard_no])?;
    let Some(base) = wal_base else { return Ok(state) };
    // Replay this shard's share of the log: inserts past the snapshot's
    // high-water mark striped to this shard (dynamic inserts go to
    // `id % S`), deletes wherever the shard owns the id. Records below
    // the mark come from segments a crashed rotation left behind — the
    // snapshot already holds them.
    for rec in wal::read_records(base)? {
        match rec {
            WalRecord::Insert { start_id, n, chars } => {
                let n = n as usize;
                ensure(n > 0 && chars.len() == n * l, || {
                    format!("rebuild: insert record shape n={n} chars={}, L={l}", chars.len())
                })?;
                ensure(chars.iter().all(|&c| (c as usize) < (1 << b)), || {
                    format!("rebuild: char outside the 2^{b} alphabet")
                })?;
                let mut items = Vec::new();
                for (j, row) in chars.chunks_exact(l).enumerate() {
                    let id = start_id
                        .checked_add(j as u32)
                        .ok_or_else(|| StoreError::corrupt("rebuild: id overflow".into()))?;
                    if id < hwm || (id as usize) % n_shards != shard_no || state.owns_id(id) {
                        continue;
                    }
                    items.push((id, row.to_vec()));
                }
                if !items.is_empty() {
                    state.insert(&items);
                }
            }
            WalRecord::Delete { id } => {
                let _ = state.delete(id);
            }
            WalRecord::MergeMarker => {}
        }
    }
    Ok(state)
}

impl Drop for Engine {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// A swappable engine reference: the server and batcher read the current
/// engine through this slot, and the `reload` protocol op replaces it
/// with one freshly loaded from a snapshot — zero-downtime cold-storage
/// swap (in-flight batches finish on the engine they started on).
pub struct EngineSlot {
    inner: RwLock<Arc<Engine>>,
}

impl EngineSlot {
    pub fn new(engine: Arc<Engine>) -> Self {
        EngineSlot { inner: RwLock::new(engine) }
    }

    /// The engine serving right now.
    pub fn current(&self) -> Arc<Engine> {
        self.inner.read().unwrap().clone()
    }

    /// Swaps in a new engine, returning the previous one (kept alive by
    /// any in-flight queries that still hold its `Arc`).
    pub fn replace(&self, engine: Arc<Engine>) -> Arc<Engine> {
        std::mem::replace(&mut *self.inner.write().unwrap(), engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn rows(n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut r = centers[rng.below_usize(8)].clone();
                for _ in 0..rng.below_usize(4) {
                    let p = rng.below_usize(16);
                    r[p] = rng.below(4) as u8;
                }
                r
            })
            .collect()
    }

    fn oracle(rows: &[Vec<u8>], q: &[u8], tau: usize) -> Vec<u32> {
        (0..rows.len())
            .filter(|&i| ham_chars(&rows[i], q) <= tau)
            .map(|i| i as u32)
            .collect()
    }

    #[test]
    fn sharded_equals_unsharded() {
        let rows = rows(2000, 91);
        let set = SketchSet::from_rows(2, 16, &rows);
        for n_shards in [1usize, 3, 8] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            assert_eq!(engine.n_shards(), n_shards);
            let mut rng = Rng::new(92);
            for _ in 0..10 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 2, 4] {
                    let mut got = engine.search(&q, tau);
                    got.sort();
                    assert_eq!(got, oracle(&rows, &q, tau), "shards={n_shards} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn count_and_topk_agree_with_search() {
        let rows = rows(1200, 96);
        let set = SketchSet::from_rows(2, 16, &rows);
        for n_shards in [1usize, 4] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            for qi in [0usize, 7, 400] {
                let q = &rows[qi];
                for tau in [0usize, 2, 4] {
                    assert_eq!(
                        engine.count(q, tau),
                        engine.search(q, tau).len(),
                        "shards={n_shards} tau={tau}"
                    );
                }
                // top-k equals globally sorted brute force by (dist, id)
                let tau = 4usize;
                let mut all: Vec<(usize, u32)> = (0..rows.len())
                    .map(|i| (ham_chars(&rows[i], q), i as u32))
                    .filter(|&(d, _)| d <= tau)
                    .collect();
                all.sort_unstable();
                for k in [1usize, 10, 1000] {
                    let got = engine.top_k(q, k, tau);
                    let expect: Vec<(u32, usize)> =
                        all.iter().take(k).map(|&(d, id)| (id, d)).collect();
                    assert_eq!(got, expect, "shards={n_shards} k={k}");
                }
            }
        }
    }

    #[test]
    fn batch_matches_sequential_and_records_per_query_latency() {
        let rows = rows(900, 97);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let queries: Vec<(Arc<[u8]>, usize)> = (0..8)
            .map(|i| (Arc::from(rows[i * 37].as_slice()), i % 4))
            .collect();
        let batch = engine.search_batch(&queries);
        assert_eq!(batch.len(), queries.len());
        for ((q, tau), got) in queries.iter().zip(&batch) {
            let mut got = got.clone();
            got.sort();
            let mut expect = engine.search(q, *tau);
            expect.sort();
            assert_eq!(got, expect);
        }
        // one metrics record per query (batch counted once)
        let m = engine.metrics();
        assert_eq!(
            m.queries.load(Ordering::Relaxed),
            (queries.len() * 2) as u64
        );
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn mixed_mode_batch_matches_single_queries() {
        let rows = rows(700, 99);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let q0: Arc<[u8]> = Arc::from(rows[0].as_slice());
        let q1: Arc<[u8]> = Arc::from(rows[50].as_slice());
        let batch = engine.run_batch(&[
            (Arc::clone(&q0), 2, QueryMode::Ids),
            (Arc::clone(&q1), 3, QueryMode::Count),
            (Arc::clone(&q0), 4, QueryMode::TopK(5)),
        ]);
        assert_eq!(batch.len(), 3);
        match &batch[0] {
            QueryResult::Ids(ids) => {
                let mut got = ids.clone();
                got.sort();
                let mut expect = engine.search(&q0, 2);
                expect.sort();
                assert_eq!(got, expect);
            }
            other => panic!("expected Ids, got {other:?}"),
        }
        assert_eq!(batch[1], QueryResult::Count(engine.count(&q1, 3)));
        assert_eq!(batch[2], QueryResult::TopK(engine.top_k(&q0, 5, 4)));
    }

    #[test]
    fn group_blocks_by_tau_and_mode_in_arrival_order() {
        let q: Arc<[u8]> = Arc::from(vec![0u8; 4].as_slice());
        let queries: Vec<(Arc<[u8]>, usize, QueryMode)> = vec![
            (Arc::clone(&q), 2, QueryMode::Ids),   // 0 ┐ group (2, Ids)
            (Arc::clone(&q), 1, QueryMode::Ids),   // 1 — group (1, Ids)
            (Arc::clone(&q), 2, QueryMode::Ids),   // 2 ┘
            (Arc::clone(&q), 2, QueryMode::Count), // 3 — group (2, Count)
            (Arc::clone(&q), 2, QueryMode::TopK(3)), // 4 ┐ split by k
            (Arc::clone(&q), 2, QueryMode::TopK(5)), // 5 ┘
            (Arc::clone(&q), 2, QueryMode::Ids),   // 6 — back to (2, Ids)
        ];
        let blocks = group_blocks(&queries, 8);
        assert_eq!(
            blocks,
            vec![vec![0, 2, 6], vec![1], vec![3], vec![4], vec![5]]
        );
        // width caps block size; every index appears exactly once
        let blocks = group_blocks(&queries, 2);
        assert_eq!(blocks[0], vec![0, 2]);
        let mut all: Vec<usize> = blocks.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..queries.len()).collect::<Vec<_>>());
    }

    #[test]
    fn blocked_batch_matches_serial_all_modes() {
        let all = rows(900, 101);
        let set = SketchSet::from_rows(2, 16, &all[..700]);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        // make the shards dynamic: delta rows + tombstones
        engine.insert_batch(&all[700..]).unwrap();
        engine.delete(5);
        engine.delete(750);
        let mut rng = Rng::new(102);
        let queries: Vec<(Arc<[u8]>, usize, QueryMode)> = (0..24)
            .map(|i| {
                let q: Arc<[u8]> = Arc::from(all[rng.below_usize(all.len())].as_slice());
                let tau = i % 4;
                let mode = match i % 3 {
                    0 => QueryMode::Ids,
                    1 => QueryMode::Count,
                    _ => QueryMode::TopK(5),
                };
                (q, tau, mode)
            })
            .collect();
        let serial = engine.run_batch(&queries);
        for width in [1usize, 4, 8, 64] {
            let blocked = engine.run_batch_blocked(&queries, width);
            assert_eq!(blocked.len(), serial.len());
            for (i, (s, b)) in serial.iter().zip(&blocked).enumerate() {
                match (s, b) {
                    (QueryResult::Ids(sv), QueryResult::Ids(bv)) => {
                        // shard replies merge in arrival order — sort
                        let mut sv = sv.clone();
                        let mut bv = bv.clone();
                        sv.sort_unstable();
                        bv.sort_unstable();
                        assert_eq!(sv, bv, "width={width} q={i}");
                    }
                    (s, b) => assert_eq!(s, b, "width={width} q={i}"),
                }
            }
        }
    }

    #[test]
    fn blocked_batch_records_per_query_latency() {
        let all = rows(400, 103);
        let set = SketchSet::from_rows(2, 16, &all);
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        let queries: Vec<(Arc<[u8]>, usize, QueryMode)> = (0..6)
            .map(|i| (Arc::from(all[i * 7].as_slice()), 2usize, QueryMode::Ids))
            .collect();
        let out = engine.run_batch_blocked(&queries, 8);
        assert_eq!(out.len(), 6);
        let m = engine.metrics();
        assert_eq!(m.queries.load(Ordering::Relaxed), 6, "one record per query");
        assert_eq!(m.batches.load(Ordering::Relaxed), 1, "batch counted once");
    }

    #[test]
    fn multibst_shards_work() {
        let rows = rows(800, 93);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::MultiBst(2));
        let q = rows[0].clone();
        let mut got = engine.search(&q, 3);
        got.sort();
        assert_eq!(got, oracle(&rows, &q, 3));
        // blocked execution routes MI-bST shards through the hoisted-lock
        // path; results must be unchanged
        let queries: Vec<(Arc<[u8]>, usize, QueryMode)> = (0..6)
            .map(|i| (Arc::from(rows[i * 9].as_slice()), 3usize, QueryMode::Ids))
            .collect();
        let serial = engine.run_batch(&queries);
        let blocked = engine.run_batch_blocked(&queries, 8);
        for (s, b) in serial.iter().zip(&blocked) {
            match (s, b) {
                (QueryResult::Ids(sv), QueryResult::Ids(bv)) => {
                    let (mut sv, mut bv) = (sv.clone(), bv.clone());
                    sv.sort_unstable();
                    bv.sort_unstable();
                    assert_eq!(sv, bv);
                }
                _ => panic!("expected ids"),
            }
        }
    }

    #[test]
    fn metrics_accumulate() {
        let rows = rows(300, 94);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        for i in 0..5 {
            engine.search(&rows[i], 1);
        }
        let m = engine.metrics();
        assert_eq!(m.queries.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn concurrent_searches_are_safe() {
        let rows = rows(1000, 95);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = std::sync::Arc::new(Engine::build(
            &set,
            4,
            &ShardIndexKind::Bst(BstConfig::default()),
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let eng = std::sync::Arc::clone(&engine);
            let q = rows[t * 10].clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let hits = eng.search(&q, 2);
                    assert!(!hits.is_empty()); // at least itself
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn inserts_are_visible_and_id_ordered() {
        let all = rows(600, 81);
        let set = SketchSet::from_rows(2, 16, &all[..400]);
        for n_shards in [1usize, 3] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            let range = engine.insert_batch(&all[400..]).unwrap();
            assert_eq!(range, 400..600);
            assert_eq!(engine.n(), 600);
            let mut rng = Rng::new(82);
            for _ in 0..8 {
                let q = all[rng.below_usize(all.len())].clone();
                for tau in [0usize, 2, 4] {
                    let mut got = engine.search(&q, tau);
                    got.sort();
                    assert_eq!(got, oracle(&all, &q, tau), "shards={n_shards} tau={tau}");
                    assert_eq!(engine.count(&q, tau), got.len());
                }
            }
            assert_eq!(engine.metrics().inserts.load(Ordering::Relaxed), 200);
        }
        // single insert + bad rows rejected without assigning ids
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        let id = engine.insert(&all[0]).unwrap();
        assert_eq!(id, 400);
        assert!(engine.insert_batch(&[vec![0u8; 3]]).is_err(), "wrong length");
        assert!(engine.insert_batch(&[vec![9u8; 16]]).is_err(), "alphabet");
        assert_eq!(engine.n(), 401);
    }

    #[test]
    fn concurrent_inserts_keep_ids_unique_and_mergeable() {
        let all = rows(440, 79);
        let set = SketchSet::from_rows(2, 16, &all[..200]);
        let engine =
            Arc::new(Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default())));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let eng = Arc::clone(&engine);
            let batch: Vec<Vec<u8>> = all[200 + t * 60..200 + (t + 1) * 60].to_vec();
            handles.push(std::thread::spawn(move || {
                (t, eng.insert_batch(&batch).unwrap())
            }));
        }
        let mut ranges: Vec<(usize, std::ops::Range<u32>)> = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect();
        ranges.sort_by_key(|(_, r)| r.start);
        assert_eq!(engine.n(), 440);
        // ranges tile 200..440 without overlap, whatever the interleaving
        let mut expect_start = 200u32;
        for (_, r) in &ranges {
            assert_eq!(r.start, expect_start);
            assert_eq!(r.end - r.start, 60);
            expect_start = r.end;
        }
        // every inserted row is findable under its assigned id
        for (t, r) in &ranges {
            for (j, id) in r.clone().enumerate() {
                let row = &all[200 + t * 60 + j];
                assert!(engine.search(row, 0).contains(&id), "t={t} j={j}");
            }
        }
        // deltas stayed monotone per shard: the merge folds cleanly and
        // results are unchanged afterwards
        let before = {
            let mut v = engine.search(&all[0], 4);
            v.sort();
            v
        };
        assert_eq!(engine.merge(), MergeSummary { merged: 3, skipped: 0 });
        let after = {
            let mut v = engine.search(&all[0], 4);
            v.sort();
            v
        };
        assert_eq!(before, after);
    }

    #[test]
    fn deletes_tombstone_every_mode() {
        let all = rows(500, 83);
        let set = SketchSet::from_rows(2, 16, &all[..450]);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        engine.insert_batch(&all[450..]).unwrap();
        assert!(engine.delete(7), "base row");
        assert!(engine.delete(470), "delta row");
        assert!(!engine.delete(7), "already gone");
        assert!(!engine.delete(9999), "never existed");
        assert_eq!(engine.metrics().deletes.load(Ordering::Relaxed), 2);
        let alive = |i: usize| i != 7 && i != 470;
        for qi in [7usize, 470, 100] {
            let q = &all[qi];
            for tau in [0usize, 2, 4] {
                let mut got = engine.search(q, tau);
                got.sort();
                let expect: Vec<u32> = oracle(&all, q, tau)
                    .into_iter()
                    .filter(|&g| alive(g as usize))
                    .collect();
                assert_eq!(got, expect, "qi={qi} tau={tau}");
                assert_eq!(engine.count(q, tau), expect.len());
            }
            let got = engine.top_k(q, 5, 16);
            assert!(got.iter().all(|&(id, _)| alive(id as usize)));
        }
    }

    #[test]
    fn force_merge_and_background_merge_keep_results() {
        let all = rows(800, 85);
        let set = SketchSet::from_rows(2, 16, &all[..500]);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        // background merges: tiny threshold, batched inserts
        engine.set_merge_threshold(8);
        for chunk in all[500..].chunks(64) {
            engine.insert_batch(chunk).unwrap();
        }
        engine.delete(600);
        // whatever the background merges have/haven't finished, results
        // must equal the oracle at all times
        for tau in [0usize, 2, 4] {
            let mut got = engine.search(&all[600], tau);
            got.sort();
            let expect: Vec<u32> = oracle(&all, &all[600], tau)
                .into_iter()
                .filter(|&g| g != 600)
                .collect();
            assert_eq!(got, expect, "pre-force tau={tau}");
        }
        let summary = engine.merge();
        assert_eq!(summary, MergeSummary { merged: 3, skipped: 0 });
        for tau in [0usize, 2, 4] {
            let mut got = engine.search(&all[600], tau);
            got.sort();
            let expect: Vec<u32> = oracle(&all, &all[600], tau)
                .into_iter()
                .filter(|&g| g != 600)
                .collect();
            assert_eq!(got, expect, "post-force tau={tau}");
        }
        // a second merge sweep is clean
        assert_eq!(engine.merge(), MergeSummary { merged: 3, skipped: 0 });
    }

    #[test]
    fn save_load_roundtrip_answers_identically() {
        let rows = rows(1500, 90);
        let set = SketchSet::from_rows(2, 16, &rows);
        let dir = std::env::temp_dir().join("bst_engine_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (kind, name) in [
            (ShardIndexKind::Bst(BstConfig::default()), "bst"),
            (ShardIndexKind::MultiBst(2), "mibst"),
        ] {
            let engine = Engine::build(&set, 3, &kind);
            let path = dir.join(format!("engine_{name}.snap"));
            engine.save(&path).unwrap();

            // (the no-rebuild counter assertions live in the dedicated
            // single-test binary tests/snapshot_cold_start.rs — the
            // global counters would race with parallel sibling tests)
            let loaded = Engine::load(&path).unwrap();
            let mapped = Engine::load_with(&path, true).unwrap();
            assert_eq!(loaded.n(), engine.n());
            assert_eq!(loaded.l(), engine.l());
            assert_eq!(loaded.b(), engine.b());
            assert_eq!(loaded.n_shards(), engine.n_shards());
            assert_eq!(mapped.n(), engine.n());
            assert_eq!(mapped.n_shards(), engine.n_shards());
            // Mapped serving borrows the payload arrays, so its
            // assembly-time heap must come in strictly below owned.
            assert!(
                mapped.heap_bytes() < loaded.heap_bytes(),
                "{name}: mapped heap {} !< owned heap {}",
                mapped.heap_bytes(),
                loaded.heap_bytes()
            );
            let mut rng = Rng::new(77);
            for _ in 0..8 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 2, 4] {
                    let mut a = engine.search(&q, tau);
                    let mut b = loaded.search(&q, tau);
                    let mut m = mapped.search(&q, tau);
                    a.sort();
                    b.sort();
                    m.sort();
                    assert_eq!(a, b, "{name} tau={tau}");
                    assert_eq!(a, m, "{name} tau={tau} (mapped)");
                    assert_eq!(engine.count(&q, tau), loaded.count(&q, tau));
                    assert_eq!(engine.count(&q, tau), mapped.count(&q, tau));
                }
                assert_eq!(engine.top_k(&q, 7, 5), loaded.top_k(&q, 7, 5), "{name}");
                assert_eq!(engine.top_k(&q, 7, 5), mapped.top_k(&q, 7, 5), "{name}");
            }
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn mutated_snapshot_roundtrips_with_delta_and_tombstones() {
        let all = rows(700, 87);
        let set = SketchSet::from_rows(2, 16, &all[..500]);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        engine.insert_batch(&all[500..]).unwrap();
        engine.delete(2);
        engine.delete(650);
        let dir = std::env::temp_dir().join("bst_engine_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("engine_delta.snap");
        engine.save(&path).unwrap();
        let loaded = Engine::load(&path).unwrap();
        assert_eq!(loaded.n(), 700);
        for qi in [0usize, 500, 650] {
            for tau in [0usize, 2, 4] {
                let mut a = engine.search(&all[qi], tau);
                let mut b = loaded.search(&all[qi], tau);
                a.sort();
                b.sort();
                assert_eq!(a, b, "qi={qi} tau={tau}");
            }
            assert_eq!(engine.top_k(&all[qi], 9, 6), loaded.top_k(&all[qi], 9, 6));
        }
        // further writes keep working on the reloaded engine
        let range = loaded.insert_batch(&all[..10]).unwrap();
        assert_eq!(range, 700..710);
        assert!(loaded.delete(705));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_corrupt_and_missing() {
        let rows = rows(300, 89);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        let dir = std::env::temp_dir().join("bst_engine_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("engine_corrupt.snap");
        engine.save(&path).unwrap();

        let good = std::fs::read(&path).unwrap();
        // truncations at many points
        for cut in [0usize, 8, 40, good.len() / 2, good.len() - 3] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(Engine::load(&path).is_err(), "cut={cut}");
        }
        // flip 8 consecutive bytes mid-file: inter-section padding runs
        // are at most 7 bytes, so at least one checksummed byte flips
        let mut bad = good.clone();
        let mid = good.len() / 2;
        for b in &mut bad[mid..mid + 8] {
            *b ^= 0x10;
        }
        std::fs::write(&path, &bad).unwrap();
        assert!(Engine::load(&path).is_err());
        // missing file
        assert!(Engine::load(&dir.join("nope.snap")).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn engine_slot_swaps() {
        let rows = rows(200, 88);
        let set = SketchSet::from_rows(2, 16, &rows);
        let a = Arc::new(Engine::build(&set, 1, &ShardIndexKind::Bst(BstConfig::default())));
        let b = Arc::new(Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default())));
        let slot = EngineSlot::new(Arc::clone(&a));
        assert_eq!(slot.current().n_shards(), 1);
        let old = slot.replace(Arc::clone(&b));
        assert_eq!(old.n_shards(), 1);
        assert_eq!(slot.current().n_shards(), 2);
    }

    fn wal_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("bst_engwal_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sorted_search(e: &Engine, q: &[u8], tau: usize) -> Vec<u32> {
        let mut v = e.search(q, tau);
        v.sort_unstable();
        v
    }

    #[test]
    fn wal_replay_roundtrips_without_snapshot() {
        let all = rows(200, 110);
        let set = SketchSet::from_rows(2, 16, &all[..100]);
        let dir = wal_dir("roundtrip");
        let base = dir.join("wal");
        let kind = ShardIndexKind::Bst(BstConfig::default());
        let e1 = Engine::build(&set, 3, &kind);
        let r = e1.attach_wal(&base, WalSync::Always).unwrap();
        assert_eq!((r.replayed_inserts, r.replayed_deletes), (0, 0));
        e1.insert_batch(&all[100..]).unwrap();
        assert!(e1.delete(5));
        assert!(e1.delete(150));
        e1.merge(); // writes a marker record; replay must ignore it
        let expect: Vec<Vec<u32>> =
            (0..4).map(|tau| sorted_search(&e1, &all[0], tau)).collect();
        drop(e1);

        // A second engine over the same base rows recovers every
        // acknowledged write from the log alone.
        let e2 = Engine::build(&set, 3, &kind);
        let r = e2.attach_wal(&base, WalSync::Always).unwrap();
        assert_eq!(r.replayed_inserts, 100);
        assert_eq!(r.replayed_deletes, 2);
        assert_eq!(e2.n(), 200);
        for (tau, want) in expect.iter().enumerate() {
            assert_eq!(&sorted_search(&e2, &all[0], tau), want, "tau={tau}");
        }
        // replayed rows keep their ids; new writes continue past them
        assert_eq!(e2.insert_batch(&all[..4]).unwrap(), 200..204);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_rotates_wal_and_reload_replays_nothing() {
        let all = rows(260, 111);
        let set = SketchSet::from_rows(2, 16, &all[..130]);
        let dir = wal_dir("rotate");
        let (base, snap) = (dir.join("wal"), dir.join("engine.snap"));
        let kind = ShardIndexKind::Bst(BstConfig::default());
        let e1 = Engine::build(&set, 3, &kind);
        e1.attach_wal(&base, WalSync::Always).unwrap();
        e1.insert_batch(&all[130..]).unwrap();
        e1.delete(7);
        e1.save(&snap).unwrap();
        // post-save writes land in the rotated segment only
        assert!(e1.delete(200));
        let expect = sorted_search(&e1, &all[0], 4);
        drop(e1);

        let e2 = Engine::load(&snap).unwrap();
        let r = e2.attach_wal(&base, WalSync::Always).unwrap();
        assert_eq!(r.replayed_inserts, 0, "snapshot already covers the inserts");
        assert_eq!(r.replayed_deletes, 1, "only the post-save delete replays");
        assert_eq!(r.skipped_records, 0, "rotation deleted the old segments");
        assert_eq!(sorted_search(&e2, &all[0], 4), expect);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn worker_panic_restarts_and_rejoins() {
        use crate::util::failpoint::{self, Action};
        let all = rows(300, 112);
        let set = SketchSet::from_rows(2, 16, &all[..200]);
        let dir = wal_dir("panic");
        let (base, snap) = (dir.join("wal"), dir.join("engine.snap"));
        let kind = ShardIndexKind::Bst(BstConfig::default());
        Engine::build(&set, 3, &kind).save(&snap).unwrap();

        let e = Engine::load(&snap).unwrap();
        e.attach_wal(&base, WalSync::Always).unwrap();
        e.insert_batch(&all[200..]).unwrap();
        e.delete(4);
        e.delete(250);

        // Panic shard 1 on its next message; the supervisor must
        // rebuild it from snapshot + WAL while shards 0/2 keep serving.
        let filter = format!("{}/shard-1", e.instance_tag());
        failpoint::arm_scoped("shard.worker", &filter, 0, 1, Action::Panic);
        let _ = e.search(&all[0], 2); // sacrificial query trips the panic
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while e.metrics().worker_restarts.load(Ordering::Relaxed) == 0 {
            assert!(std::time::Instant::now() < deadline, "restart never happened");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        failpoint::clear("shard.worker");

        // The restarted shard answers from rebuilt state: snapshot base
        // rows + WAL-replayed inserts and tombstones.
        let alive = |g: u32| g != 4 && g != 250;
        for qi in [0usize, 210, 250] {
            for tau in [0usize, 2, 4] {
                let got = sorted_search(&e, &all[qi], tau);
                let want: Vec<u32> = oracle(&all, &all[qi], tau)
                    .into_iter()
                    .filter(|&g| alive(g))
                    .collect();
                assert_eq!(got, want, "qi={qi} tau={tau}");
            }
        }
        // and the shard accepts fresh writes
        let range = e.insert_batch(&all[..6]).unwrap();
        assert_eq!(range, 300..306);
        assert!(e.search(&all[0], 0).contains(&300));
        assert_eq!(e.metrics().worker_restarts.load(Ordering::Relaxed), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dead_shard_fails_queries_instead_of_hanging() {
        use crate::util::failpoint::{self, Action};
        let all = rows(200, 113);
        let set = SketchSet::from_rows(2, 16, &all);
        // Built, never saved: no recovery source, so a panicked shard
        // goes dead — queries must fail, not hang, and the other shards
        // must keep answering.
        let e = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let filter = format!("{}/shard-2", e.instance_tag());
        failpoint::arm_scoped("shard.worker", &filter, 0, 1, Action::Panic);
        let _ = e.search(&all[0], 0);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while e.metrics().worker_restarts.load(Ordering::Relaxed) == 0 {
            assert!(std::time::Instant::now() < deadline, "panic never registered");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        failpoint::clear("shard.worker");
        let q: Arc<[u8]> = Arc::from(all[0].as_slice());
        let out = e.run_batch(&[(Arc::clone(&q), 2, QueryMode::Ids)]);
        assert_eq!(out, vec![QueryResult::Failed]);
        let out = e.run_batch_blocked(
            &[(Arc::clone(&q), 2, QueryMode::Count), (q, 2, QueryMode::Count)],
            8,
        );
        assert_eq!(out, vec![QueryResult::Failed, QueryResult::Failed]);
        assert!(e.insert_batch(&all[..2]).is_err(), "writes report failure");
        // dropping the engine shuts the dead shard's drain loop down
        drop(e);
    }

    #[test]
    fn query_spec_routes_all_modes() {
        let rows = rows(600, 120);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let q = &rows[3];
        for tau in [0usize, 2, 4] {
            let mut ids = match engine.query(&QuerySpec::ids(q, tau)) {
                QueryResult::Ids(v) => v,
                other => panic!("expected ids, got {other:?}"),
            };
            ids.sort_unstable();
            assert_eq!(ids, oracle(&rows, q, tau), "tau={tau}");
            assert_eq!(
                engine.query(&QuerySpec::count(q, tau)),
                QueryResult::Count(engine.count(q, tau)),
                "tau={tau}"
            );
        }
        assert_eq!(
            engine.query(&QuerySpec::top_k(q, 7, 5)),
            QueryResult::TopK(engine.top_k(q, 7, 5))
        );
    }

    #[test]
    fn apply_replicated_mirrors_and_is_idempotent() {
        let all = rows(300, 121);
        let set = SketchSet::from_rows(2, 16, &all[..200]);
        let dir = wal_dir("replapply");
        let base = dir.join("wal");
        let kind = ShardIndexKind::Bst(BstConfig::default());
        let primary = Engine::build(&set, 3, &kind);
        primary.attach_wal(&base, WalSync::Always).unwrap();
        primary.insert_batch(&all[200..]).unwrap();
        assert!(primary.delete(7));
        assert!(primary.delete(250));
        let records = wal::read_records(&base).unwrap();

        // A follower applies the shipped records and answers like the
        // primary.
        let follower = Engine::build(&set, 3, &kind);
        let rep = follower.apply_replicated(records.clone()).unwrap();
        assert_eq!(rep.replayed_inserts, 100);
        assert_eq!(rep.replayed_deletes, 2);
        assert_eq!(follower.n(), primary.n());
        // Re-fetching an overlapping span (reconnect) converges: the
        // insert skips below the high-water mark, deletes re-tombstone.
        let rep = follower.apply_replicated(records).unwrap();
        assert_eq!(rep.replayed_inserts, 0);
        assert_eq!(rep.skipped_records, 1);
        for qi in [0usize, 7, 250] {
            for tau in [0usize, 2, 4] {
                assert_eq!(
                    sorted_search(&follower, &all[qi], tau),
                    sorted_search(&primary, &all[qi], tau),
                    "qi={qi} tau={tau}"
                );
            }
            assert_eq!(follower.top_k(&all[qi], 9, 8), primary.top_k(&all[qi], 9, 8));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_with_cursor_reports_the_rotated_frontier() {
        let all = rows(120, 122);
        let set = SketchSet::from_rows(2, 16, &all[..100]);
        let dir = wal_dir("savecursor");
        let (base, snap) = (dir.join("wal"), dir.join("engine.snap"));
        let e = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        assert_eq!(e.save_with_cursor(&snap).unwrap(), None, "no wal attached");
        assert_eq!(e.wal_base(), None);
        e.attach_wal(&base, WalSync::Always).unwrap();
        e.insert_batch(&all[100..]).unwrap();
        let cur = e.save_with_cursor(&snap).unwrap().expect("wal attached");
        assert_eq!(cur, WalCursor { seq: 1, off: 0 }, "fresh post-rotation segment");
        assert_eq!(e.wal_base().as_deref(), Some(base.as_path()));
        // Records appended after the save are exactly what a fetch from
        // the cursor returns — the replica bootstrap contract.
        assert!(e.delete(5));
        let got = match wal::fetch_frames(&base, cur, 1 << 20, e.durable_frontier()).unwrap() {
            wal::WalFetch::Chunk(c) => wal::scan_frames(&c.frames).0,
            wal::WalFetch::Gap => panic!("cursor from save must stay fetchable"),
        };
        assert_eq!(got, vec![WalRecord::Delete { id: 5 }]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repeated_panics_park_the_shard() {
        use crate::util::failpoint::{self, Action};
        let all = rows(200, 123);
        let set = SketchSet::from_rows(2, 16, &all[..150]);
        let dir = wal_dir("park");
        let (base, snap) = (dir.join("wal"), dir.join("engine.snap"));
        let kind = ShardIndexKind::Bst(BstConfig::default());
        Engine::build(&set, 2, &kind).save(&snap).unwrap();
        let e = Engine::load(&snap).unwrap();
        e.attach_wal(&base, WalSync::Always).unwrap();
        // A deterministic poison pill: every message to shard 1 panics.
        // The supervisor rebuilds with backoff, then parks the shard
        // once it exhausts its restart budget.
        let filter = format!("{}/shard-1", e.instance_tag());
        failpoint::arm_scoped("shard.worker", &filter, 0, 1_000_000, Action::Panic);
        let q: Arc<[u8]> = Arc::from(all[0].as_slice());
        let deadline = Instant::now() + Duration::from_secs(60);
        while e.metrics().shards_parked.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "shard never parked");
            let _ = e.run_batch(&[(Arc::clone(&q), 0, QueryMode::Ids)]);
            std::thread::sleep(Duration::from_millis(10));
        }
        failpoint::clear("shard.worker");
        assert!(
            e.metrics().worker_restarts.load(Ordering::Relaxed)
                > MAX_REBUILDS_PER_WINDOW as u64
        );
        // Parked: queries fail fast instead of looping rebuilds.
        let out = e.run_batch(&[(q, 0, QueryMode::Ids)]);
        assert_eq!(out, vec![QueryResult::Failed]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
