//! Sharded query engine.
//!
//! The database is striped into `S` contiguous shards; each shard worker
//! thread owns one index (any [`SearchIndex`]) over its stripe plus one
//! persistent [`QueryCtx`] — the per-worker scratch pool that makes the
//! per-shard hot path allocation-free after warm-up. A query fans out to
//! all shards as one shared `Arc<[u8]>` (no per-shard copies) and merges
//! results with the global id offsets.
//!
//! Three query modes ride the same fan-out machinery: id collection
//! ([`Engine::search`] / [`Engine::search_batch`]), counting
//! ([`Engine::count`]) and top-k nearest neighbors ([`Engine::top_k`],
//! merged globally by `(dist, id)`).
//!
//! Shard workers are persistent (channel-fed) rather than spawned per
//! query — fan-out latency is two channel hops, and the workers give the
//! natural place for per-shard pinning or NUMA placement at larger scale.

use super::metrics::Metrics;
use crate::index::SearchIndex;
use crate::query::{CollectIds, CountOnly, QueryCtx, TopK};
use crate::sketch::SketchSet;
use crate::trie::bst::BstConfig;
use crate::util::timer::Timer;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// How a fanned-out query collects results on each shard.
#[derive(Debug, Clone, Copy)]
pub enum QueryMode {
    /// Collect matching ids (classic threshold search).
    Ids,
    /// Count matches only.
    Count,
    /// Per-shard top-k by `(dist, id)`; merged globally by the caller.
    TopK(usize),
}

/// One shard's result payload.
pub enum ShardReply {
    Ids(Vec<u32>),
    Count(usize),
    TopK(Vec<(u32, usize)>),
}

enum ShardMsg {
    Query {
        q: Arc<[u8]>,
        tau: usize,
        mode: QueryMode,
        reply: Sender<(usize, ShardReply)>,
        shard_no: usize,
    },
    Shutdown,
}

struct Shard {
    tx: Sender<ShardMsg>,
    handle: Option<JoinHandle<()>>,
    offset: u32,
}

/// Builder: which index each shard uses.
pub enum ShardIndexKind {
    /// SI-bST (default).
    Bst(BstConfig),
    /// MI-bST with `m` blocks.
    MultiBst(usize),
}

/// The sharded engine.
pub struct Engine {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    l: usize,
    n: usize,
    heap_bytes: usize,
}

impl Engine {
    /// Stripes `set` over `n_shards` shards and builds per-shard indexes
    /// in parallel.
    pub fn build(set: &SketchSet, n_shards: usize, kind: &ShardIndexKind) -> Self {
        let n = set.n();
        let n_shards = n_shards.clamp(1, n.max(1));
        let per = n.div_ceil(n_shards);
        let metrics = Arc::new(Metrics::new());

        let mut shards = Vec::with_capacity(n_shards);
        let mut heap_bytes = 0usize;
        // Build indexes in parallel with scoped threads, then move each
        // into its worker thread.
        let stripes: Vec<(u32, SketchSet)> = (0..n_shards)
            .map(|s| {
                let lo = s * per;
                let hi = ((s + 1) * per).min(n);
                let mut stripe = SketchSet::zeros(set.b(), set.l(), hi - lo);
                for i in lo..hi {
                    for p in 0..set.l() {
                        stripe.set_char(i - lo, p, set.get_char(i, p));
                    }
                }
                (lo as u32, stripe)
            })
            .collect();

        let built: Vec<(u32, Box<dyn SearchIndex + Send + Sync>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = stripes
                .into_iter()
                .map(|(offset, stripe)| {
                    scope.spawn(move || {
                        let index: Box<dyn SearchIndex + Send + Sync> = match kind {
                            ShardIndexKind::Bst(cfg) => {
                                Box::new(crate::index::SingleBst::build(&stripe, *cfg))
                            }
                            ShardIndexKind::MultiBst(m) => {
                                Box::new(crate::index::MultiBst::build(&stripe, *m))
                            }
                        };
                        (offset, index)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard build")).collect()
        });

        for (offset, index) in built {
            heap_bytes += index.heap_bytes();
            let (tx, rx) = channel::<ShardMsg>();
            let handle = std::thread::Builder::new()
                .name(format!("bst-shard-{offset}"))
                .spawn(move || {
                    // One QueryCtx per worker: scratch buffers are warmed
                    // by the first query and reused for the shard's
                    // lifetime (the pooling layer of the query refactor).
                    let mut qctx = QueryCtx::new();
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ShardMsg::Query { q, tau, mode, reply, shard_no } => {
                                let result = match mode {
                                    QueryMode::Ids => {
                                        let mut hits = Vec::new();
                                        let mut coll = CollectIds::new(tau, &mut hits);
                                        index.run(&q, &mut qctx, &mut coll);
                                        ShardReply::Ids(hits)
                                    }
                                    QueryMode::Count => {
                                        let mut coll = CountOnly::new(tau);
                                        index.run(&q, &mut qctx, &mut coll);
                                        ShardReply::Count(coll.count())
                                    }
                                    QueryMode::TopK(k) => {
                                        let mut coll = TopK::new(k, tau);
                                        index.run(&q, &mut qctx, &mut coll);
                                        ShardReply::TopK(coll.finish())
                                    }
                                };
                                let _ = reply.send((shard_no, result));
                            }
                            ShardMsg::Shutdown => break,
                        }
                    }
                })
                .expect("spawn shard worker");
            shards.push(Shard { tx, handle: Some(handle), offset });
        }

        Engine { shards, metrics, l: set.l(), n, heap_bytes }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn l(&self) -> usize {
        self.l
    }

    pub fn heap_bytes(&self) -> usize {
        self.heap_bytes
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Enqueues `q` on every shard; the query bytes are shared via one
    /// `Arc` clone per shard, never copied.
    fn fan_out(
        &self,
        q: &Arc<[u8]>,
        tau: usize,
        mode: QueryMode,
        reply_tx: &Sender<(usize, ShardReply)>,
    ) {
        for (no, shard) in self.shards.iter().enumerate() {
            shard
                .tx
                .send(ShardMsg::Query {
                    q: Arc::clone(q),
                    tau,
                    mode,
                    reply: reply_tx.clone(),
                    shard_no: no,
                })
                .expect("shard worker alive");
        }
    }

    /// Fans a query out to every shard and merges global ids.
    pub fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        assert_eq!(q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let q: Arc<[u8]> = Arc::from(q);
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&q, tau, QueryMode::Ids, &reply_tx);
        drop(reply_tx);
        let mut out = Vec::new();
        for (shard_no, reply) in reply_rx {
            if let ShardReply::Ids(hits) = reply {
                let offset = self.shards[shard_no].offset;
                out.extend(hits.into_iter().map(|id| id + offset));
            }
        }
        self.metrics.record_query(timer.elapsed_us() as u64, out.len());
        out
    }

    /// Counts matches across all shards.
    pub fn count(&self, q: &[u8], tau: usize) -> usize {
        assert_eq!(q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let q: Arc<[u8]> = Arc::from(q);
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&q, tau, QueryMode::Count, &reply_tx);
        drop(reply_tx);
        let mut total = 0usize;
        for (_no, reply) in reply_rx {
            if let ShardReply::Count(n) = reply {
                total += n;
            }
        }
        self.metrics.record_query(timer.elapsed_us() as u64, total);
        total
    }

    /// Global top-k within radius `tau`: each shard answers its local
    /// top-k, merged here by `(dist, global id)` — within a shard the
    /// local-id order equals the global-id order (offsets are monotone),
    /// so the merge is exact. Returns `(id, dist)` pairs.
    pub fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        assert_eq!(q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let q: Arc<[u8]> = Arc::from(q);
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&q, tau, QueryMode::TopK(k), &reply_tx);
        drop(reply_tx);
        let mut all: Vec<(usize, u32)> = Vec::new();
        for (shard_no, reply) in reply_rx {
            if let ShardReply::TopK(hits) = reply {
                let offset = self.shards[shard_no].offset;
                all.extend(hits.into_iter().map(|(id, d)| (d, id + offset)));
            }
        }
        all.sort_unstable();
        all.truncate(k);
        self.metrics.record_query(timer.elapsed_us() as u64, all.len());
        all.into_iter().map(|(d, id)| (id, d)).collect()
    }

    /// Executes a batch of queries as one pipelined fan-out round (the
    /// batcher's entry point). All queries are enqueued on every shard
    /// *before* any result is collected, so the batch completes in
    /// (slowest shard's queue) time rather than Σ per-query latencies —
    /// see EXPERIMENTS.md §Perf for the before/after. Queries arrive as
    /// `Arc<[u8]>` and are shared, not cloned, across shard messages.
    pub fn search_batch(&self, queries: &[(Arc<[u8]>, usize)]) -> Vec<Vec<u32>> {
        self.metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Phase 1: fan out everything, stamping each query's own start so
        // latency metrics reflect real per-query wall time (an even split
        // of the batch total would hide stragglers).
        let pending: Vec<_> = queries
            .iter()
            .map(|(q, tau)| {
                let timer = Timer::start();
                let (reply_tx, reply_rx) = channel();
                self.fan_out(q, *tau, QueryMode::Ids, &reply_tx);
                (timer, reply_rx)
            })
            .collect();
        // Phase 2: collect in request order; each query's latency is
        // measured from its fan-out to the receipt of its last shard
        // reply.
        let n_shards = self.shards.len();
        pending
            .into_iter()
            .map(|(timer, rx)| {
                let mut merged = Vec::new();
                for _ in 0..n_shards {
                    let (shard_no, reply) = rx.recv().expect("shard reply");
                    if let ShardReply::Ids(hits) = reply {
                        let offset = self.shards[shard_no].offset;
                        merged.extend(hits.into_iter().map(|id| id + offset));
                    }
                }
                self.metrics.record_query(timer.elapsed_us() as u64, merged.len());
                merged
            })
            .collect()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn rows(n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut r = centers[rng.below_usize(8)].clone();
                for _ in 0..rng.below_usize(4) {
                    let p = rng.below_usize(16);
                    r[p] = rng.below(4) as u8;
                }
                r
            })
            .collect()
    }

    #[test]
    fn sharded_equals_unsharded() {
        let rows = rows(2000, 91);
        let set = SketchSet::from_rows(2, 16, &rows);
        for n_shards in [1usize, 3, 8] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            assert_eq!(engine.n_shards(), n_shards);
            let mut rng = Rng::new(92);
            for _ in 0..10 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 2, 4] {
                    let mut got = engine.search(&q, tau);
                    got.sort();
                    let expect: Vec<u32> = (0..rows.len())
                        .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                        .map(|i| i as u32)
                        .collect();
                    assert_eq!(got, expect, "shards={n_shards} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn count_and_topk_agree_with_search() {
        let rows = rows(1200, 96);
        let set = SketchSet::from_rows(2, 16, &rows);
        for n_shards in [1usize, 4] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            for qi in [0usize, 7, 400] {
                let q = &rows[qi];
                for tau in [0usize, 2, 4] {
                    assert_eq!(
                        engine.count(q, tau),
                        engine.search(q, tau).len(),
                        "shards={n_shards} tau={tau}"
                    );
                }
                // top-k equals globally sorted brute force by (dist, id)
                let tau = 4usize;
                let mut all: Vec<(usize, u32)> = (0..rows.len())
                    .map(|i| (ham_chars(&rows[i], q), i as u32))
                    .filter(|&(d, _)| d <= tau)
                    .collect();
                all.sort_unstable();
                for k in [1usize, 10, 1000] {
                    let got = engine.top_k(q, k, tau);
                    let expect: Vec<(u32, usize)> =
                        all.iter().take(k).map(|&(d, id)| (id, d)).collect();
                    assert_eq!(got, expect, "shards={n_shards} k={k}");
                }
            }
        }
    }

    #[test]
    fn batch_matches_sequential_and_records_per_query_latency() {
        let rows = rows(900, 97);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let queries: Vec<(Arc<[u8]>, usize)> = (0..8)
            .map(|i| (Arc::from(rows[i * 37].as_slice()), i % 4))
            .collect();
        let batch = engine.search_batch(&queries);
        assert_eq!(batch.len(), queries.len());
        for ((q, tau), got) in queries.iter().zip(&batch) {
            let mut got = got.clone();
            got.sort();
            let mut expect = engine.search(q, *tau);
            expect.sort();
            assert_eq!(got, expect);
        }
        // one metrics record per query (batch counted once)
        let m = engine.metrics();
        assert_eq!(
            m.queries.load(std::sync::atomic::Ordering::Relaxed),
            (queries.len() * 2) as u64
        );
        assert_eq!(m.batches.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn multibst_shards_work() {
        let rows = rows(800, 93);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::MultiBst(2));
        let q = rows[0].clone();
        let mut got = engine.search(&q, 3);
        got.sort();
        let expect: Vec<u32> = (0..rows.len())
            .filter(|&i| ham_chars(&rows[i], &q) <= 3)
            .map(|i| i as u32)
            .collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn metrics_accumulate() {
        let rows = rows(300, 94);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        for i in 0..5 {
            engine.search(&rows[i], 1);
        }
        let m = engine.metrics();
        assert_eq!(m.queries.load(std::sync::atomic::Ordering::Relaxed), 5);
    }

    #[test]
    fn concurrent_searches_are_safe() {
        let rows = rows(1000, 95);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = std::sync::Arc::new(Engine::build(
            &set,
            4,
            &ShardIndexKind::Bst(BstConfig::default()),
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let eng = std::sync::Arc::clone(&engine);
            let q = rows[t * 10].clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let hits = eng.search(&q, 2);
                    assert!(!hits.is_empty()); // at least itself
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
