//! Sharded query engine.
//!
//! The database is striped into `S` contiguous shards; each shard worker
//! thread owns one index (a [`ShardIndex`]) over its stripe plus one
//! persistent [`QueryCtx`] — the per-worker scratch pool that makes the
//! per-shard hot path allocation-free after warm-up (including the top-k
//! heap, parked in the ctx between queries). A query fans out to all
//! shards as one shared `Arc<[u8]>` (no per-shard copies) and merges
//! results with the global id offsets.
//!
//! Three query modes ride the same fan-out machinery: id collection
//! ([`Engine::search`] / [`Engine::run_batch`]), counting
//! ([`Engine::count`]) and top-k nearest neighbors ([`Engine::top_k`],
//! merged globally by `(dist, id)`). [`Engine::run_batch`] executes a
//! mixed-mode batch as one pipelined fan-out round — the batcher routes
//! *all three* modes through it, so every served query records real
//! per-query wall time.
//!
//! **Persistence** ([`Engine::save`] / [`Engine::load`]): the engine
//! writes one snapshot (see [`crate::store`]) with a `meta` section
//! (sketch length, database size, shard offsets) and one `shard.N`
//! section per shard. Loading validates the container and reconstructs
//! the workers directly from the serialized structures — it never
//! re-runs `SortedSketches::build`, sorts anything, or rebuilds a
//! rank/select directory. Build once, serve many, restart in seconds.
//!
//! Shard workers are persistent (channel-fed) rather than spawned per
//! query — fan-out latency is two channel hops, and the workers give the
//! natural place for per-shard pinning or NUMA placement at larger scale.

use super::metrics::Metrics;
use crate::index::{MultiBst, SearchIndex, SingleBst};
use crate::query::{CollectIds, Collector, CountOnly, QueryCtx};
use crate::sketch::SketchSet;
use crate::store::{
    ensure, from_payload, to_payload, ByteReader, ByteWriter, Persist, Snapshot,
    SnapshotStreamWriter, StoreError,
};
use crate::trie::bst::BstConfig;
use crate::util::timer::Timer;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

/// How a fanned-out query collects results on each shard.
#[derive(Debug, Clone, Copy)]
pub enum QueryMode {
    /// Collect matching ids (classic threshold search).
    Ids,
    /// Count matches only.
    Count,
    /// Per-shard top-k by `(dist, id)`; merged globally by the caller.
    TopK(usize),
}

/// One shard's result payload.
pub enum ShardReply {
    Ids(Vec<u32>),
    Count(usize),
    TopK(Vec<(u32, usize)>),
}

/// A globally merged query result (one per batch entry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryResult {
    Ids(Vec<u32>),
    Count(usize),
    TopK(Vec<(u32, usize)>),
}

enum ShardMsg {
    Query {
        q: Arc<[u8]>,
        tau: usize,
        mode: QueryMode,
        reply: Sender<(usize, ShardReply)>,
        shard_no: usize,
    },
    Shutdown,
}

struct Shard {
    tx: Sender<ShardMsg>,
    handle: Option<JoinHandle<()>>,
    offset: u32,
    /// Shared with the worker thread; kept here so `save` can serialize
    /// the live structures without a rebuild.
    index: Arc<ShardIndex>,
}

/// Builder: which index each shard uses.
pub enum ShardIndexKind {
    /// SI-bST (default).
    Bst(BstConfig),
    /// MI-bST with `m` blocks.
    MultiBst(usize),
}

/// A shard's index, concretely tagged so snapshots can restore it. All
/// variants answer queries through [`SearchIndex`].
pub enum ShardIndex {
    Bst(SingleBst),
    MultiBst(MultiBst),
}

impl ShardIndex {
    /// Rows in this shard's stripe.
    fn n_rows(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.trie().post_id_count(),
            ShardIndex::MultiBst(idx) => idx.n(),
        }
    }

    /// Sketch length the shard serves.
    fn l(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.trie().sketch_len(),
            ShardIndex::MultiBst(idx) => idx.l(),
        }
    }
}

impl SearchIndex for ShardIndex {
    fn run(&self, q: &[u8], ctx: &mut QueryCtx, c: &mut dyn Collector) {
        match self {
            ShardIndex::Bst(idx) => idx.run(q, ctx, c),
            ShardIndex::MultiBst(idx) => idx.run(q, ctx, c),
        }
    }

    fn heap_bytes(&self) -> usize {
        match self {
            ShardIndex::Bst(idx) => idx.heap_bytes(),
            ShardIndex::MultiBst(idx) => SearchIndex::heap_bytes(idx),
        }
    }

    fn name(&self) -> String {
        match self {
            ShardIndex::Bst(idx) => idx.name(),
            ShardIndex::MultiBst(idx) => SearchIndex::name(idx),
        }
    }
}

impl Persist for ShardIndex {
    fn write_into(&self, w: &mut ByteWriter) {
        match self {
            ShardIndex::Bst(idx) => {
                w.put_u8(0);
                idx.write_into(w);
            }
            ShardIndex::MultiBst(idx) => {
                w.put_u8(1);
                idx.write_into(w);
            }
        }
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        match r.get_u8()? {
            0 => Ok(ShardIndex::Bst(SingleBst::read_from(r)?)),
            1 => Ok(ShardIndex::MultiBst(MultiBst::read_from(r)?)),
            t => Err(StoreError::Corrupt(format!("shard index: unknown kind tag {t}"))),
        }
    }
}

/// The sharded engine.
pub struct Engine {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    l: usize,
    n: usize,
    heap_bytes: usize,
}

impl Engine {
    /// Most shards an engine will build or load — keeps `save`/`load`
    /// symmetric (anything `build` produces, `load` accepts) and bounds
    /// the allocation a corrupt snapshot header can request.
    pub const MAX_SHARDS: usize = 65_536;

    /// Stripes `set` over `n_shards` shards and builds per-shard indexes
    /// in parallel.
    pub fn build(set: &SketchSet, n_shards: usize, kind: &ShardIndexKind) -> Self {
        let n = set.n();
        let n_shards = n_shards.clamp(1, n.max(1)).min(Self::MAX_SHARDS);
        let per = n.div_ceil(n_shards);

        // Build indexes in parallel with scoped threads, then move each
        // into its worker thread.
        let stripes: Vec<(u32, SketchSet)> = (0..n_shards)
            .map(|s| {
                let lo = s * per;
                let hi = ((s + 1) * per).min(n);
                let mut stripe = SketchSet::zeros(set.b(), set.l(), hi - lo);
                for i in lo..hi {
                    for p in 0..set.l() {
                        stripe.set_char(i - lo, p, set.get_char(i, p));
                    }
                }
                (lo as u32, stripe)
            })
            .collect();

        let built: Vec<(u32, Arc<ShardIndex>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = stripes
                .into_iter()
                .map(|(offset, stripe)| {
                    scope.spawn(move || {
                        let index = match kind {
                            ShardIndexKind::Bst(cfg) => {
                                ShardIndex::Bst(SingleBst::build(&stripe, *cfg))
                            }
                            ShardIndexKind::MultiBst(m) => {
                                ShardIndex::MultiBst(MultiBst::build(&stripe, *m))
                            }
                        };
                        (offset, Arc::new(index))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard build")).collect()
        });

        Engine::assemble(set.l(), n, built)
    }

    /// Spawns the shard workers over already-built (or loaded) indexes.
    fn assemble(l: usize, n: usize, parts: Vec<(u32, Arc<ShardIndex>)>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let mut shards = Vec::with_capacity(parts.len());
        let mut heap_bytes = 0usize;
        for (offset, index) in parts {
            heap_bytes += index.heap_bytes();
            let (tx, rx) = channel::<ShardMsg>();
            let worker_index = Arc::clone(&index);
            let handle = std::thread::Builder::new()
                .name(format!("bst-shard-{offset}"))
                .spawn(move || {
                    // One QueryCtx per worker: scratch buffers (including
                    // the parked top-k heap) are warmed by the first query
                    // and reused for the shard's lifetime.
                    let mut qctx = QueryCtx::new();
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ShardMsg::Query { q, tau, mode, reply, shard_no } => {
                                let result = match mode {
                                    QueryMode::Ids => {
                                        let mut hits = Vec::new();
                                        let mut coll = CollectIds::new(tau, &mut hits);
                                        worker_index.run(&q, &mut qctx, &mut coll);
                                        ShardReply::Ids(hits)
                                    }
                                    QueryMode::Count => {
                                        let mut coll = CountOnly::new(tau);
                                        worker_index.run(&q, &mut qctx, &mut coll);
                                        ShardReply::Count(coll.count())
                                    }
                                    QueryMode::TopK(k) => {
                                        let mut hits = Vec::new();
                                        worker_index.top_k_into(&q, k, tau, &mut qctx, &mut hits);
                                        ShardReply::TopK(hits)
                                    }
                                };
                                let _ = reply.send((shard_no, result));
                            }
                            ShardMsg::Shutdown => break,
                        }
                    }
                })
                .expect("spawn shard worker");
            shards.push(Shard { tx, handle: Some(handle), offset, index });
        }

        Engine { shards, metrics, l, n, heap_bytes }
    }

    /// Writes a snapshot: one `meta` section plus one `shard.N` section
    /// per shard (see [`crate::store::container`] for the file format).
    /// Shards are serialized and streamed one at a time, so saving a
    /// large engine never holds more than one shard's payload beyond the
    /// resident structures.
    pub fn save(&self, path: &Path) -> Result<(), StoreError> {
        let mut out = SnapshotStreamWriter::create(path, 1 + self.shards.len())?;
        let mut w = ByteWriter::new();
        w.put_usize(self.l);
        w.put_usize(self.n);
        w.put_usize(self.shards.len());
        for s in &self.shards {
            w.put_u64(s.offset as u64);
        }
        out.add_section("meta", &w.into_bytes())?;
        for (i, s) in self.shards.iter().enumerate() {
            out.add_section(&format!("shard.{i}"), &to_payload(&*s.index))?;
        }
        out.finish()
    }

    /// Restores an engine from a snapshot and spawns its workers. The
    /// load path is parse + validate only: no sorting, no trie
    /// construction, no rank/select re-indexing.
    pub fn load(path: &Path) -> Result<Self, StoreError> {
        let snap = Snapshot::open(path)?;
        let mut r = snap.section("meta")?;
        let l = r.get_usize()?;
        let n = r.get_usize()?;
        let n_shards = r.get_usize()?;
        ensure(l >= 1 && (1..=Self::MAX_SHARDS).contains(&n_shards), || {
            format!("engine meta: bad shape L={l} shards={n_shards}")
        })?;
        let mut offsets = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let o = r.get_u64()?;
            offsets.push(u32::try_from(o).map_err(|_| {
                StoreError::Corrupt(format!("engine meta: shard offset {o} exceeds u32"))
            })?);
        }
        r.expect_end()?;

        let mut parts = Vec::with_capacity(n_shards);
        let mut covered = 0usize;
        for (i, &offset) in offsets.iter().enumerate() {
            let mut sr = snap.section(&format!("shard.{i}"))?;
            let index: ShardIndex = from_payload(&mut sr)?;
            ensure(offset as usize == covered, || {
                format!("engine meta: shard {i} offset {offset} does not tile (expected {covered})")
            })?;
            ensure(index.l() == l, || {
                format!("shard {i}: sketch length {} != engine L={l}", index.l())
            })?;
            // Bound local ids by the stripe size: the merge paths compute
            // `id + offset`, so out-of-range ids from a crafted shard
            // must be rejected here, not wrap at query time. (MI-bST
            // shards bound their ids inside MultiIndex::read_from.)
            if let ShardIndex::Bst(idx) = &index {
                ensure(
                    idx.trie()
                        .max_posting()
                        .map_or(true, |m| (m as usize) < index.n_rows()),
                    || format!("shard {i}: posting ids exceed the stripe size"),
                )?;
            }
            covered += index.n_rows();
            parts.push((offset, Arc::new(index)));
        }
        ensure(covered == n, || {
            format!("engine meta: shards cover {covered} rows, expected n={n}")
        })?;
        Ok(Engine::assemble(l, n, parts))
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn l(&self) -> usize {
        self.l
    }

    pub fn heap_bytes(&self) -> usize {
        self.heap_bytes
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Enqueues `q` on every shard; the query bytes are shared via one
    /// `Arc` clone per shard, never copied.
    fn fan_out(
        &self,
        q: &Arc<[u8]>,
        tau: usize,
        mode: QueryMode,
        reply_tx: &Sender<(usize, ShardReply)>,
    ) {
        for (no, shard) in self.shards.iter().enumerate() {
            shard
                .tx
                .send(ShardMsg::Query {
                    q: Arc::clone(q),
                    tau,
                    mode,
                    reply: reply_tx.clone(),
                    shard_no: no,
                })
                .expect("shard worker alive");
        }
    }

    /// Fans a query out to every shard and merges global ids.
    pub fn search(&self, q: &[u8], tau: usize) -> Vec<u32> {
        assert_eq!(q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let q: Arc<[u8]> = Arc::from(q);
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&q, tau, QueryMode::Ids, &reply_tx);
        drop(reply_tx);
        let mut out = Vec::new();
        for (shard_no, reply) in reply_rx {
            if let ShardReply::Ids(hits) = reply {
                let offset = self.shards[shard_no].offset;
                out.extend(hits.into_iter().map(|id| id + offset));
            }
        }
        self.metrics.record_query(timer.elapsed_us() as u64, out.len());
        out
    }

    /// Counts matches across all shards.
    pub fn count(&self, q: &[u8], tau: usize) -> usize {
        assert_eq!(q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let q: Arc<[u8]> = Arc::from(q);
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&q, tau, QueryMode::Count, &reply_tx);
        drop(reply_tx);
        let mut total = 0usize;
        for (_no, reply) in reply_rx {
            if let ShardReply::Count(n) = reply {
                total += n;
            }
        }
        self.metrics.record_query(timer.elapsed_us() as u64, total);
        total
    }

    /// Global top-k within radius `tau`: each shard answers its local
    /// top-k, merged here by `(dist, global id)` — within a shard the
    /// local-id order equals the global-id order (offsets are monotone),
    /// so the merge is exact. Returns `(id, dist)` pairs.
    pub fn top_k(&self, q: &[u8], k: usize, tau: usize) -> Vec<(u32, usize)> {
        assert_eq!(q.len(), self.l, "query length mismatch");
        let timer = Timer::start();
        let q: Arc<[u8]> = Arc::from(q);
        let (reply_tx, reply_rx) = channel();
        self.fan_out(&q, tau, QueryMode::TopK(k), &reply_tx);
        drop(reply_tx);
        let merged = Self::merge_topk(&self.shards, reply_rx.iter(), k);
        self.metrics.record_query(timer.elapsed_us() as u64, merged.len());
        merged
    }

    fn merge_topk(
        shards: &[Shard],
        replies: impl Iterator<Item = (usize, ShardReply)>,
        k: usize,
    ) -> Vec<(u32, usize)> {
        let mut all: Vec<(usize, u32)> = Vec::new();
        for (shard_no, reply) in replies {
            if let ShardReply::TopK(hits) = reply {
                let offset = shards[shard_no].offset;
                all.extend(hits.into_iter().map(|(id, d)| (d, id + offset)));
            }
        }
        all.sort_unstable();
        all.truncate(k);
        all.into_iter().map(|(d, id)| (id, d)).collect()
    }

    /// Executes a mixed-mode batch of queries as one pipelined fan-out
    /// round (the batcher's entry point — search, count *and* top-k all
    /// flow through here). All queries are enqueued on every shard
    /// *before* any result is collected, so the batch completes in
    /// (slowest shard's queue) time rather than Σ per-query latencies.
    /// Each query's latency is stamped from its own fan-out to its last
    /// shard reply — real per-query wall time, identical accounting for
    /// all three modes.
    pub fn run_batch(&self, queries: &[(Arc<[u8]>, usize, QueryMode)]) -> Vec<QueryResult> {
        self.metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        for (q, _, _) in queries {
            assert_eq!(q.len(), self.l, "query length mismatch");
        }
        // Phase 1: fan out everything.
        let pending: Vec<_> = queries
            .iter()
            .map(|(q, tau, mode)| {
                let timer = Timer::start();
                let (reply_tx, reply_rx) = channel();
                self.fan_out(q, *tau, *mode, &reply_tx);
                (*mode, timer, reply_rx)
            })
            .collect();
        // Phase 2: collect in request order.
        let n_shards = self.shards.len();
        pending
            .into_iter()
            .map(|(mode, timer, rx)| {
                let result = match mode {
                    QueryMode::Ids => {
                        let mut merged = Vec::new();
                        for _ in 0..n_shards {
                            let (shard_no, reply) = rx.recv().expect("shard reply");
                            if let ShardReply::Ids(hits) = reply {
                                let offset = self.shards[shard_no].offset;
                                merged.extend(hits.into_iter().map(|id| id + offset));
                            }
                        }
                        QueryResult::Ids(merged)
                    }
                    QueryMode::Count => {
                        let mut total = 0usize;
                        for _ in 0..n_shards {
                            let (_, reply) = rx.recv().expect("shard reply");
                            if let ShardReply::Count(c) = reply {
                                total += c;
                            }
                        }
                        QueryResult::Count(total)
                    }
                    QueryMode::TopK(k) => {
                        let replies = (0..n_shards).map(|_| rx.recv().expect("shard reply"));
                        QueryResult::TopK(Self::merge_topk(&self.shards, replies, k))
                    }
                };
                let size = match &result {
                    QueryResult::Ids(v) => v.len(),
                    QueryResult::Count(c) => *c,
                    QueryResult::TopK(v) => v.len(),
                };
                self.metrics.record_query(timer.elapsed_us() as u64, size);
                result
            })
            .collect()
    }

    /// Id-search-only batch (compatibility wrapper over
    /// [`Engine::run_batch`]).
    pub fn search_batch(&self, queries: &[(Arc<[u8]>, usize)]) -> Vec<Vec<u32>> {
        let with_mode: Vec<(Arc<[u8]>, usize, QueryMode)> = queries
            .iter()
            .map(|(q, tau)| (Arc::clone(q), *tau, QueryMode::Ids))
            .collect();
        self.run_batch(&with_mode)
            .into_iter()
            .map(|r| match r {
                QueryResult::Ids(v) => v,
                _ => unreachable!("Ids batch returned a non-Ids result"),
            })
            .collect()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// A swappable engine reference: the server and batcher read the current
/// engine through this slot, and the `reload` protocol op replaces it
/// with one freshly loaded from a snapshot — zero-downtime cold-storage
/// swap (in-flight batches finish on the engine they started on).
pub struct EngineSlot {
    inner: RwLock<Arc<Engine>>,
}

impl EngineSlot {
    pub fn new(engine: Arc<Engine>) -> Self {
        EngineSlot { inner: RwLock::new(engine) }
    }

    /// The engine serving right now.
    pub fn current(&self) -> Arc<Engine> {
        self.inner.read().unwrap().clone()
    }

    /// Swaps in a new engine, returning the previous one (kept alive by
    /// any in-flight queries that still hold its `Arc`).
    pub fn replace(&self, engine: Arc<Engine>) -> Arc<Engine> {
        std::mem::replace(&mut *self.inner.write().unwrap(), engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn rows(n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..16).map(|_| rng.below(4) as u8).collect())
            .collect();
        (0..n)
            .map(|_| {
                let mut r = centers[rng.below_usize(8)].clone();
                for _ in 0..rng.below_usize(4) {
                    let p = rng.below_usize(16);
                    r[p] = rng.below(4) as u8;
                }
                r
            })
            .collect()
    }

    #[test]
    fn sharded_equals_unsharded() {
        let rows = rows(2000, 91);
        let set = SketchSet::from_rows(2, 16, &rows);
        for n_shards in [1usize, 3, 8] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            assert_eq!(engine.n_shards(), n_shards);
            let mut rng = Rng::new(92);
            for _ in 0..10 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 2, 4] {
                    let mut got = engine.search(&q, tau);
                    got.sort();
                    let expect: Vec<u32> = (0..rows.len())
                        .filter(|&i| ham_chars(&rows[i], &q) <= tau)
                        .map(|i| i as u32)
                        .collect();
                    assert_eq!(got, expect, "shards={n_shards} tau={tau}");
                }
            }
        }
    }

    #[test]
    fn count_and_topk_agree_with_search() {
        let rows = rows(1200, 96);
        let set = SketchSet::from_rows(2, 16, &rows);
        for n_shards in [1usize, 4] {
            let engine = Engine::build(&set, n_shards, &ShardIndexKind::Bst(BstConfig::default()));
            for qi in [0usize, 7, 400] {
                let q = &rows[qi];
                for tau in [0usize, 2, 4] {
                    assert_eq!(
                        engine.count(q, tau),
                        engine.search(q, tau).len(),
                        "shards={n_shards} tau={tau}"
                    );
                }
                // top-k equals globally sorted brute force by (dist, id)
                let tau = 4usize;
                let mut all: Vec<(usize, u32)> = (0..rows.len())
                    .map(|i| (ham_chars(&rows[i], q), i as u32))
                    .filter(|&(d, _)| d <= tau)
                    .collect();
                all.sort_unstable();
                for k in [1usize, 10, 1000] {
                    let got = engine.top_k(q, k, tau);
                    let expect: Vec<(u32, usize)> =
                        all.iter().take(k).map(|&(d, id)| (id, d)).collect();
                    assert_eq!(got, expect, "shards={n_shards} k={k}");
                }
            }
        }
    }

    #[test]
    fn batch_matches_sequential_and_records_per_query_latency() {
        let rows = rows(900, 97);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let queries: Vec<(Arc<[u8]>, usize)> = (0..8)
            .map(|i| (Arc::from(rows[i * 37].as_slice()), i % 4))
            .collect();
        let batch = engine.search_batch(&queries);
        assert_eq!(batch.len(), queries.len());
        for ((q, tau), got) in queries.iter().zip(&batch) {
            let mut got = got.clone();
            got.sort();
            let mut expect = engine.search(q, *tau);
            expect.sort();
            assert_eq!(got, expect);
        }
        // one metrics record per query (batch counted once)
        let m = engine.metrics();
        assert_eq!(
            m.queries.load(std::sync::atomic::Ordering::Relaxed),
            (queries.len() * 2) as u64
        );
        assert_eq!(m.batches.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn mixed_mode_batch_matches_single_queries() {
        let rows = rows(700, 99);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 3, &ShardIndexKind::Bst(BstConfig::default()));
        let q0: Arc<[u8]> = Arc::from(rows[0].as_slice());
        let q1: Arc<[u8]> = Arc::from(rows[50].as_slice());
        let batch = engine.run_batch(&[
            (Arc::clone(&q0), 2, QueryMode::Ids),
            (Arc::clone(&q1), 3, QueryMode::Count),
            (Arc::clone(&q0), 4, QueryMode::TopK(5)),
        ]);
        assert_eq!(batch.len(), 3);
        match &batch[0] {
            QueryResult::Ids(ids) => {
                let mut got = ids.clone();
                got.sort();
                let mut expect = engine.search(&q0, 2);
                expect.sort();
                assert_eq!(got, expect);
            }
            other => panic!("expected Ids, got {other:?}"),
        }
        assert_eq!(batch[1], QueryResult::Count(engine.count(&q1, 3)));
        assert_eq!(batch[2], QueryResult::TopK(engine.top_k(&q0, 5, 4)));
    }

    #[test]
    fn multibst_shards_work() {
        let rows = rows(800, 93);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::MultiBst(2));
        let q = rows[0].clone();
        let mut got = engine.search(&q, 3);
        got.sort();
        let expect: Vec<u32> = (0..rows.len())
            .filter(|&i| ham_chars(&rows[i], &q) <= 3)
            .map(|i| i as u32)
            .collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn metrics_accumulate() {
        let rows = rows(300, 94);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        for i in 0..5 {
            engine.search(&rows[i], 1);
        }
        let m = engine.metrics();
        assert_eq!(m.queries.load(std::sync::atomic::Ordering::Relaxed), 5);
    }

    #[test]
    fn concurrent_searches_are_safe() {
        let rows = rows(1000, 95);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = std::sync::Arc::new(Engine::build(
            &set,
            4,
            &ShardIndexKind::Bst(BstConfig::default()),
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let eng = std::sync::Arc::clone(&engine);
            let q = rows[t * 10].clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let hits = eng.search(&q, 2);
                    assert!(!hits.is_empty()); // at least itself
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn save_load_roundtrip_answers_identically() {
        let rows = rows(1500, 90);
        let set = SketchSet::from_rows(2, 16, &rows);
        let dir = std::env::temp_dir().join("bst_engine_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (kind, name) in [
            (ShardIndexKind::Bst(BstConfig::default()), "bst"),
            (ShardIndexKind::MultiBst(2), "mibst"),
        ] {
            let engine = Engine::build(&set, 3, &kind);
            let path = dir.join(format!("engine_{name}.snap"));
            engine.save(&path).unwrap();

            // (the no-rebuild counter assertions live in the dedicated
            // single-test binary tests/snapshot_cold_start.rs — the
            // global counters would race with parallel sibling tests)
            let loaded = Engine::load(&path).unwrap();
            assert_eq!(loaded.n(), engine.n());
            assert_eq!(loaded.l(), engine.l());
            assert_eq!(loaded.n_shards(), engine.n_shards());
            let mut rng = Rng::new(77);
            for _ in 0..8 {
                let q = rows[rng.below_usize(rows.len())].clone();
                for tau in [0usize, 2, 4] {
                    let mut a = engine.search(&q, tau);
                    let mut b = loaded.search(&q, tau);
                    a.sort();
                    b.sort();
                    assert_eq!(a, b, "{name} tau={tau}");
                    assert_eq!(engine.count(&q, tau), loaded.count(&q, tau));
                }
                assert_eq!(engine.top_k(&q, 7, 5), loaded.top_k(&q, 7, 5), "{name}");
            }
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn load_rejects_corrupt_and_missing() {
        let rows = rows(300, 89);
        let set = SketchSet::from_rows(2, 16, &rows);
        let engine = Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default()));
        let dir = std::env::temp_dir().join("bst_engine_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("engine_corrupt.snap");
        engine.save(&path).unwrap();

        let good = std::fs::read(&path).unwrap();
        // truncations at many points
        for cut in [0usize, 8, 40, good.len() / 2, good.len() - 3] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(Engine::load(&path).is_err(), "cut={cut}");
        }
        // flip 8 consecutive bytes mid-file: inter-section padding runs
        // are at most 7 bytes, so at least one checksummed byte flips
        let mut bad = good.clone();
        let mid = good.len() / 2;
        for b in &mut bad[mid..mid + 8] {
            *b ^= 0x10;
        }
        std::fs::write(&path, &bad).unwrap();
        assert!(Engine::load(&path).is_err());
        // missing file
        assert!(Engine::load(&dir.join("nope.snap")).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn engine_slot_swaps() {
        let rows = rows(200, 88);
        let set = SketchSet::from_rows(2, 16, &rows);
        let a = Arc::new(Engine::build(&set, 1, &ShardIndexKind::Bst(BstConfig::default())));
        let b = Arc::new(Engine::build(&set, 2, &ShardIndexKind::Bst(BstConfig::default())));
        let slot = EngineSlot::new(Arc::clone(&a));
        assert_eq!(slot.current().n_shards(), 1);
        let old = slot.replace(Arc::clone(&b));
        assert_eq!(old.n_shards(), 1);
        assert_eq!(slot.current().n_shards(), 2);
    }
}
