//! TCP front-end.
//!
//! One thread per connection (sufficient for the benchmark client counts
//! here; the request path itself is the batcher → sharded engine). The
//! listener thread accepts until `shutdown` is requested by any client or
//! the returned [`ServerHandle`] is stopped.
//!
//! Requests ride the versioned envelope defined in [`super::protocol`]:
//! the server parses with [`parse_request_line`], remembers the client's
//! declared `v`, and threads it into every response builder — so legacy
//! (`v`-absent) clients keep their exact pre-versioning shapes while
//! version-bearing clients get `"v"`-stamped replies and structured
//! `{code, message}` errors.
//!
//! The engine lives behind an [`EngineSlot`]: the `reload` op loads a
//! snapshot from disk ([`Engine::load_with`] — no rebuild, honoring the
//! configured serving load mode, owned or mapped) and swaps it in;
//! subsequent batches serve from the new engine. A reload must keep the
//! sketch shape `L`/`b` (the serving schema); snapshots of a different
//! shape — and missing or corrupt snapshot files — are rejected with an
//! error response while the running engine keeps serving untouched.
//!
//! Write ops (`insert` / `delete` / `merge` / `save`) are control-plane:
//! they hit the current engine directly rather than riding the batcher,
//! and a reload replaces the engine wholesale — flush mutations with a
//! `merge` + `save` before reloading if they must survive.
//!
//! Replication (see [`super::replica`] for the follower side):
//!
//! * A **primary** answers `snapshot.fetch` (write a fenced snapshot,
//!   stream it raw after the header line) and `wal.fetch` (read-only
//!   cursor fetch of raw WAL frames — requires `--wal`).
//! * A **follower** (`--follow`) runs a [`Replicator`] tail thread,
//!   serves every read op from the replicated engine, and rejects
//!   writes — and replication-source ops — with a `read_only` error.
//! * `repl.status` reports `{role, applied_id, lag_records,
//!   last_contact_ms}` on both roles.
//!
//! Request lines are read through a hard size cap
//! (`--max-request-bytes`, default 16 MiB): an oversized line is
//! answered with an error and discarded in bounded chunks — one hostile
//! client cannot grow a connection buffer until the process dies — and
//! the connection keeps serving.

use super::batcher::{BatchSubmitter, Batcher};
use super::engine::{Engine, EngineSlot};
use super::protocol::{
    count_response, delete_response, error_response, insert_response, merge_response, ok_response,
    parse_request_line, ping_response, reload_response, repl_status_response, respond,
    save_response, search_response, snapshot_fetch_header, topk_response, wal_fetch_header,
    ErrorCode, Request,
};
use super::replica::{self, ReplState, Replicator, TailCfg};
use super::ServeConfig;
use crate::store::wal::{self, WalCursor, WalFetch};
use crate::util::json::Json;
use crate::util::timer::Timer;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Running server handle; dropping it stops the listener.
pub struct ServerHandle {
    /// Bound address (useful when the config asked for port 0).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.do_stop();
    }

    fn do_stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.do_stop();
        }
    }
}

/// Everything a connection thread needs, bundled once per server.
#[derive(Clone)]
struct ConnCtx {
    submitter: BatchSubmitter,
    slot: Arc<EngineSlot>,
    stop: Arc<AtomicBool>,
    /// Present on followers: replication telemetry for `repl.status`.
    repl: Option<Arc<ReplState>>,
    default_tau: usize,
    mmap: bool,
    max_request_bytes: usize,
    /// Followers reject write ops (and replication-source ops) with a
    /// `read_only` error.
    read_only: bool,
}

/// Starts serving `engine` per `cfg`; returns immediately.
pub fn serve(engine: Arc<Engine>, cfg: ServeConfig) -> std::io::Result<ServerHandle> {
    engine.set_merge_threshold(cfg.merge_threshold);
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);

    let slot = Arc::new(EngineSlot::new(engine));
    let batcher = Batcher::start(Arc::clone(&slot), &cfg);

    // Follower mode: spawn the replication tail. The caller (serve
    // --follow startup) has already bootstrapped the engine from the
    // primary's snapshot and recorded the tail cursor in the config.
    let repl_state = cfg.follow.as_ref().map(|_| Arc::new(ReplState::new()));
    let replicator = match (&cfg.follow, cfg.follow_cursor, &repl_state) {
        (Some(primary), Some(cursor), Some(state)) => Some(Replicator::start(TailCfg {
            primary: primary.clone(),
            slot: Arc::clone(&slot),
            state: Arc::clone(state),
            cursor,
            poll: Duration::from_millis(cfg.follow_poll_ms.max(1)),
            local_snapshot: replica::default_local_snapshot(),
            mmap: cfg.mmap,
        })),
        _ => None,
    };

    let ctx = ConnCtx {
        submitter: batcher.submitter(),
        slot,
        stop: Arc::clone(&stop),
        repl: repl_state,
        default_tau: cfg.default_tau,
        mmap: cfg.mmap,
        max_request_bytes: cfg.max_request_bytes,
        read_only: cfg.follow.is_some(),
    };

    let handle = std::thread::Builder::new()
        .name("bst-listener".into())
        .spawn(move || {
            // keep the batcher and replication tail alive for the
            // server lifetime
            let _batcher = batcher;
            let _replicator = replicator;
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                // Small request/response pairs: Nagle + delayed ACK would
                // add ~40 ms per round trip (measured; EXPERIMENTS.md §Perf).
                let _ = stream.set_nodelay(true);
                let ctx = ctx.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, ctx);
                });
            }
        })
        .expect("spawn listener");

    Ok(ServerHandle { addr, stop, handle: Some(handle) })
}

/// Validates a request's query length against the engine's sketch length.
fn check_len(engine: &Engine, q: &[u8]) -> Result<(), String> {
    if q.len() == engine.l() {
        Ok(())
    } else {
        engine
            .metrics()
            .errors
            .fetch_add(1, Ordering::Relaxed);
        Err(format!(
            "query length {} != sketch length {}",
            q.len(),
            engine.l()
        ))
    }
}

/// Ops a read-only follower refuses. `snapshot.fetch` and `wal.fetch`
/// are included: replicas replicate from the primary, not from each
/// other (a follower's WAL-less engine has nothing to ship anyway).
fn is_write_op(req: &Request) -> bool {
    matches!(
        req,
        Request::Insert { .. }
            | Request::Delete { .. }
            | Request::Merge
            | Request::Save { .. }
            | Request::Reload { .. }
            | Request::SnapshotFetch
            | Request::WalFetch { .. }
    )
}

/// Reads one newline-terminated request into `buf`, holding at most
/// `limit + 1` bytes at any point. Returns `Ok(None)` on clean EOF,
/// `Ok(Some(true))` for a complete line, and `Ok(Some(false))` for an
/// oversized line — whose remainder has already been discarded in
/// bounded chunks, so the next call starts at a fresh request.
fn read_request_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    limit: usize,
) -> std::io::Result<Option<bool>> {
    buf.clear();
    let n = reader.by_ref().take(limit as u64 + 1).read_until(b'\n', buf)?;
    if n == 0 {
        return Ok(None);
    }
    // Complete when the line terminator arrived (content may be exactly
    // `limit` bytes) or EOF ended a short final line. The only other way
    // read_until stops is the `take` cap: `limit + 1` bytes, no newline.
    if buf.ends_with(b"\n") || buf.len() <= limit {
        return Ok(Some(true));
    }
    let mut scratch = Vec::new();
    loop {
        scratch.clear();
        let k = reader.by_ref().take(65536).read_until(b'\n', &mut scratch)?;
        if k == 0 || scratch.ends_with(b"\n") {
            return Ok(Some(false));
        }
    }
}

/// Monotonic tag for concurrent `snapshot.fetch` temp files.
static SNAP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Answers `snapshot.fetch`: writes a fenced snapshot to a process-local
/// temp file, streams it raw after the header line, and unlinks it (the
/// open handle keeps the bytes readable — Unix). The header carries the
/// post-rotation WAL cursor so the follower knows where to tail from.
fn stream_snapshot(
    engine: &Engine,
    writer: &mut TcpStream,
    v: Option<u64>,
) -> std::io::Result<()> {
    let tag = SNAP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let name = format!("bst-serve-snap-{}-{tag}.bin", std::process::id());
    let path = std::env::temp_dir().join(name);
    let cursor = match engine.save_with_cursor(&path) {
        Ok(c) => c,
        Err(e) => {
            engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
            let reply = error_response(ErrorCode::Io, &format!("snapshot failed: {e}"), v);
            writer.write_all(reply.as_bytes())?;
            return writer.write_all(b"\n");
        }
    };
    let mut file = std::fs::File::open(&path)?;
    let len = file.metadata()?.len();
    // Unlink immediately: the open handle streams the bytes, and a
    // killed connection leaves nothing behind.
    let _ = std::fs::remove_file(&path);
    let header = snapshot_fetch_header(len, engine.n(), cursor.map(|c| (c.seq, c.off)), v);
    writer.write_all(header.as_bytes())?;
    writer.write_all(b"\n")?;
    std::io::copy(&mut file, writer)?;
    Ok(())
}

/// Answers `wal.fetch`: a read-only cursor fetch of raw frames from the
/// engine's log, streamed after the header line. A rotated-away cursor
/// is a structured `wal_gap` — the follower's signal to re-bootstrap.
fn stream_wal(
    engine: &Engine,
    writer: &mut TcpStream,
    from_seq: u64,
    from_off: u64,
    max_bytes: usize,
    v: Option<u64>,
) -> std::io::Result<()> {
    let Some(base) = engine.wal_base() else {
        let reply = error_response(
            ErrorCode::NoWal,
            "this server has no write-ahead log (started without --wal)",
            v,
        );
        writer.write_all(reply.as_bytes())?;
        return writer.write_all(b"\n");
    };
    let from = WalCursor { seq: from_seq, off: from_off };
    // Serve only up to the durable frontier: under group commit the
    // tail past it is un-fsynced and its group can still fail (NACKed
    // and re-staged) — a follower must never apply a record its
    // primary has not yet acknowledged as durable.
    match wal::fetch_frames(&base, from, max_bytes, engine.durable_frontier()) {
        Err(e) => {
            engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
            let reply = error_response(ErrorCode::Io, &format!("wal read failed: {e}"), v);
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")
        }
        Ok(WalFetch::Gap) => {
            let reply = error_response(
                ErrorCode::WalGap,
                &format!(
                    "wal position {from_seq}:{from_off} was rotated away; \
                     re-bootstrap from snapshot.fetch"
                ),
                v,
            );
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")
        }
        Ok(WalFetch::Chunk(chunk)) => {
            // Advertise the durable row count, not the buffered tail:
            // a follower measures its lag against state that survives
            // the primary crashing, and the gap can never go negative
            // while a group is open.
            let header = wal_fetch_header(
                chunk.frames.len() as u64,
                chunk.records,
                chunk.next.seq,
                chunk.next.off,
                engine.durable_n() as usize,
                v,
            );
            writer.write_all(header.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.write_all(&chunk.frames)
        }
    }
}

fn handle_conn(stream: TcpStream, ctx: ConnCtx) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let complete = match read_request_line(&mut reader, &mut buf, ctx.max_request_bytes)? {
            None => break,
            Some(complete) => complete,
        };
        if !complete {
            ctx.slot.current().metrics().errors.fetch_add(1, Ordering::Relaxed);
            let reply = error_response(
                ErrorCode::BadRequest,
                &format!(
                    "request exceeds max request size ({} bytes)",
                    ctx.max_request_bytes
                ),
                None,
            );
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
            continue;
        }
        let line = String::from_utf8_lossy(&buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let engine = ctx.slot.current();
        let parsed = parse_request_line(line);
        let v = parsed.v;
        let req = match parsed.result {
            Ok(req) => req,
            Err(e) => {
                engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                let reply = error_response(e.code, &e.message, v);
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                continue;
            }
        };
        if ctx.read_only && is_write_op(&req) {
            engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
            let reply = error_response(
                ErrorCode::ReadOnly,
                "this server is a read-only follower; send writes to the primary",
                v,
            );
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
            continue;
        }
        // Streaming ops write their own header + raw payload.
        match req {
            Request::SnapshotFetch => {
                stream_snapshot(&engine, &mut writer, v)?;
                continue;
            }
            Request::WalFetch { from_seq, from_off, max_bytes } => {
                stream_wal(&engine, &mut writer, from_seq, from_off, max_bytes, v)?;
                continue;
            }
            _ => {}
        }
        let reply = match req {
            Request::Ping => ping_response(v),
            Request::Stats => {
                let mut stats = engine.metrics().snapshot();
                // Residency gauges for mapped engines: how much of the
                // snapshot is mapped, and how much of that is page-cache
                // resident right now (mincore). `null` when the engine
                // owns its memory (no mapping to measure).
                if let Json::Obj(map) = &mut stats {
                    let gauge = |g: Option<usize>| match g {
                        Some(g) => Json::num(g as f64),
                        None => Json::Null,
                    };
                    map.insert("mapped_bytes".to_string(), gauge(engine.mapped_bytes()));
                    map.insert("resident_bytes".to_string(), gauge(engine.resident_bytes()));
                    map.insert("advised_bytes".to_string(), gauge(engine.advised_bytes()));
                }
                respond(stats, v)
            }
            Request::ReplStatus => match &ctx.repl {
                Some(state) => {
                    let applied = engine.n() as u64;
                    repl_status_response(
                        "follower",
                        applied,
                        state.primary_n().saturating_sub(applied),
                        state.last_contact_ms(),
                        v,
                    )
                }
                // A primary reports the durability watermark, not the
                // buffered tail of an open commit group: `applied_id`
                // is what followers can actually fetch, so an operator
                // diffing primary vs follower never sees the follower
                // "ahead" (negative lag) mid-group.
                None => repl_status_response("primary", engine.durable_n(), 0, None, v),
            },
            Request::Shutdown => {
                ctx.stop.store(true, Ordering::SeqCst);
                writer.write_all(ok_response(v).as_bytes())?;
                writer.write_all(b"\n")?;
                // poke the accept loop so it observes the stop flag
                let _ = TcpStream::connect(writer.local_addr()?);
                break;
            }
            // All three query modes ride the batcher, so they share the
            // fan-out amortization and the per-query latency accounting.
            Request::Search { q, tau } => match check_len(&engine, &q) {
                Err(e) => error_response(ErrorCode::BadRequest, &e, v),
                Ok(()) => {
                    let timer = Timer::start();
                    match ctx.submitter.search(q, tau.unwrap_or(ctx.default_tau)) {
                        Some(ids) => search_response(&ids, timer.elapsed_us() as u64, v),
                        None => error_response(ErrorCode::ShardFailed, "engine unavailable", v),
                    }
                }
            },
            Request::Count { q, tau } => match check_len(&engine, &q) {
                Err(e) => error_response(ErrorCode::BadRequest, &e, v),
                Ok(()) => {
                    let timer = Timer::start();
                    match ctx.submitter.count(q, tau.unwrap_or(ctx.default_tau)) {
                        Some(n) => count_response(n, timer.elapsed_us() as u64, v),
                        None => error_response(ErrorCode::ShardFailed, "engine unavailable", v),
                    }
                }
            },
            Request::TopK { q, k, tau } => match check_len(&engine, &q) {
                Err(e) => error_response(ErrorCode::BadRequest, &e, v),
                Ok(()) => {
                    let timer = Timer::start();
                    // default radius: unbounded nearest-neighbor (tau = L);
                    // k above the database size is meaningless — clamp it
                    // so untrusted requests stay cheap.
                    let k = k.min(engine.n());
                    let tau = tau.unwrap_or(engine.l());
                    match ctx.submitter.topk(q, k, tau) {
                        Some(hits) => topk_response(&hits, timer.elapsed_us() as u64, v),
                        None => error_response(ErrorCode::ShardFailed, "engine unavailable", v),
                    }
                }
            },
            // Write ops are control-plane: they go straight to the
            // current engine (not through the batcher). Inserts block
            // until every shard has appended, so a subsequent query on
            // this connection sees the new rows.
            Request::Insert { rows } => {
                let timer = Timer::start();
                match engine.insert_batch(&rows) {
                    Err(e) => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(ErrorCode::BadRequest, &e, v)
                    }
                    Ok(range) => insert_response(
                        range.start,
                        rows.len(),
                        timer.elapsed_us() as u64,
                        v,
                    ),
                }
            }
            Request::Delete { id } => {
                let timer = Timer::start();
                let deleted = engine.delete(id);
                delete_response(deleted, timer.elapsed_us() as u64, v)
            }
            Request::Merge => {
                let timer = Timer::start();
                let summary = engine.merge();
                merge_response(summary.merged, summary.skipped, timer.elapsed_us() as u64, v)
            }
            Request::Save { path } => {
                let timer = Timer::start();
                // Durable checkpoint: atomic snapshot write (tmp + fsync
                // + rename), then the WAL rotates — replay-on-load only
                // covers writes after this point.
                match engine.save(Path::new(&path)) {
                    Err(e) => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(ErrorCode::Io, &format!("save failed: {e}"), v)
                    }
                    Ok(()) => save_response(engine.n(), timer.elapsed_us() as u64, v),
                }
            }
            Request::Reload { path } => {
                let timer = Timer::start();
                // The running engine keeps serving through every error
                // arm below — a failed reload never swaps the slot.
                match Engine::load_with(Path::new(&path), ctx.mmap) {
                    Err(e) => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(ErrorCode::Io, &format!("reload failed: {e}"), v)
                    }
                    Ok(new_engine) if new_engine.l() != engine.l() => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(
                            ErrorCode::BadRequest,
                            &format!(
                                "reload rejected: snapshot L={} != serving L={}",
                                new_engine.l(),
                                engine.l()
                            ),
                            v,
                        )
                    }
                    Ok(new_engine) if new_engine.b() != engine.b() => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(
                            ErrorCode::BadRequest,
                            &format!(
                                "reload rejected: snapshot b={} != serving b={}",
                                new_engine.b(),
                                engine.b()
                            ),
                            v,
                        )
                    }
                    Ok(new_engine) => {
                        // the snapshot engine inherits the serving
                        // merge threshold (it is not persisted)
                        new_engine.set_merge_threshold(engine.merge_threshold());
                        let n = new_engine.n();
                        let shards = new_engine.n_shards();
                        ctx.slot.replace(Arc::new(new_engine));
                        reload_response(n, shards, timer.elapsed_us() as u64, v)
                    }
                }
            }
            // handled above (streaming)
            Request::SnapshotFetch | Request::WalFetch { .. } => unreachable!(),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}
