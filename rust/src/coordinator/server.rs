//! TCP front-end.
//!
//! One thread per connection (sufficient for the benchmark client counts
//! here; the request path itself is the batcher → sharded engine). The
//! listener thread accepts until `shutdown` is requested by any client or
//! the returned [`ServerHandle`] is stopped.
//!
//! The engine lives behind an [`EngineSlot`]: the `reload` op loads a
//! snapshot from disk ([`Engine::load_with`] — no rebuild, honoring the
//! configured serving load mode, owned or mapped) and swaps it in;
//! subsequent batches serve from the new engine. A reload must keep the
//! sketch shape `L`/`b` (the serving schema); snapshots of a different
//! shape — and missing or corrupt snapshot files — are rejected with an
//! error response while the running engine keeps serving untouched.
//!
//! Write ops (`insert` / `delete` / `merge` / `save`) are control-plane:
//! they hit the current engine directly rather than riding the batcher,
//! and a reload replaces the engine wholesale — flush mutations with a
//! `merge` + `save` before reloading if they must survive.
//!
//! Request lines are read through a hard size cap
//! (`--max-request-bytes`, default 16 MiB): an oversized line is
//! answered with an error and discarded in bounded chunks — one hostile
//! client cannot grow a connection buffer until the process dies — and
//! the connection keeps serving.

use super::batcher::Batcher;
use super::engine::{Engine, EngineSlot};
use super::protocol::{
    count_response, delete_response, error_response, insert_response, merge_response,
    parse_request, reload_response, save_response, search_response, topk_response, Request,
};
use super::ServeConfig;
use crate::util::json::Json;
use crate::util::timer::Timer;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Running server handle; dropping it stops the listener.
pub struct ServerHandle {
    /// Bound address (useful when the config asked for port 0).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.do_stop();
    }

    fn do_stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.do_stop();
        }
    }
}

/// Starts serving `engine` per `cfg`; returns immediately.
pub fn serve(engine: Arc<Engine>, cfg: ServeConfig) -> std::io::Result<ServerHandle> {
    engine.set_merge_threshold(cfg.merge_threshold);
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let default_tau = cfg.default_tau;
    let mmap = cfg.mmap;
    let max_request_bytes = cfg.max_request_bytes;

    let slot = Arc::new(EngineSlot::new(engine));
    let batcher = Batcher::start(Arc::clone(&slot), &cfg);

    let handle = std::thread::Builder::new()
        .name("bst-listener".into())
        .spawn(move || {
            // keep the batcher alive for the server lifetime
            let batcher = batcher;
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                // Small request/response pairs: Nagle + delayed ACK would
                // add ~40 ms per round trip (measured; EXPERIMENTS.md §Perf).
                let _ = stream.set_nodelay(true);
                let submitter = batcher.submitter();
                let slot = Arc::clone(&slot);
                let stop3 = Arc::clone(&stop2);
                std::thread::spawn(move || {
                    let _ = handle_conn(
                        stream,
                        submitter,
                        slot,
                        stop3,
                        default_tau,
                        mmap,
                        max_request_bytes,
                    );
                });
            }
        })
        .expect("spawn listener");

    Ok(ServerHandle { addr, stop, handle: Some(handle) })
}

/// Validates a request's query length against the engine's sketch length.
fn check_len(engine: &Engine, q: &[u8]) -> Result<(), String> {
    if q.len() == engine.l() {
        Ok(())
    } else {
        engine
            .metrics()
            .errors
            .fetch_add(1, Ordering::Relaxed);
        Err(format!(
            "query length {} != sketch length {}",
            q.len(),
            engine.l()
        ))
    }
}

/// Reads one newline-terminated request into `buf`, holding at most
/// `limit + 1` bytes at any point. Returns `Ok(None)` on clean EOF,
/// `Ok(Some(true))` for a complete line, and `Ok(Some(false))` for an
/// oversized line — whose remainder has already been discarded in
/// bounded chunks, so the next call starts at a fresh request.
fn read_request_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    limit: usize,
) -> std::io::Result<Option<bool>> {
    buf.clear();
    let n = reader.by_ref().take(limit as u64 + 1).read_until(b'\n', buf)?;
    if n == 0 {
        return Ok(None);
    }
    // Complete when the line terminator arrived (content may be exactly
    // `limit` bytes) or EOF ended a short final line. The only other way
    // read_until stops is the `take` cap: `limit + 1` bytes, no newline.
    if buf.ends_with(b"\n") || buf.len() <= limit {
        return Ok(Some(true));
    }
    let mut scratch = Vec::new();
    loop {
        scratch.clear();
        let k = reader.by_ref().take(65536).read_until(b'\n', &mut scratch)?;
        if k == 0 || scratch.ends_with(b"\n") {
            return Ok(Some(false));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    submitter: super::batcher::BatchSubmitter,
    slot: Arc<EngineSlot>,
    stop: Arc<AtomicBool>,
    default_tau: usize,
    mmap: bool,
    max_request_bytes: usize,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let complete = match read_request_line(&mut reader, &mut buf, max_request_bytes)? {
            None => break,
            Some(complete) => complete,
        };
        if !complete {
            slot.current().metrics().errors.fetch_add(1, Ordering::Relaxed);
            let reply = error_response(&format!(
                "request exceeds max request size ({max_request_bytes} bytes)"
            ));
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
            continue;
        }
        let line = String::from_utf8_lossy(&buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let engine = slot.current();
        let reply = match parse_request(line) {
            Err(e) => {
                engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                error_response(&e)
            }
            Ok(Request::Ping) => r#"{"pong":true}"#.to_string(),
            Ok(Request::Stats) => {
                let mut stats = engine.metrics().snapshot();
                // Residency gauges for mapped engines: how much of the
                // snapshot is mapped, and how much of that is page-cache
                // resident right now (mincore). `null` when the engine
                // owns its memory (no mapping to measure).
                if let Json::Obj(map) = &mut stats {
                    let gauge = |v: Option<usize>| match v {
                        Some(v) => Json::num(v as f64),
                        None => Json::Null,
                    };
                    map.insert("mapped_bytes".to_string(), gauge(engine.mapped_bytes()));
                    map.insert("resident_bytes".to_string(), gauge(engine.resident_bytes()));
                }
                stats.to_string()
            }
            Ok(Request::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                writer.write_all(b"{\"ok\":true}\n")?;
                // poke the accept loop so it observes the stop flag
                let _ = TcpStream::connect(writer.local_addr()?);
                break;
            }
            // All three query modes ride the batcher, so they share the
            // fan-out amortization and the per-query latency accounting.
            Ok(Request::Search { q, tau }) => match check_len(&engine, &q) {
                Err(e) => error_response(&e),
                Ok(()) => {
                    let timer = Timer::start();
                    match submitter.search(q, tau.unwrap_or(default_tau)) {
                        Some(ids) => search_response(&ids, timer.elapsed_us() as u64),
                        None => error_response("engine unavailable"),
                    }
                }
            },
            Ok(Request::Count { q, tau }) => match check_len(&engine, &q) {
                Err(e) => error_response(&e),
                Ok(()) => {
                    let timer = Timer::start();
                    match submitter.count(q, tau.unwrap_or(default_tau)) {
                        Some(n) => count_response(n, timer.elapsed_us() as u64),
                        None => error_response("engine unavailable"),
                    }
                }
            },
            Ok(Request::TopK { q, k, tau }) => match check_len(&engine, &q) {
                Err(e) => error_response(&e),
                Ok(()) => {
                    let timer = Timer::start();
                    // default radius: unbounded nearest-neighbor (tau = L);
                    // k above the database size is meaningless — clamp it
                    // so untrusted requests stay cheap.
                    let k = k.min(engine.n());
                    let tau = tau.unwrap_or(engine.l());
                    match submitter.topk(q, k, tau) {
                        Some(hits) => topk_response(&hits, timer.elapsed_us() as u64),
                        None => error_response("engine unavailable"),
                    }
                }
            },
            // Write ops are control-plane: they go straight to the
            // current engine (not through the batcher). Inserts block
            // until every shard has appended, so a subsequent query on
            // this connection sees the new rows.
            Ok(Request::Insert { rows }) => {
                let timer = Timer::start();
                match engine.insert_batch(&rows) {
                    Err(e) => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&e)
                    }
                    Ok(range) => insert_response(
                        range.start,
                        rows.len(),
                        timer.elapsed_us() as u64,
                    ),
                }
            }
            Ok(Request::Delete { id }) => {
                let timer = Timer::start();
                let deleted = engine.delete(id);
                delete_response(deleted, timer.elapsed_us() as u64)
            }
            Ok(Request::Merge) => {
                let timer = Timer::start();
                let summary = engine.merge();
                merge_response(summary.merged, summary.skipped, timer.elapsed_us() as u64)
            }
            Ok(Request::Save { path }) => {
                let timer = Timer::start();
                // Durable checkpoint: atomic snapshot write (tmp + fsync
                // + rename), then the WAL rotates — replay-on-load only
                // covers writes after this point.
                match engine.save(Path::new(&path)) {
                    Err(e) => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&format!("save failed: {e}"))
                    }
                    Ok(()) => save_response(engine.n(), timer.elapsed_us() as u64),
                }
            }
            Ok(Request::Reload { path }) => {
                let timer = Timer::start();
                // The running engine keeps serving through every error
                // arm below — a failed reload never swaps the slot.
                match Engine::load_with(Path::new(&path), mmap) {
                    Err(e) => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&format!("reload failed: {e}"))
                    }
                    Ok(new_engine) if new_engine.l() != engine.l() => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&format!(
                            "reload rejected: snapshot L={} != serving L={}",
                            new_engine.l(),
                            engine.l()
                        ))
                    }
                    Ok(new_engine) if new_engine.b() != engine.b() => {
                        engine.metrics().errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&format!(
                            "reload rejected: snapshot b={} != serving b={}",
                            new_engine.b(),
                            engine.b()
                        ))
                    }
                    Ok(new_engine) => {
                        // the snapshot engine inherits the serving
                        // merge threshold (it is not persisted)
                        new_engine.set_merge_threshold(engine.merge_threshold());
                        let n = new_engine.n();
                        let shards = new_engine.n_shards();
                        slot.replace(Arc::new(new_engine));
                        reload_response(n, shards, timer.elapsed_us() as u64)
                    }
                }
            }
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}
