//! WAL-shipping replication: the follower side.
//!
//! A follower (`bst serve --follow HOST:PORT`) holds no authoritative
//! state of its own. It bootstraps by fetching the primary's snapshot
//! over the wire (`snapshot.fetch` — the primary writes it under the
//! same save fence as a local `save`, so the header's `wal_seq` /
//! `wal_off` cursor points at the first record *not* covered by the
//! snapshot), then tails the primary's log with `wal.fetch` and applies
//! the shipped records through [`Engine::apply_replicated`] — the same
//! idempotent replay path crash recovery uses, so overlapping re-fetches
//! after a reconnect converge instead of corrupting.
//!
//! Failure handling is cursor-driven:
//!
//! * **Connection loss / primary restart** — the tail thread reconnects
//!   and resumes from its cursor. Idempotent apply makes the overlap
//!   harmless.
//! * **`wal_gap`** — the primary rotated (a local `save` deletes old
//!   segments) past the follower's cursor, or restarted with a fresh
//!   log the cursor predates. The follower re-bootstraps: fetches a new
//!   snapshot, swaps it into the serving [`EngineSlot`], and tails from
//!   the new cursor. Queries keep serving throughout — the swap is the
//!   same mechanism as the `reload` op.
//! * **Checksum mismatch on shipped frames** — the connection is
//!   dropped and the fetch retried; the cursor only advances past
//!   verified, applied records.
//!
//! The follower serves every read op; writes are rejected by the server
//! with a `read_only` error (see [`super::server`]). Replication state
//! (primary row count, last contact) lives in [`ReplState`], surfaced
//! by the `repl.status` op.

use super::engine::{Engine, EngineSlot};
use crate::store::wal::{self, WalCursor};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard cap on one fetched payload: the largest `wal.fetch` budget the
/// protocol clamps to, plus one maximal frame (a single frame always
/// ships whole regardless of budget). A header declaring more is
/// protocol corruption, not a large database.
const MAX_PAYLOAD_BYTES: usize = (64 << 20) + (1 << 30) + 64;

/// How long a read from the primary may stall before the tail thread
/// treats the connection as dead and reconnects (also bounds how long
/// `Replicator::drop` can block on a wedged primary).
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Shared replication telemetry: written by the tail thread, read by
/// the server's `repl.status` op.
pub struct ReplState {
    start: Instant,
    /// Milliseconds since `start` of the last successful exchange with
    /// the primary; `u64::MAX` = never.
    last_contact_at: AtomicU64,
    /// The primary's row count from the most recent fetch header — the
    /// follower's lag denominator.
    primary_n: AtomicU64,
}

impl ReplState {
    pub fn new() -> ReplState {
        ReplState {
            start: Instant::now(),
            last_contact_at: AtomicU64::new(u64::MAX),
            primary_n: AtomicU64::new(0),
        }
    }

    /// Records a successful exchange with the primary.
    fn contact(&self, primary_n: u64) {
        self.primary_n.store(primary_n, Ordering::Relaxed);
        let ms = self.start.elapsed().as_millis() as u64;
        self.last_contact_at.store(ms, Ordering::Relaxed);
    }

    /// Milliseconds since the last successful exchange with the primary
    /// (`None` before the first one).
    pub fn last_contact_ms(&self) -> Option<u64> {
        let at = self.last_contact_at.load(Ordering::Relaxed);
        if at == u64::MAX {
            return None;
        }
        Some((self.start.elapsed().as_millis() as u64).saturating_sub(at))
    }

    /// The primary's row count as of the last contact.
    pub fn primary_n(&self) -> u64 {
        self.primary_n.load(Ordering::Relaxed)
    }
}

impl Default for ReplState {
    fn default() -> Self {
        ReplState::new()
    }
}

/// Where a follower of this process keeps its fetched snapshot.
pub fn default_local_snapshot() -> PathBuf {
    std::env::temp_dir().join(format!("bst-follower-{}.snap", std::process::id()))
}

/// One line-delimited-JSON client connection to the primary.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn connect(addr: &str) -> std::io::Result<Conn> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
        let writer = stream.try_clone()?;
        Ok(Conn { reader: BufReader::new(stream), writer })
    }

    /// Sends one request line and reads one reply line.
    fn call(&mut self, line: &str) -> std::io::Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "primary closed the connection",
            ));
        }
        Json::parse(reply.trim())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }

    /// Reads exactly `len` raw payload bytes following a header line.
    fn read_payload(&mut self, len: usize) -> std::io::Result<Vec<u8>> {
        if len > MAX_PAYLOAD_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("payload length {len} exceeds the protocol maximum"),
            ));
        }
        let mut buf = vec![0u8; len];
        self.reader.read_exact(&mut buf)?;
        Ok(buf)
    }
}

/// The error payload of a reply, whichever shape it came in: the bare
/// legacy string or the structured object's `message`.
fn error_text(err: &Json) -> String {
    match err.as_str() {
        Some(s) => s.to_string(),
        None => err
            .get("message")
            .and_then(|m| m.as_str())
            .unwrap_or("unknown error")
            .to_string(),
    }
}

/// The machine-readable code of a structured error reply, if any.
fn error_code(err: &Json) -> Option<&str> {
    err.get("code").and_then(|c| c.as_str())
}

/// What a completed bootstrap hands the caller.
pub struct Bootstrap {
    /// Engine loaded from the fetched snapshot.
    pub engine: Engine,
    /// The primary's post-rotation WAL cursor: where tailing starts.
    /// `None` when the primary serves without `--wal` — nothing to
    /// tail, the follower would serve a frozen snapshot.
    pub cursor: Option<WalCursor>,
    /// The primary's row count at the time of the snapshot.
    pub primary_n: u64,
}

/// Fetches the primary's snapshot into `local` (atomically: tmp file +
/// fsync + rename, same contract as a local `save`) and loads it. This
/// is the follower's synchronous startup step; the in-server
/// [`Replicator`] repeats it on a `wal_gap`.
pub fn bootstrap(primary: &str, local: &Path, mapped: bool) -> Result<Bootstrap, String> {
    let mut conn =
        Conn::connect(primary).map_err(|e| format!("connect to primary {primary}: {e}"))?;
    let (engine, cursor, primary_n) = fetch_snapshot(&mut conn, local, mapped)?;
    Ok(Bootstrap { engine, cursor, primary_n })
}

/// The wire half of [`bootstrap`], reusable on an open connection.
fn fetch_snapshot(
    conn: &mut Conn,
    local: &Path,
    mapped: bool,
) -> Result<(Engine, Option<WalCursor>, u64), String> {
    let header = conn
        .call(r#"{"op":"snapshot.fetch","v":1}"#)
        .map_err(|e| format!("snapshot.fetch: {e}"))?;
    if let Some(err) = header.get("error") {
        return Err(format!("primary refused snapshot.fetch: {}", error_text(err)));
    }
    let len = header
        .get("len")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| "snapshot.fetch header lacks 'len'".to_string())?;
    let primary_n = header
        .get("n")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| "snapshot.fetch header lacks 'n'".to_string())? as u64;
    let cursor = match (
        header.get("wal_seq").and_then(|x| x.as_usize()),
        header.get("wal_off").and_then(|x| x.as_usize()),
    ) {
        (Some(s), Some(o)) => Some(WalCursor { seq: s as u64, off: o as u64 }),
        _ => None,
    };
    stream_to_file(conn, len as u64, local)
        .map_err(|e| format!("snapshot transfer failed: {e}"))?;
    let engine =
        Engine::load_with(local, mapped).map_err(|e| format!("fetched snapshot rejected: {e}"))?;
    Ok((engine, cursor, primary_n))
}

/// Streams `len` payload bytes into `path` crash-atomically: a sibling
/// tmp file is written, fsync'd, and renamed into place, so `path` is
/// only ever absent or a complete container.
fn stream_to_file(conn: &mut Conn, len: u64, path: &Path) -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".fetch-tmp");
    let tmp = PathBuf::from(tmp);
    let mut f = std::fs::File::create(&tmp)?;
    let mut chunk = [0u8; 65536];
    let mut remaining = len;
    while remaining > 0 {
        let want = (chunk.len() as u64).min(remaining) as usize;
        conn.reader.read_exact(&mut chunk[..want])?;
        f.write_all(&chunk[..want])?;
        remaining -= want as u64;
    }
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    if let Err(e) = crate::store::sync_parent_dir(path) {
        if let crate::store::StoreError::Io(io) = e {
            return Err(io);
        }
    }
    Ok(())
}

/// Everything the tail thread needs.
pub struct TailCfg {
    /// The primary's `HOST:PORT`.
    pub primary: String,
    /// Serving slot the follower answers queries from; re-bootstraps
    /// swap a freshly fetched engine in here.
    pub slot: Arc<EngineSlot>,
    /// Shared telemetry for `repl.status`.
    pub state: Arc<ReplState>,
    /// Where tailing starts (the bootstrap's cursor).
    pub cursor: WalCursor,
    /// Sleep between polls that found nothing new.
    pub poll: Duration,
    /// Where fetched snapshots land (see [`default_local_snapshot`]).
    pub local_snapshot: PathBuf,
    /// Serving load mode for fetched snapshots (`--mmap`).
    pub mmap: bool,
}

/// The background replication tail; dropping it stops the thread.
pub struct Replicator {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Replicator {
    pub fn start(cfg: TailCfg) -> Replicator {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("bst-replica".into())
            .spawn(move || tail_loop(cfg, &stop2))
            .expect("spawn replication tail");
        Replicator { stop, handle: Some(handle) }
    }
}

impl Drop for Replicator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One fetch round's verdict.
enum Step {
    /// Records applied (or the cursor advanced): fetch again at once.
    Progress,
    /// At the primary's frontier: sleep one poll interval.
    CaughtUp,
    /// Transient trouble (connection, timeout, malformed reply): sleep,
    /// reconnect if needed, retry from the same cursor.
    Retry,
    /// The cursor is unservable (rotated away / predates the primary's
    /// log): re-bootstrap from a fresh snapshot.
    Gap,
}

fn tail_loop(cfg: TailCfg, stop: &AtomicBool) {
    let mut cursor = cfg.cursor;
    let mut conn: Option<Conn> = None;
    while !stop.load(Ordering::SeqCst) {
        match fetch_and_apply(&cfg, &mut conn, &mut cursor) {
            Step::Progress => {}
            Step::CaughtUp | Step::Retry => sleep_until(cfg.poll, stop),
            Step::Gap => match rebootstrap(&cfg, &mut conn) {
                Some(c) => cursor = c,
                // No cursor: the primary (currently) serves without a
                // WAL, so there is nothing to tail — back off hard
                // before fetching another full snapshot.
                None => sleep_until(cfg.poll.saturating_mul(10), stop),
            },
        }
    }
}

/// Interruptible sleep: checks `stop` every 50 ms.
fn sleep_until(total: Duration, stop: &AtomicBool) {
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::SeqCst) {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        std::thread::sleep((deadline - now).min(Duration::from_millis(50)));
    }
}

fn ensure_conn<'a>(conn: &'a mut Option<Conn>, primary: &str) -> Option<&'a mut Conn> {
    if conn.is_none() {
        *conn = Conn::connect(primary).ok();
    }
    conn.as_mut()
}

/// Decodes a `wal.fetch` success header.
fn parse_fetch_header(header: &Json) -> Option<(usize, usize, u64, u64, u64)> {
    Some((
        header.get("len")?.as_usize()?,
        header.get("records")?.as_usize()?,
        header.get("next_seq")?.as_usize()? as u64,
        header.get("next_off")?.as_usize()? as u64,
        header.get("n")?.as_usize()? as u64,
    ))
}

/// One `wal.fetch` round: request from `cursor`, verify the shipped
/// frames, apply, advance. The cursor only moves past records that were
/// checksum-verified and durably applied to the serving engine.
fn fetch_and_apply(cfg: &TailCfg, conn: &mut Option<Conn>, cursor: &mut WalCursor) -> Step {
    let Some(c) = ensure_conn(conn, &cfg.primary) else {
        return Step::Retry;
    };
    let req = format!(
        r#"{{"op":"wal.fetch","from_seq":{},"from_off":{},"v":1}}"#,
        cursor.seq, cursor.off
    );
    let header = match c.call(&req) {
        Ok(h) => h,
        Err(_) => {
            *conn = None;
            return Step::Retry;
        }
    };
    if let Some(err) = header.get("error") {
        // A clean error reply leaves the stream aligned (no payload
        // follows), so the connection stays usable.
        return match error_code(err) {
            Some("wal_gap") => Step::Gap,
            _ => Step::Retry,
        };
    }
    let Some((len, records, next_seq, next_off, primary_n)) = parse_fetch_header(&header) else {
        *conn = None;
        return Step::Retry;
    };
    let bytes = match c.read_payload(len) {
        Ok(b) => b,
        Err(_) => {
            *conn = None;
            return Step::Retry;
        }
    };
    cfg.state.contact(primary_n);
    let next = WalCursor { seq: next_seq, off: next_off };
    if records == 0 {
        // Nothing shipped; the cursor may still hop to a fresh segment
        // opened by a rotation on the primary.
        let caught_up = next == *cursor;
        *cursor = next;
        return if caught_up { Step::CaughtUp } else { Step::Progress };
    }
    // Receiver-side verification: re-parse every frame, re-checking
    // lengths and FNV-1a checksums, before anything is applied.
    let (recs, valid) = wal::scan_frames(&bytes);
    if valid != bytes.len() || recs.len() != records {
        *conn = None;
        return Step::Retry;
    }
    match cfg.slot.current().apply_replicated(recs) {
        Ok(_) => {
            *cursor = next;
            Step::Progress
        }
        // A replay gap (a record starting beyond the local high-water
        // mark) means this engine predates the cursor — the snapshot
        // and log diverged, e.g. across a primary wipe. Re-bootstrap.
        Err(_) => Step::Gap,
    }
}

/// Fetches a fresh snapshot and swaps it into the serving slot.
/// Returns the new tail cursor, or `None` when the bootstrap failed or
/// the primary serves without a WAL.
fn rebootstrap(cfg: &TailCfg, conn: &mut Option<Conn>) -> Option<WalCursor> {
    let c = ensure_conn(conn, &cfg.primary)?;
    match fetch_snapshot(c, &cfg.local_snapshot, cfg.mmap) {
        Ok((engine, cursor, primary_n)) => {
            engine.set_merge_threshold(cfg.slot.current().merge_threshold());
            cfg.state.contact(primary_n);
            cfg.slot.replace(Arc::new(engine));
            cursor
        }
        Err(_) => {
            // A failure mid-payload leaves the stream misaligned; drop
            // the connection either way and retry from scratch.
            *conn = None;
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repl_state_tracks_contact_and_lag_denominator() {
        let st = ReplState::new();
        assert_eq!(st.last_contact_ms(), None, "no contact yet");
        assert_eq!(st.primary_n(), 0);
        st.contact(1234);
        assert_eq!(st.primary_n(), 1234);
        let ms = st.last_contact_ms().expect("contact recorded");
        assert!(ms < 5_000, "fresh contact reads near-zero, got {ms}");
    }

    #[test]
    fn error_replies_decode_in_both_shapes() {
        let legacy = Json::parse(r#"{"error":"boom"}"#).unwrap();
        let err = legacy.get("error").unwrap();
        assert_eq!(error_text(err), "boom");
        assert_eq!(error_code(err), None);
        let structured =
            Json::parse(r#"{"error":{"code":"wal_gap","message":"rotated"},"v":1}"#).unwrap();
        let err = structured.get("error").unwrap();
        assert_eq!(error_text(err), "rotated");
        assert_eq!(error_code(err), Some("wal_gap"));
    }

    #[test]
    fn fetch_headers_parse_and_reject_malformed() {
        let h = Json::parse(
            r#"{"ok":true,"len":64,"records":2,"next_seq":3,"next_off":128,"n":12,"v":1}"#,
        )
        .unwrap();
        assert_eq!(parse_fetch_header(&h), Some((64, 2, 3, 128, 12)));
        let h = Json::parse(r#"{"ok":true,"len":64}"#).unwrap();
        assert_eq!(parse_fetch_header(&h), None);
        let h = Json::parse(r#"{"ok":true,"len":-1,"records":0,"next_seq":0,"next_off":0,"n":0}"#)
            .unwrap();
        assert_eq!(parse_fetch_header(&h), None, "negative lengths rejected");
    }

    #[test]
    fn local_snapshot_path_is_per_process() {
        let p = default_local_snapshot();
        assert!(p.to_string_lossy().contains(&std::process::id().to_string()));
    }
}
