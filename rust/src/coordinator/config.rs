//! Serving configuration.

use crate::store::{WalCursor, WalSync};

/// Parameters of the query service.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Number of index shards (each gets its own worker thread).
    pub shards: usize,
    /// Dynamic batcher: flush when this many requests are queued…
    pub max_batch: usize,
    /// …or when the oldest queued request is this old (microseconds).
    pub max_delay_us: u64,
    /// Default Hamming threshold when a request omits `tau`.
    pub default_tau: usize,
    /// Active-delta row count that triggers a background shard merge
    /// (`usize::MAX` disables auto-merging; the `merge` op still works).
    pub merge_threshold: usize,
    /// Block width for multi-query execution: compatible queries (same
    /// τ, same mode) in a batch are grouped into blocks of at most this
    /// many and share one pass over each shard's trie and plane-word
    /// stream. `1` disables blocking (serial per-query execution);
    /// widths above 64 are clamped to the kernel's 64-query live mask.
    pub block_width: usize,
    /// Serving load mode for snapshots: when `true`, `reload` ops map
    /// the snapshot read-only and serve immutable segments zero-copy
    /// from the mapping ([`super::engine::Engine::load_with`]); when
    /// `false` (default) snapshots load fully owned. The initial
    /// engine is loaded by the caller — this field governs reloads.
    pub mmap: bool,
    /// Write-ahead-log segment base path (`--wal`). `None` serves
    /// without durability: acknowledged writes live only in memory
    /// until an explicit save. The caller attaches the WAL to the
    /// engine before serving ([`super::engine::Engine::attach_wal`]).
    pub wal: Option<std::path::PathBuf>,
    /// Fsync policy for WAL appends (`--wal-sync`); see the durability
    /// contract in [`crate::store::wal`]. Only meaningful with `wal`.
    pub wal_sync: WalSync,
    /// Group-commit window (`--wal-group-window`), microseconds the
    /// group leader waits for more writers before its fsync. `None`
    /// (auto, the default) enables group commit with no added wait —
    /// coalescing still happens whenever writers queue behind an
    /// in-flight fsync; `Some(0)` disables grouping entirely (every
    /// append fsyncs inline under the insert lock); `Some(us)` trades
    /// that much single-writer latency for bigger groups. Only
    /// meaningful with `wal` under `--wal-sync always`.
    pub wal_group_window: Option<u64>,
    /// Largest accepted request line in bytes (`--max-request-bytes`).
    /// Longer lines are answered with an error (and counted in
    /// `metrics.errors`) without buffering them — one hostile client
    /// cannot OOM the server — and the connection keeps serving.
    pub max_request_bytes: usize,
    /// Follower mode (`--follow HOST:PORT`): the primary this server
    /// replicates from. When set the server is read-only — it answers
    /// every read op and rejects writes with a `read_only` error — and
    /// a replication thread tails the primary's WAL. Mutually exclusive
    /// with `wal` (a follower's durability is its primary's).
    pub follow: Option<String>,
    /// How long the replication thread sleeps between `wal.fetch` polls
    /// that returned no new records (`--follow-poll-ms`).
    pub follow_poll_ms: u64,
    /// Where the replication tail starts: the cursor returned by the
    /// bootstrap snapshot fetch. Set by the `serve --follow` startup
    /// path, not a CLI flag.
    pub follow_cursor: Option<WalCursor>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            shards: 4,
            max_batch: 32,
            max_delay_us: 200,
            default_tau: 2,
            merge_threshold: 4096,
            block_width: 8,
            mmap: false,
            wal: None,
            wal_sync: WalSync::Always,
            wal_group_window: None,
            max_request_bytes: 16 << 20,
            follow: None,
            follow_poll_ms: 200,
            follow_cursor: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = ServeConfig::default();
        assert!(c.shards >= 1);
        assert!(c.max_batch >= 1);
        assert!(c.block_width >= 1);
    }
}
