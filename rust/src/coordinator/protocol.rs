//! Wire protocol: line-delimited JSON over TCP.
//!
//! Requests:
//! ```text
//! {"op":"search","q":[0,1,2,3],"tau":2}
//! {"op":"count","q":[0,1,2,3],"tau":2}
//! {"op":"topk","q":[0,1,2,3],"k":5,"tau":4}
//! {"op":"insert","rows":[[0,1,2,3],[3,2,1,0]]}
//! {"op":"delete","id":17}
//! {"op":"merge"}
//! {"op":"stats"}
//! {"op":"ping"}
//! {"op":"save","path":"/path/to/engine.snap"}
//! {"op":"reload","path":"/path/to/engine.snap"}
//! {"op":"shutdown"}
//! ```
//! Responses (one line each):
//! ```text
//! {"ids":[5,17],"latency_us":123}
//! {"count":2,"latency_us":87}
//! {"ids":[5,17],"dists":[0,2],"latency_us":140}
//! {"ok":true,"first_id":1000,"inserted":2,"latency_us":95}
//! {"ok":true,"deleted":true,"latency_us":12}
//! {"ok":true,"merged":4,"skipped":0,"latency_us":5100}
//! {"queries":...,"p50_latency_us":...}
//! {"pong":true}
//! {"ok":true}
//! {"error":"..."}
//! ```
//!
//! `tau` is optional everywhere: `search`/`count` fall back to the
//! server's default threshold, `topk` to the sketch length (an unbounded
//! nearest-neighbor query). `topk` results are sorted by `(dist, id)`.
//!
//! Write ops: `insert` appends rows (consecutive global ids, returned
//! via `first_id`), `delete` tombstones one id, `merge` force-folds
//! every shard's delta into a fresh immutable segment. `save` writes a
//! snapshot of the serving engine (atomic: tmp file + fsync + rename).
//!
//! **Durability contract.** When the server runs with `--wal <base>`,
//! every `insert`/`delete` is appended to the write-ahead log — fsync'd
//! per `--wal-sync` — *before* it is applied or acknowledged: under
//! `--wal-sync always`, an acknowledged write survives `kill -9` and is
//! replayed on the next start from snapshot + log; under `batch` the
//! tail since the last 256 KiB sync boundary may be lost; under `off`
//! the OS page cache decides. A write that was never acknowledged is at
//! worst a torn tail record, which replay truncates at a record
//! boundary — never a parse error, never a partially applied batch.
//! `save` rotates the log (old segments are deleted only after the
//! snapshot durably renames into place), bounding replay time. Without
//! `--wal`, acknowledged writes live in memory until an explicit
//! `save`. The `stats` op reports `worker_restarts` (shards rebuilt
//! from snapshot + log after an isolated panic) and, for `--mmap`
//! engines, `mapped_bytes`/`resident_bytes` (page-cache residency of
//! the serving snapshot; `null` when not mapped).
//!
//! **Block execution.** The server's batcher groups compatible queries
//! — same `tau` and the same mode (`search` / `count` / `topk` with the
//! same `k`) — into blocks of at most `--block-width` (default 8, max
//! 64) and executes each block as one pass over every shard's trie and
//! plane-word stream. This is invisible on the wire: results (ids,
//! counts, top-k order by `(dist, id)`) are byte-identical to serial
//! execution, and `--block-width 1` disables blocking entirely. The
//! `latency_us` a blocked query reports is its share of the block's
//! wall time, attributed by live work: each query's visited + pruned
//! node count across all shards, an equal split when the block did no
//! work. The same rule feeds the `stats` op's latency percentiles.

use crate::util::json::Json;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Search { q: Vec<u8>, tau: Option<usize> },
    Count { q: Vec<u8>, tau: Option<usize> },
    TopK { q: Vec<u8>, k: usize, tau: Option<usize> },
    /// Append rows to the serving engine's delta segments.
    Insert { rows: Vec<Vec<u8>> },
    /// Tombstone one global id.
    Delete { id: u32 },
    /// Force-fold every shard's delta into its base segment.
    Merge,
    /// Write a snapshot of the serving engine (rotates the WAL).
    Save { path: String },
    /// Swap the serving engine for one loaded from a snapshot file.
    Reload { path: String },
    Stats,
    Ping,
    Shutdown,
}

/// Decodes one array of sketch characters.
fn parse_chars(arr: &[Json], what: &str) -> Result<Vec<u8>, String> {
    arr.iter()
        .map(|x| {
            x.as_f64()
                .filter(|&f| f.fract() == 0.0 && (0.0..256.0).contains(&f))
                .map(|f| f as u8)
                .ok_or_else(|| format!("{what} entries must be chars 0..256"))
        })
        .collect()
}

/// Extracts the query characters from a request body.
fn parse_q(v: &Json) -> Result<Vec<u8>, String> {
    let arr = v
        .get("q")
        .and_then(|q| q.as_arr())
        .ok_or_else(|| "request requires 'q' array".to_string())?;
    parse_chars(arr, "q")
}

/// Parses one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line.trim()).map_err(|e| e.to_string())?;
    let op = v
        .get("op")
        .and_then(|o| o.as_str())
        .ok_or_else(|| "missing 'op'".to_string())?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "search" => {
            let q = parse_q(&v)?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::Search { q, tau })
        }
        "count" => {
            let q = parse_q(&v)?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::Count { q, tau })
        }
        "topk" => {
            let q = parse_q(&v)?;
            let k = v
                .get("k")
                .and_then(|k| k.as_usize())
                .filter(|&k| k >= 1)
                .ok_or_else(|| "topk requires 'k' >= 1".to_string())?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::TopK { q, k, tau })
        }
        "insert" => {
            let rows = v
                .get("rows")
                .and_then(|r| r.as_arr())
                .filter(|r| !r.is_empty())
                .ok_or_else(|| "insert requires a non-empty 'rows' array".to_string())?
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| "insert rows must be arrays".to_string())
                        .and_then(|arr| parse_chars(arr, "rows"))
                })
                .collect::<Result<Vec<Vec<u8>>, String>>()?;
            Ok(Request::Insert { rows })
        }
        "delete" => {
            let id = v
                .get("id")
                .and_then(|i| i.as_f64())
                .filter(|&f| f.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&f))
                .ok_or_else(|| "delete requires an 'id' in 0..2^32".to_string())?;
            Ok(Request::Delete { id: id as u32 })
        }
        "merge" => Ok(Request::Merge),
        "save" => {
            let path = v
                .get("path")
                .and_then(|p| p.as_str())
                .filter(|p| !p.is_empty())
                .ok_or_else(|| "save requires a non-empty 'path'".to_string())?;
            Ok(Request::Save { path: path.to_string() })
        }
        "reload" => {
            let path = v
                .get("path")
                .and_then(|p| p.as_str())
                .filter(|p| !p.is_empty())
                .ok_or_else(|| "reload requires a non-empty 'path'".to_string())?;
            Ok(Request::Reload { path: path.to_string() })
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

/// Encodes a search response.
pub fn search_response(ids: &[u32], latency_us: u64) -> String {
    Json::obj(vec![
        ("ids", Json::ids(ids)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes a count response.
pub fn count_response(count: usize, latency_us: u64) -> String {
    Json::obj(vec![
        ("count", Json::num(count as f64)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes a top-k response: parallel `ids` / `dists` arrays sorted by
/// `(dist, id)`.
pub fn topk_response(hits: &[(u32, usize)], latency_us: u64) -> String {
    Json::obj(vec![
        (
            "ids",
            Json::Arr(hits.iter().map(|&(id, _)| Json::Num(id as f64)).collect()),
        ),
        (
            "dists",
            Json::Arr(hits.iter().map(|&(_, d)| Json::Num(d as f64)).collect()),
        ),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes an insert response: the first assigned global id (the batch
/// gets consecutive ids) and the row count.
pub fn insert_response(first_id: u32, inserted: usize, latency_us: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("first_id", Json::num(first_id as f64)),
        ("inserted", Json::num(inserted as f64)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes a delete response (`deleted` is false for unknown or
/// already-tombstoned ids).
pub fn delete_response(deleted: bool, latency_us: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("deleted", Json::Bool(deleted)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes a merge response: shards now all-immutable vs legacy shards
/// that had nothing to fold into.
pub fn merge_response(merged: usize, skipped: usize, latency_us: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("merged", Json::num(merged as f64)),
        ("skipped", Json::num(skipped as f64)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes a save response: the rows captured by the snapshot.
pub fn save_response(n: usize, latency_us: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("n", Json::num(n as f64)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes an error response.
pub fn error_response(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// Encodes a successful reload: the snapshot path now serving plus the
/// new engine's shape.
pub fn reload_response(n: usize, shards: usize, latency_us: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("n", Json::num(n as f64)),
        ("shards", Json::num(shards as f64)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_search() {
        let r = parse_request(r#"{"op":"search","q":[0,3,1],"tau":2}"#).unwrap();
        assert_eq!(r, Request::Search { q: vec![0, 3, 1], tau: Some(2) });
        let r = parse_request(r#"{"op":"search","q":[255]}"#).unwrap();
        assert_eq!(r, Request::Search { q: vec![255], tau: None });
    }

    #[test]
    fn parses_count_and_topk() {
        let r = parse_request(r#"{"op":"count","q":[1,2],"tau":3}"#).unwrap();
        assert_eq!(r, Request::Count { q: vec![1, 2], tau: Some(3) });
        let r = parse_request(r#"{"op":"topk","q":[1,2],"k":5}"#).unwrap();
        assert_eq!(r, Request::TopK { q: vec![1, 2], k: 5, tau: None });
        let r = parse_request(r#"{"op":"topk","q":[0],"k":1,"tau":2}"#).unwrap();
        assert_eq!(r, Request::TopK { q: vec![0], k: 1, tau: Some(2) });
    }

    #[test]
    fn parses_control_ops() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
        assert_eq!(
            parse_request(r#"{"op":"reload","path":"/tmp/e.snap"}"#).unwrap(),
            Request::Reload { path: "/tmp/e.snap".into() }
        );
        assert!(parse_request(r#"{"op":"reload"}"#).is_err());
        assert!(parse_request(r#"{"op":"reload","path":""}"#).is_err());
        assert_eq!(
            parse_request(r#"{"op":"save","path":"/tmp/e.snap"}"#).unwrap(),
            Request::Save { path: "/tmp/e.snap".into() }
        );
        assert!(parse_request(r#"{"op":"save"}"#).is_err());
        assert!(parse_request(r#"{"op":"save","path":""}"#).is_err());
    }

    #[test]
    fn parses_write_ops() {
        let r = parse_request(r#"{"op":"insert","rows":[[0,1],[3,2]]}"#).unwrap();
        assert_eq!(r, Request::Insert { rows: vec![vec![0, 1], vec![3, 2]] });
        let r = parse_request(r#"{"op":"delete","id":17}"#).unwrap();
        assert_eq!(r, Request::Delete { id: 17 });
        assert_eq!(parse_request(r#"{"op":"merge"}"#).unwrap(), Request::Merge);
        assert!(parse_request(r#"{"op":"insert"}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","rows":[]}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","rows":[3]}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","rows":[[300]]}"#).is_err());
        assert!(parse_request(r#"{"op":"delete"}"#).is_err());
        assert!(parse_request(r#"{"op":"delete","id":-1}"#).is_err());
        assert!(parse_request(r#"{"op":"delete","id":1.5}"#).is_err());
    }

    #[test]
    fn write_responses_are_valid_json() {
        let i = insert_response(1000, 2, 95);
        let v = Json::parse(&i).unwrap();
        assert_eq!(v.get("first_id").and_then(|x| x.as_usize()), Some(1000));
        assert_eq!(v.get("inserted").and_then(|x| x.as_usize()), Some(2));
        let d = delete_response(true, 12);
        let v = Json::parse(&d).unwrap();
        assert_eq!(v.get("deleted").and_then(|x| x.as_bool()), Some(true));
        let m = merge_response(4, 1, 5100);
        let v = Json::parse(&m).unwrap();
        assert_eq!(v.get("merged").and_then(|x| x.as_usize()), Some(4));
        assert_eq!(v.get("skipped").and_then(|x| x.as_usize()), Some(1));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"search"}"#).is_err());
        assert!(parse_request(r#"{"op":"search","q":[300]}"#).is_err());
        assert!(parse_request(r#"{"op":"search","q":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"op":"count"}"#).is_err());
        assert!(parse_request(r#"{"op":"topk","q":[1]}"#).is_err());
        assert!(parse_request(r#"{"op":"topk","q":[1],"k":0}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        let s = search_response(&[1, 2, 3], 42);
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("ids").unwrap().as_arr().unwrap().len(), 3);
        let c = count_response(7, 10);
        assert_eq!(Json::parse(&c).unwrap().get("count").unwrap().as_usize(), Some(7));
        let t = topk_response(&[(5, 0), (17, 2)], 140);
        let tv = Json::parse(&t).unwrap();
        assert_eq!(tv.get("ids").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(tv.get("dists").unwrap().as_arr().unwrap().len(), 2);
        let e = error_response("bad");
        assert!(Json::parse(&e).unwrap().get("error").is_some());
        let rl = reload_response(1000, 4, 12);
        let v = Json::parse(&rl).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v.get("shards").and_then(|s| s.as_usize()), Some(4));
        let sv = save_response(1000, 88);
        let v = Json::parse(&sv).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v.get("n").and_then(|n| n.as_usize()), Some(1000));
    }
}
