//! Wire protocol: line-delimited JSON over TCP.
//!
//! Requests:
//! ```text
//! {"op":"search","q":[0,1,2,3],"tau":2}
//! {"op":"stats"}
//! {"op":"ping"}
//! {"op":"shutdown"}
//! ```
//! Responses (one line each):
//! ```text
//! {"ids":[5,17],"latency_us":123}
//! {"queries":...,"p50_latency_us":...}
//! {"pong":true}
//! {"ok":true}
//! {"error":"..."}
//! ```

use crate::util::json::Json;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Search { q: Vec<u8>, tau: Option<usize> },
    Stats,
    Ping,
    Shutdown,
}

/// Parses one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line.trim()).map_err(|e| e.to_string())?;
    let op = v
        .get("op")
        .and_then(|o| o.as_str())
        .ok_or_else(|| "missing 'op'".to_string())?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "search" => {
            let q = v
                .get("q")
                .and_then(|q| q.as_arr())
                .ok_or_else(|| "search requires 'q' array".to_string())?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|&f| f.fract() == 0.0 && (0.0..256.0).contains(&f))
                        .map(|f| f as u8)
                        .ok_or_else(|| "q entries must be chars 0..256".to_string())
                })
                .collect::<Result<Vec<u8>, _>>()?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::Search { q, tau })
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

/// Encodes a search response.
pub fn search_response(ids: &[u32], latency_us: u64) -> String {
    Json::obj(vec![
        ("ids", Json::ids(ids)),
        ("latency_us", Json::num(latency_us as f64)),
    ])
    .to_string()
}

/// Encodes an error response.
pub fn error_response(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_search() {
        let r = parse_request(r#"{"op":"search","q":[0,3,1],"tau":2}"#).unwrap();
        assert_eq!(r, Request::Search { q: vec![0, 3, 1], tau: Some(2) });
        let r = parse_request(r#"{"op":"search","q":[255]}"#).unwrap();
        assert_eq!(r, Request::Search { q: vec![255], tau: None });
    }

    #[test]
    fn parses_control_ops() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"search"}"#).is_err());
        assert!(parse_request(r#"{"op":"search","q":[300]}"#).is_err());
        assert!(parse_request(r#"{"op":"search","q":[1.5]}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        let s = search_response(&[1, 2, 3], 42);
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("ids").unwrap().as_arr().unwrap().len(), 3);
        let e = error_response("bad");
        assert!(Json::parse(&e).unwrap().get("error").is_some());
    }
}
