//! Wire protocol: line-delimited JSON over TCP, versioned.
//!
//! ## Versioning
//!
//! Every request may carry an optional `"v"` field naming the protocol
//! version it speaks. The negotiation rule:
//!
//! * **`v` absent** — the request is treated as version 1 *and* the
//!   response uses the legacy (pre-versioning) shapes: no `"v"` field
//!   and errors as a bare string (`{"error":"..."}`). Every client
//!   written against the original protocol keeps working unchanged.
//! * **`v` present and equal to [`PROTOCOL_VERSION`]** — the response
//!   carries `"v"` back and errors are structured objects (see below).
//! * **`v` present and anything else** — the request is not executed;
//!   the server answers a structured `unsupported_version` error
//!   stamped with the version it does speak, so a newer client can
//!   detect the mismatch and downgrade.
//!
//! ## Structured errors
//!
//! For `v`-bearing requests, errors are
//! `{"error":{"code":"...","message":"..."},"v":1}` where `code` is one
//! of the machine-readable [`ErrorCode`] values:
//!
//! | code                  | meaning                                            |
//! |-----------------------|----------------------------------------------------|
//! | `bad_request`         | malformed JSON, missing/invalid fields, bad rows   |
//! | `unsupported_op`      | unknown `"op"`                                     |
//! | `unsupported_version` | `"v"` names a version this server does not speak   |
//! | `read_only`           | write op sent to a follower (`--follow`)           |
//! | `shard_failed`        | a shard worker is dead; the answer would be partial|
//! | `wal_gap`             | `wal.fetch` cursor was rotated away; re-bootstrap  |
//! | `no_wal`              | `wal.fetch`/replication op but server has no `--wal`|
//! | `io`                  | snapshot save/load or log I/O failed               |
//!
//! `v`-absent requests get the same message as a bare string.
//!
//! ## Wire-API reference
//!
//! | op               | request fields                        | success response fields                          | error codes                          | since |
//! |------------------|---------------------------------------|--------------------------------------------------|--------------------------------------|-------|
//! | `search`         | `q`, `tau`?                           | `ids`, `latency_us`                              | `bad_request`, `shard_failed`        | 1     |
//! | `count`          | `q`, `tau`?                           | `count`, `latency_us`                            | `bad_request`, `shard_failed`        | 1     |
//! | `topk`           | `q`, `k`, `tau`?                      | `ids`, `dists`, `latency_us`                     | `bad_request`, `shard_failed`        | 1     |
//! | `insert`         | `rows`                                | `ok`, `first_id`, `inserted`, `latency_us`       | `bad_request`, `read_only`           | 1     |
//! | `delete`         | `id`                                  | `ok`, `deleted`, `latency_us`                    | `bad_request`, `read_only`           | 1     |
//! | `merge`          |                                       | `ok`, `merged`, `skipped`, `latency_us`          | `read_only`                          | 1     |
//! | `save`           | `path`                                | `ok`, `n`, `latency_us`                          | `bad_request`, `read_only`, `io`     | 1     |
//! | `reload`         | `path`                                | `ok`, `n`, `shards`, `latency_us`                | `bad_request`, `read_only`, `io`     | 1     |
//! | `stats`          |                                       | counters, latency percentiles, `shards_parked`   |                                      | 1     |
//! | `ping`           |                                       | `pong`                                           |                                      | 1     |
//! | `shutdown`       |                                       | `ok`                                             |                                      | 1     |
//! | `snapshot.fetch` |                                       | header `ok`,`len`,`n`,`wal_seq`,`wal_off` + bytes| `read_only`, `io`                    | 1     |
//! | `wal.fetch`      | `from_seq`?, `from_off`?, `max_bytes`?| header `ok`,`len`,`records`,`next_seq`,`next_off`,`n` + bytes | `bad_request`, `wal_gap`, `no_wal`, `io` | 1 |
//! | `repl.status`    |                                       | `role`, `applied_id`, `lag_records`, `last_contact_ms` | | 1 |
//!
//! `tau` is optional everywhere: `search`/`count` fall back to the
//! server's default threshold, `topk` to the sketch length (an unbounded
//! nearest-neighbor query). `topk` results are sorted by `(dist, id)`.
//!
//! ## Streaming ops and replication
//!
//! `snapshot.fetch` and `wal.fetch` are the only responses that are not
//! a single JSON line: the server writes one JSON header line whose
//! `len` field gives an exact byte count, then `len` raw bytes on the
//! same stream. `snapshot.fetch` streams a complete snapshot container
//! (written with the same atomic fence as `save`, so it rotates the
//! primary's WAL and reports the post-rotation cursor in
//! `wal_seq`/`wal_off`). `wal.fetch` streams raw log frames — length
//! prefix, FNV-1a checksum, payload, exactly as on disk — from the
//! cursor `(from_seq, from_off)` forward, plus the cursor for the next
//! fetch; the receiver re-verifies every checksum before applying. A
//! follower (`bst serve --follow HOST:PORT`) bootstraps via
//! `snapshot.fetch`, tails via `wal.fetch`, answers every read op
//! identically to its primary, rejects writes with `read_only`, and on
//! `wal_gap` (the primary rotated past its cursor) re-bootstraps from a
//! fresh snapshot. `repl.status` reports the replication role and lag
//! on both sides.
//!
//! Write ops: `insert` appends rows (consecutive global ids, returned
//! via `first_id`), `delete` tombstones one id, `merge` force-folds
//! every shard's delta into a fresh immutable segment. `save` writes a
//! snapshot of the serving engine (atomic: tmp file + fsync + rename).
//!
//! **Durability contract.** When the server runs with `--wal <base>`,
//! every `insert`/`delete` is appended to the write-ahead log — fsync'd
//! per `--wal-sync` — *before* it is applied or acknowledged: under
//! `--wal-sync always`, an acknowledged write survives `kill -9` and is
//! replayed on the next start from snapshot + log; under `batch` the
//! tail since the last 256 KiB sync boundary may be lost; under `off`
//! the OS page cache decides. A write that was never acknowledged is at
//! worst a torn tail record, which replay truncates at a record
//! boundary — never a parse error, never a partially applied batch.
//! `save` rotates the log (old segments are deleted only after the
//! snapshot durably renames into place), bounding replay time. Without
//! `--wal`, acknowledged writes live in memory until an explicit
//! `save`.
//!
//! Under `--wal-sync always`, concurrent writers *group-commit*
//! (`--wal-group-window auto|0|USECS`, default `auto`; `0` reverts to
//! one fsync per write): records buffer in log order, one writer fsyncs
//! for the whole group, and every write blocks until the durability
//! watermark covers its record — so the per-write guarantee above is
//! unchanged, only the fsync count shrinks. A failed group fsync fails
//! every write in the group with `wal group fsync failed; write not
//! acknowledged`, and the failed span is re-staged for the next group's
//! fsync so the log's id sequence stays replayable: a NACKed write may
//! still reach disk (a false NACK, which replication and replay
//! tolerate), but an acknowledged write is always durable. `repl.status`
//! on a primary reports the durable watermark, not the buffered tail,
//! and `wal.fetch` never streams past it — a follower cannot apply a
//! record its primary has not acknowledged. The `stats` op reports
//! `worker_restarts` (shards rebuilt from snapshot + log after an
//! isolated panic), `shards_parked` (shards taken out of service after
//! exhausting their restart budget), `wal_fsyncs`/`wal_group_records`
//! (write-ack fsyncs and the records they covered; their ratio is the
//! group-commit coalescing factor), and, for `--mmap` engines,
//! `mapped_bytes`/`resident_bytes`/`advised_bytes` (page-cache
//! residency of the serving snapshot and the bytes covered by `madvise`
//! hints at load; `null` when not mapped).
//!
//! **Block execution.** The server's batcher groups compatible queries
//! — same `tau` and the same mode (`search` / `count` / `topk` with the
//! same `k`) — into blocks of at most `--block-width` (default 8, max
//! 64) and executes each block as one pass over every shard's trie and
//! plane-word stream. This is invisible on the wire: results (ids,
//! counts, top-k order by `(dist, id)`) are byte-identical to serial
//! execution, and `--block-width 1` disables blocking entirely. The
//! `latency_us` a blocked query reports is its share of the block's
//! wall time, attributed by live work: each query's visited + pruned
//! node count across all shards, an equal split when the block did no
//! work. The same rule feeds the `stats` op's latency percentiles.

use crate::util::json::Json;

/// The protocol version this build speaks (and the only one so far).
pub const PROTOCOL_VERSION: u64 = 1;

/// Default `wal.fetch` budget when the client names none.
pub const DEFAULT_FETCH_BYTES: usize = 1 << 20;

/// Smallest accepted `wal.fetch` budget (smaller values are clamped up;
/// a single frame always goes through regardless).
pub const MIN_FETCH_BYTES: usize = 1024;

/// Largest accepted `wal.fetch` budget (larger values are clamped down
/// so one fetch cannot buffer unbounded bytes server-side).
pub const MAX_FETCH_BYTES: usize = 64 << 20;

/// Machine-readable error category carried by structured errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    BadRequest,
    UnsupportedOp,
    UnsupportedVersion,
    ReadOnly,
    ShardFailed,
    WalGap,
    NoWal,
    Io,
}

impl ErrorCode {
    /// Every defined code, in documentation order.
    pub const ALL: [ErrorCode; 8] = [
        ErrorCode::BadRequest,
        ErrorCode::UnsupportedOp,
        ErrorCode::UnsupportedVersion,
        ErrorCode::ReadOnly,
        ErrorCode::ShardFailed,
        ErrorCode::WalGap,
        ErrorCode::NoWal,
        ErrorCode::Io,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnsupportedOp => "unsupported_op",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::ReadOnly => "read_only",
            ErrorCode::ShardFailed => "shard_failed",
            ErrorCode::WalGap => "wal_gap",
            ErrorCode::NoWal => "no_wal",
            ErrorCode::Io => "io",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.as_str() == s)
    }
}

/// A structured wire error: category plus human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError { code, message: message.into() }
    }
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Search { q: Vec<u8>, tau: Option<usize> },
    Count { q: Vec<u8>, tau: Option<usize> },
    TopK { q: Vec<u8>, k: usize, tau: Option<usize> },
    /// Append rows to the serving engine's delta segments.
    Insert { rows: Vec<Vec<u8>> },
    /// Tombstone one global id.
    Delete { id: u32 },
    /// Force-fold every shard's delta into its base segment.
    Merge,
    /// Write a snapshot of the serving engine (rotates the WAL).
    Save { path: String },
    /// Swap the serving engine for one loaded from a snapshot file.
    Reload { path: String },
    /// Stream a snapshot of the serving engine to the client
    /// (replication bootstrap).
    SnapshotFetch,
    /// Stream raw WAL frames from a cursor forward (replication tail).
    WalFetch { from_seq: u64, from_off: u64, max_bytes: usize },
    /// Report replication role and lag.
    ReplStatus,
    Stats,
    Ping,
    Shutdown,
}

/// The outcome of parsing one request line: the version the client
/// declared (`None` = legacy, pre-versioning shapes) plus the request
/// or a structured error. The server threads `v` into every response
/// builder so the reply shape matches what the client speaks.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedRequest {
    pub v: Option<u64>,
    pub result: Result<Request, WireError>,
}

/// Decodes one array of sketch characters.
fn parse_chars(arr: &[Json], what: &str) -> Result<Vec<u8>, String> {
    arr.iter()
        .map(|x| {
            x.as_f64()
                .filter(|&f| f.fract() == 0.0 && (0.0..256.0).contains(&f))
                .map(|f| f as u8)
                .ok_or_else(|| format!("{what} entries must be chars 0..256"))
        })
        .collect()
}

/// Extracts the query characters from a request body.
fn parse_q(v: &Json) -> Result<Vec<u8>, String> {
    let arr = v
        .get("q")
        .and_then(|q| q.as_arr())
        .ok_or_else(|| "request requires 'q' array".to_string())?;
    parse_chars(arr, "q")
}

/// Reads an optional non-negative integer field.
fn parse_u64_field(v: &Json, key: &str) -> Result<Option<u64>, WireError> {
    match v.get(key) {
        None => Ok(None),
        Some(j) => j
            .as_f64()
            .filter(|f| f.fract() == 0.0 && *f >= 0.0 && *f <= u64::MAX as f64)
            .map(|f| Some(f as u64))
            .ok_or_else(|| {
                WireError::new(
                    ErrorCode::BadRequest,
                    format!("'{key}' must be a non-negative integer"),
                )
            }),
    }
}

/// Parses one request line, negotiating the protocol version (see the
/// module docs for the rule).
pub fn parse_request_line(line: &str) -> ParsedRequest {
    let body = match Json::parse(line.trim()) {
        Ok(v) => v,
        Err(e) => {
            return ParsedRequest {
                v: None,
                result: Err(WireError::new(ErrorCode::BadRequest, e.to_string())),
            }
        }
    };
    let v = match body.get("v") {
        None => None,
        Some(j) => match j.as_f64().filter(|f| f.fract() == 0.0 && *f >= 0.0) {
            Some(f) => Some(f as u64),
            None => {
                // An unintelligible 'v' gets the legacy error shape:
                // we cannot tell what the client speaks.
                return ParsedRequest {
                    v: None,
                    result: Err(WireError::new(
                        ErrorCode::BadRequest,
                        "'v' must be a non-negative integer",
                    )),
                };
            }
        },
    };
    if let Some(n) = v {
        if n != PROTOCOL_VERSION {
            return ParsedRequest {
                v: Some(n),
                result: Err(WireError::new(
                    ErrorCode::UnsupportedVersion,
                    format!(
                        "protocol version {n} is not supported \
                         (this server speaks {PROTOCOL_VERSION})"
                    ),
                )),
            };
        }
    }
    ParsedRequest { v, result: parse_body(&body) }
}

/// Parses the request body, version questions already settled.
fn parse_body(v: &Json) -> Result<Request, WireError> {
    let bad = |m: String| WireError::new(ErrorCode::BadRequest, m);
    let op = v
        .get("op")
        .and_then(|o| o.as_str())
        .ok_or_else(|| bad("missing 'op'".to_string()))?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "search" => {
            let q = parse_q(v).map_err(bad)?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::Search { q, tau })
        }
        "count" => {
            let q = parse_q(v).map_err(bad)?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::Count { q, tau })
        }
        "topk" => {
            let q = parse_q(v).map_err(bad)?;
            let k = v
                .get("k")
                .and_then(|k| k.as_usize())
                .filter(|&k| k >= 1)
                .ok_or_else(|| bad("topk requires 'k' >= 1".to_string()))?;
            let tau = v.get("tau").and_then(|t| t.as_usize());
            Ok(Request::TopK { q, k, tau })
        }
        "insert" => {
            let rows = v
                .get("rows")
                .and_then(|r| r.as_arr())
                .filter(|r| !r.is_empty())
                .ok_or_else(|| bad("insert requires a non-empty 'rows' array".to_string()))?
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| "insert rows must be arrays".to_string())
                        .and_then(|arr| parse_chars(arr, "rows"))
                })
                .collect::<Result<Vec<Vec<u8>>, String>>()
                .map_err(bad)?;
            Ok(Request::Insert { rows })
        }
        "delete" => {
            let id = v
                .get("id")
                .and_then(|i| i.as_f64())
                .filter(|&f| f.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&f))
                .ok_or_else(|| bad("delete requires an 'id' in 0..2^32".to_string()))?;
            Ok(Request::Delete { id: id as u32 })
        }
        "merge" => Ok(Request::Merge),
        "save" => {
            let path = v
                .get("path")
                .and_then(|p| p.as_str())
                .filter(|p| !p.is_empty())
                .ok_or_else(|| bad("save requires a non-empty 'path'".to_string()))?;
            Ok(Request::Save { path: path.to_string() })
        }
        "reload" => {
            let path = v
                .get("path")
                .and_then(|p| p.as_str())
                .filter(|p| !p.is_empty())
                .ok_or_else(|| bad("reload requires a non-empty 'path'".to_string()))?;
            Ok(Request::Reload { path: path.to_string() })
        }
        "snapshot.fetch" => Ok(Request::SnapshotFetch),
        "wal.fetch" => {
            let from_seq = parse_u64_field(v, "from_seq")?.unwrap_or(0);
            let from_off = parse_u64_field(v, "from_off")?.unwrap_or(0);
            let max_bytes = parse_u64_field(v, "max_bytes")?
                .map(|m| (m.min(MAX_FETCH_BYTES as u64) as usize).max(MIN_FETCH_BYTES))
                .unwrap_or(DEFAULT_FETCH_BYTES);
            Ok(Request::WalFetch { from_seq, from_off, max_bytes })
        }
        "repl.status" => Ok(Request::ReplStatus),
        other => Err(WireError::new(
            ErrorCode::UnsupportedOp,
            format!("unknown op '{other}'"),
        )),
    }
}

/// Legacy entry point: parses a request, flattening structured errors
/// to their message (the pre-versioning contract).
pub fn parse_request(line: &str) -> Result<Request, String> {
    parse_request_line(line).result.map_err(|e| e.message)
}

/// Serializes a response body, stamping `"v"` for version-bearing
/// requests (legacy requests get the body untouched).
pub fn respond(body: Json, v: Option<u64>) -> String {
    match (body, v) {
        (Json::Obj(mut m), Some(_)) => {
            m.insert("v".to_string(), Json::num(PROTOCOL_VERSION as f64));
            Json::Obj(m).to_string()
        }
        (body, _) => body.to_string(),
    }
}

/// Encodes a search response.
pub fn search_response(ids: &[u32], latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("ids", Json::ids(ids)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a count response.
pub fn count_response(count: usize, latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("count", Json::num(count as f64)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a top-k response: parallel `ids` / `dists` arrays sorted by
/// `(dist, id)`.
pub fn topk_response(hits: &[(u32, usize)], latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            (
                "ids",
                Json::Arr(hits.iter().map(|&(id, _)| Json::Num(id as f64)).collect()),
            ),
            (
                "dists",
                Json::Arr(hits.iter().map(|&(_, d)| Json::Num(d as f64)).collect()),
            ),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes an insert response: the first assigned global id (the batch
/// gets consecutive ids) and the row count.
pub fn insert_response(first_id: u32, inserted: usize, latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("first_id", Json::num(first_id as f64)),
            ("inserted", Json::num(inserted as f64)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a delete response (`deleted` is false for unknown or
/// already-tombstoned ids).
pub fn delete_response(deleted: bool, latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("deleted", Json::Bool(deleted)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a merge response: shards now all-immutable vs legacy shards
/// that had nothing to fold into.
pub fn merge_response(merged: usize, skipped: usize, latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("merged", Json::num(merged as f64)),
            ("skipped", Json::num(skipped as f64)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a save response: the rows captured by the snapshot.
pub fn save_response(n: usize, latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("n", Json::num(n as f64)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a successful reload: the snapshot path now serving plus the
/// new engine's shape.
pub fn reload_response(n: usize, shards: usize, latency_us: u64, v: Option<u64>) -> String {
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("n", Json::num(n as f64)),
            ("shards", Json::num(shards as f64)),
            ("latency_us", Json::num(latency_us as f64)),
        ]),
        v,
    )
}

/// Encodes a ping response.
pub fn ping_response(v: Option<u64>) -> String {
    respond(Json::obj(vec![("pong", Json::Bool(true))]), v)
}

/// Encodes a bare acknowledgement.
pub fn ok_response(v: Option<u64>) -> String {
    respond(Json::obj(vec![("ok", Json::Bool(true))]), v)
}

/// Encodes an error response: bare string for legacy (`v`-absent)
/// requests, `{code, message}` for version-bearing ones.
pub fn error_response(code: ErrorCode, msg: &str, v: Option<u64>) -> String {
    match v {
        None => Json::obj(vec![("error", Json::str(msg))]).to_string(),
        Some(_) => respond(
            Json::obj(vec![(
                "error",
                Json::obj(vec![
                    ("code", Json::str(code.as_str())),
                    ("message", Json::str(msg)),
                ]),
            )]),
            v,
        ),
    }
}

/// Encodes the `snapshot.fetch` header line: `len` raw container bytes
/// follow on the same stream. `wal` is the primary's post-rotation
/// cursor (`null` fields when the primary serves without `--wal`).
pub fn snapshot_fetch_header(
    len: u64,
    n: usize,
    wal: Option<(u64, u64)>,
    v: Option<u64>,
) -> String {
    let (seq, off) = match wal {
        Some((s, o)) => (Json::num(s as f64), Json::num(o as f64)),
        None => (Json::Null, Json::Null),
    };
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("len", Json::num(len as f64)),
            ("n", Json::num(n as f64)),
            ("wal_seq", seq),
            ("wal_off", off),
        ]),
        v,
    )
}

/// Encodes the `wal.fetch` header line: `len` raw frame bytes follow,
/// holding `records` whole records; the next fetch resumes at
/// `(next_seq, next_off)`. `n` is the primary's current row count, the
/// follower's lag denominator.
pub fn wal_fetch_header(
    len: u64,
    records: usize,
    next_seq: u64,
    next_off: u64,
    n: usize,
    v: Option<u64>,
) -> String {
    respond(
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("len", Json::num(len as f64)),
            ("records", Json::num(records as f64)),
            ("next_seq", Json::num(next_seq as f64)),
            ("next_off", Json::num(next_off as f64)),
            ("n", Json::num(n as f64)),
        ]),
        v,
    )
}

/// Encodes the `repl.status` response.
pub fn repl_status_response(
    role: &str,
    applied_id: u64,
    lag_records: u64,
    last_contact_ms: Option<u64>,
    v: Option<u64>,
) -> String {
    respond(
        Json::obj(vec![
            ("role", Json::str(role)),
            ("applied_id", Json::num(applied_id as f64)),
            ("lag_records", Json::num(lag_records as f64)),
            (
                "last_contact_ms",
                match last_contact_ms {
                    Some(ms) => Json::num(ms as f64),
                    None => Json::Null,
                },
            ),
        ]),
        v,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_search() {
        let r = parse_request(r#"{"op":"search","q":[0,3,1],"tau":2}"#).unwrap();
        assert_eq!(r, Request::Search { q: vec![0, 3, 1], tau: Some(2) });
        let r = parse_request(r#"{"op":"search","q":[255]}"#).unwrap();
        assert_eq!(r, Request::Search { q: vec![255], tau: None });
    }

    #[test]
    fn parses_count_and_topk() {
        let r = parse_request(r#"{"op":"count","q":[1,2],"tau":3}"#).unwrap();
        assert_eq!(r, Request::Count { q: vec![1, 2], tau: Some(3) });
        let r = parse_request(r#"{"op":"topk","q":[1,2],"k":5}"#).unwrap();
        assert_eq!(r, Request::TopK { q: vec![1, 2], k: 5, tau: None });
        let r = parse_request(r#"{"op":"topk","q":[0],"k":1,"tau":2}"#).unwrap();
        assert_eq!(r, Request::TopK { q: vec![0], k: 1, tau: Some(2) });
    }

    #[test]
    fn parses_control_ops() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
        assert_eq!(
            parse_request(r#"{"op":"reload","path":"/tmp/e.snap"}"#).unwrap(),
            Request::Reload { path: "/tmp/e.snap".into() }
        );
        assert!(parse_request(r#"{"op":"reload"}"#).is_err());
        assert!(parse_request(r#"{"op":"reload","path":""}"#).is_err());
        assert_eq!(
            parse_request(r#"{"op":"save","path":"/tmp/e.snap"}"#).unwrap(),
            Request::Save { path: "/tmp/e.snap".into() }
        );
        assert!(parse_request(r#"{"op":"save"}"#).is_err());
        assert!(parse_request(r#"{"op":"save","path":""}"#).is_err());
    }

    #[test]
    fn parses_write_ops() {
        let r = parse_request(r#"{"op":"insert","rows":[[0,1],[3,2]]}"#).unwrap();
        assert_eq!(r, Request::Insert { rows: vec![vec![0, 1], vec![3, 2]] });
        let r = parse_request(r#"{"op":"delete","id":17}"#).unwrap();
        assert_eq!(r, Request::Delete { id: 17 });
        assert_eq!(parse_request(r#"{"op":"merge"}"#).unwrap(), Request::Merge);
        assert!(parse_request(r#"{"op":"insert"}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","rows":[]}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","rows":[3]}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","rows":[[300]]}"#).is_err());
        assert!(parse_request(r#"{"op":"delete"}"#).is_err());
        assert!(parse_request(r#"{"op":"delete","id":-1}"#).is_err());
        assert!(parse_request(r#"{"op":"delete","id":1.5}"#).is_err());
    }

    #[test]
    fn parses_replication_ops() {
        assert_eq!(
            parse_request(r#"{"op":"snapshot.fetch"}"#).unwrap(),
            Request::SnapshotFetch
        );
        assert_eq!(
            parse_request(r#"{"op":"repl.status"}"#).unwrap(),
            Request::ReplStatus
        );
        // Cursor fields default to the origin, budget to the default.
        assert_eq!(
            parse_request(r#"{"op":"wal.fetch"}"#).unwrap(),
            Request::WalFetch { from_seq: 0, from_off: 0, max_bytes: DEFAULT_FETCH_BYTES }
        );
        assert_eq!(
            parse_request(r#"{"op":"wal.fetch","from_seq":3,"from_off":128,"max_bytes":4096}"#)
                .unwrap(),
            Request::WalFetch { from_seq: 3, from_off: 128, max_bytes: 4096 }
        );
        // Budgets clamp into [MIN_FETCH_BYTES, MAX_FETCH_BYTES].
        assert_eq!(
            parse_request(r#"{"op":"wal.fetch","max_bytes":1}"#).unwrap(),
            Request::WalFetch { from_seq: 0, from_off: 0, max_bytes: MIN_FETCH_BYTES }
        );
        assert_eq!(
            parse_request(r#"{"op":"wal.fetch","max_bytes":999999999999}"#).unwrap(),
            Request::WalFetch { from_seq: 0, from_off: 0, max_bytes: MAX_FETCH_BYTES }
        );
        assert!(parse_request(r#"{"op":"wal.fetch","from_seq":-1}"#).is_err());
        assert!(parse_request(r#"{"op":"wal.fetch","from_off":1.5}"#).is_err());
    }

    #[test]
    fn version_negotiation() {
        // Absent v: legacy — no version recorded, request parses.
        let p = parse_request_line(r#"{"op":"ping"}"#);
        assert_eq!(p.v, None);
        assert_eq!(p.result, Ok(Request::Ping));
        // v = current: recorded, request parses.
        let p = parse_request_line(r#"{"op":"ping","v":1}"#);
        assert_eq!(p.v, Some(1));
        assert_eq!(p.result, Ok(Request::Ping));
        // Future version: structured unsupported_version, body unparsed.
        let p = parse_request_line(r#"{"op":"ping","v":2}"#);
        assert_eq!(p.v, Some(2));
        let err = p.result.unwrap_err();
        assert_eq!(err.code, ErrorCode::UnsupportedVersion);
        assert!(err.message.contains("speaks 1"), "{}", err.message);
        // Unintelligible v: legacy-shaped bad_request.
        let p = parse_request_line(r#"{"op":"ping","v":1.5}"#);
        assert_eq!(p.v, None);
        assert_eq!(p.result.unwrap_err().code, ErrorCode::BadRequest);
        let p = parse_request_line(r#"{"op":"ping","v":"one"}"#);
        assert_eq!(p.v, None);
        assert_eq!(p.result.unwrap_err().code, ErrorCode::BadRequest);
    }

    #[test]
    fn error_codes_roundtrip_and_shape_follows_version() {
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code), "{}", code.as_str());
            // Versioned: structured object stamped with the server's v.
            let s = error_response(code, "boom", Some(1));
            let v = Json::parse(&s).unwrap();
            let e = v.get("error").unwrap();
            assert_eq!(e.get("code").and_then(|c| c.as_str()), Some(code.as_str()));
            assert_eq!(e.get("message").and_then(|m| m.as_str()), Some("boom"));
            assert_eq!(v.get("v").and_then(|n| n.as_usize()), Some(1));
            // Legacy: bare string, no v.
            let s = error_response(code, "boom", None);
            let v = Json::parse(&s).unwrap();
            assert_eq!(v.get("error").and_then(|e| e.as_str()), Some("boom"));
            assert!(v.get("v").is_none());
        }
        assert_eq!(ErrorCode::parse("nonsense"), None);
    }

    #[test]
    fn unknown_op_is_unsupported_op() {
        let p = parse_request_line(r#"{"op":"nope","v":1}"#);
        assert_eq!(p.result.unwrap_err().code, ErrorCode::UnsupportedOp);
        // Legacy path flattens to the same message as before.
        assert_eq!(parse_request(r#"{"op":"nope"}"#).unwrap_err(), "unknown op 'nope'");
    }

    #[test]
    fn write_responses_are_valid_json() {
        let i = insert_response(1000, 2, 95, None);
        let v = Json::parse(&i).unwrap();
        assert_eq!(v.get("first_id").and_then(|x| x.as_usize()), Some(1000));
        assert_eq!(v.get("inserted").and_then(|x| x.as_usize()), Some(2));
        let d = delete_response(true, 12, None);
        let v = Json::parse(&d).unwrap();
        assert_eq!(v.get("deleted").and_then(|x| x.as_bool()), Some(true));
        let m = merge_response(4, 1, 5100, None);
        let v = Json::parse(&m).unwrap();
        assert_eq!(v.get("merged").and_then(|x| x.as_usize()), Some(4));
        assert_eq!(v.get("skipped").and_then(|x| x.as_usize()), Some(1));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"search"}"#).is_err());
        assert!(parse_request(r#"{"op":"search","q":[300]}"#).is_err());
        assert!(parse_request(r#"{"op":"search","q":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"op":"count"}"#).is_err());
        assert!(parse_request(r#"{"op":"topk","q":[1]}"#).is_err());
        assert!(parse_request(r#"{"op":"topk","q":[1],"k":0}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        let s = search_response(&[1, 2, 3], 42, None);
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("ids").unwrap().as_arr().unwrap().len(), 3);
        assert!(v.get("v").is_none(), "legacy responses carry no v");
        let c = count_response(7, 10, None);
        assert_eq!(Json::parse(&c).unwrap().get("count").unwrap().as_usize(), Some(7));
        let t = topk_response(&[(5, 0), (17, 2)], 140, None);
        let tv = Json::parse(&t).unwrap();
        assert_eq!(tv.get("ids").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(tv.get("dists").unwrap().as_arr().unwrap().len(), 2);
        let e = error_response(ErrorCode::BadRequest, "bad", None);
        assert!(Json::parse(&e).unwrap().get("error").is_some());
        let rl = reload_response(1000, 4, 12, None);
        let v = Json::parse(&rl).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v.get("shards").and_then(|s| s.as_usize()), Some(4));
        let sv = save_response(1000, 88, None);
        let v = Json::parse(&sv).unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v.get("n").and_then(|n| n.as_usize()), Some(1000));
    }

    #[test]
    fn versioned_responses_carry_v() {
        for s in [
            search_response(&[1], 5, Some(1)),
            count_response(1, 5, Some(1)),
            topk_response(&[(1, 0)], 5, Some(1)),
            insert_response(0, 1, 5, Some(1)),
            delete_response(true, 5, Some(1)),
            merge_response(1, 0, 5, Some(1)),
            save_response(10, 5, Some(1)),
            reload_response(10, 2, 5, Some(1)),
            ping_response(Some(1)),
            ok_response(Some(1)),
            repl_status_response("follower", 42, 3, Some(17), Some(1)),
            snapshot_fetch_header(100, 10, Some((2, 0)), Some(1)),
            wal_fetch_header(64, 2, 3, 128, 12, Some(1)),
        ] {
            let v = Json::parse(&s).unwrap();
            assert_eq!(v.get("v").and_then(|n| n.as_usize()), Some(1), "{s}");
        }
        // The fetch headers expose exact byte counts and cursors.
        let h = Json::parse(&wal_fetch_header(64, 2, 3, 128, 12, None)).unwrap();
        assert_eq!(h.get("len").and_then(|x| x.as_usize()), Some(64));
        assert_eq!(h.get("records").and_then(|x| x.as_usize()), Some(2));
        assert_eq!(h.get("next_seq").and_then(|x| x.as_usize()), Some(3));
        assert_eq!(h.get("next_off").and_then(|x| x.as_usize()), Some(128));
        let h = Json::parse(&snapshot_fetch_header(100, 10, None, None)).unwrap();
        assert_eq!(h.get("wal_seq"), Some(&Json::Null));
        let st = Json::parse(&repl_status_response("primary", 9, 0, None, None)).unwrap();
        assert_eq!(st.get("role").and_then(|r| r.as_str()), Some("primary"));
        assert_eq!(st.get("last_contact_ms"), Some(&Json::Null));
    }
}
