//! Dependency-free stand-in for the PJRT runtime (default build).
//!
//! The `xla` crate is not vendored in every build environment, so the
//! default feature set compiles this stub instead of [`super`]'s
//! `pjrt` module. It keeps the exact API surface — `Runtime`,
//! [`Sketcher`], [`HammingScanner`] — but `Runtime::load` always fails
//! with a clear message, and the downstream types are uninhabited (they
//! can never be constructed, so their methods are statically
//! unreachable). Callers that probe with `Runtime::load(..).ok()`
//! degrade gracefully; the native Rust sketchers in [`crate::sketch`]
//! cover every ingestion path without XLA.

use super::artifacts::{ArtifactMeta, Registry};
use super::{RuntimeError, RuntimeResult};
use crate::sketch::{CwsParams, MinhashParams, SketchSet, VerticalSet};
use std::convert::Infallible;
use std::path::Path;

/// Stub runtime: cannot be constructed (see module docs).
pub struct Runtime {
    never: Infallible,
}

impl Runtime {
    /// Always fails: the binary was built without the `pjrt` feature.
    pub fn load(_dir: &Path) -> RuntimeResult<Self> {
        Err(RuntimeError::msg(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (rebuild with `--features pjrt` and the vendored xla crate)",
        ))
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn registry(&self) -> &Registry {
        match self.never {}
    }

    pub fn sketcher(&self, _dataset: &str) -> RuntimeResult<Sketcher> {
        match self.never {}
    }

    pub fn scanner(&self, _dataset: &str) -> RuntimeResult<HammingScanner> {
        match self.never {}
    }
}

/// Stub sketcher (uninhabited).
pub struct Sketcher {
    never: Infallible,
}

impl Sketcher {
    pub fn meta(&self) -> &ArtifactMeta {
        match self.never {}
    }

    pub fn sketch_minhash(
        &self,
        _x: &[f32],
        _n: usize,
        _p: &MinhashParams,
    ) -> RuntimeResult<SketchSet> {
        match self.never {}
    }

    pub fn sketch_cws(&self, _x: &[f32], _n: usize, _p: &CwsParams) -> RuntimeResult<SketchSet> {
        match self.never {}
    }
}

/// Stub scanner (uninhabited).
pub struct HammingScanner {
    never: Infallible,
}

impl HammingScanner {
    pub fn meta(&self) -> &ArtifactMeta {
        match self.never {}
    }

    pub fn distances(&self, _db: &VerticalSet, _q: &[u8]) -> RuntimeResult<Vec<i32>> {
        match self.never {}
    }

    pub fn search(&self, _db: &VerticalSet, _q: &[u8], _tau: usize) -> RuntimeResult<Vec<u32>> {
        match self.never {}
    }
}
