//! PJRT runtime: executes the AOT-lowered JAX/Pallas artifacts.
//!
//! Python runs **once**, at `make artifacts` time, lowering the Layer-2
//! models to HLO text. This module loads those artifacts through the
//! `xla` crate (`PjRtClient` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) so the serving binary is self-contained:
//!
//! * [`Sketcher`] — the ingestion path: feature batches → b-bit sketches
//!   (minhash or CWS), batched to the artifact's static shape. Minhash is
//!   bit-identical to the native Rust implementation (integer min);
//!   CWS matches up to f32 `ln` ulps (asserted < 0.5% char mismatch in
//!   the integration tests).
//! * [`HammingScanner`] — the XLA vertical Hamming scan (the
//!   accelerator-style brute-force baseline).
//!
//! Artifact metadata lives in `artifacts/meta.json` ([`artifacts`]).
//!
//! The XLA executor is feature-gated: `--features pjrt` compiles the
//! real implementation (the `pjrt` module, which needs the vendored
//! `xla` + `anyhow` crates — see Cargo.toml); the default build uses a
//! dependency-free `stub` with the identical API whose `Runtime::load`
//! reports the runtime as unavailable. All request-path code is pure
//! Rust either way.

pub mod artifacts;

mod error;
pub use error::{RuntimeError, RuntimeResult};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{HammingScanner, Runtime, Sketcher};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{HammingScanner, Runtime, Sketcher};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// Unit-level: registry failure modes (full runtime integration tests
    /// live in rust/tests/integration_runtime.rs and need `make artifacts`
    /// plus the `pjrt` feature).
    #[test]
    fn missing_registry_errors() {
        let r = Runtime::load(Path::new("/nonexistent/dir"));
        assert!(r.is_err());
    }
}
