//! Artifact registry: parses `artifacts/meta.json` written by
//! `python/compile/aot.py`.

use super::{RuntimeError, RuntimeResult};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Metadata of one lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// `sketch_minhash` | `sketch_cws` | `hamming_scan`.
    pub kind: String,
    pub dataset: String,
    /// Static batch size of the executable.
    pub batch: usize,
    /// Feature dimensionality (sketch artifacts; 0 otherwise).
    pub d: usize,
    pub l: usize,
    pub b: usize,
    /// Words per plane (hamming artifacts; 0 otherwise).
    pub w: usize,
    /// Absolute path to the `.hlo.txt` file.
    pub path: PathBuf,
}

/// All artifacts in a directory.
#[derive(Debug, Clone)]
pub struct Registry {
    artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl Registry {
    /// Reads and validates `dir/meta.json`.
    pub fn load(dir: &Path) -> RuntimeResult<Self> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .map_err(|e| RuntimeError::msg(format!("reading {meta_path:?}: {e}")))?;
        let json = Json::parse(&text)
            .map_err(|e| RuntimeError::msg(format!("parsing meta.json: {e}")))?;
        let arr = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| RuntimeError::msg("meta.json missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for item in arr {
            let get_str = |k: &str| -> RuntimeResult<String> {
                Ok(item
                    .get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| RuntimeError::msg(format!("artifact missing field {k}")))?
                    .to_string())
            };
            let get_num =
                |k: &str| -> usize { item.get(k).and_then(|v| v.as_usize()).unwrap_or(0) };
            let file = get_str("file")?;
            let path = dir.join(&file);
            if !path.exists() {
                return Err(RuntimeError::msg(format!(
                    "artifact file {path:?} missing (re-run `make artifacts`)"
                )));
            }
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                kind: get_str("kind")?,
                dataset: get_str("dataset")?,
                batch: get_num("batch"),
                d: get_num("d"),
                l: get_num("l"),
                b: get_num("b"),
                w: get_num("w"),
                path,
            });
        }
        Ok(Registry { artifacts, dir: dir.to_path_buf() })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_meta() {
        // Written by `make artifacts`; skip silently when absent so unit
        // tests can run pre-artifact (integration tests require it).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = Registry::load(&dir).unwrap();
        assert!(reg.find("sketch_review").is_some());
        assert!(reg.find("hamming_gist").is_some());
        let s = reg.find("sketch_sift").unwrap();
        assert_eq!((s.b, s.l, s.d), (4, 32, 128));
        assert_eq!(s.kind, "sketch_cws");
        let h = reg.find("hamming_gist").unwrap();
        assert_eq!(h.w, 2);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Registry::load(Path::new("/no/such/dir")).is_err());
    }
}
