//! Lightweight runtime error type.
//!
//! `anyhow` is only linked when the `pjrt` feature is enabled; the
//! artifact registry and the no-XLA stub use this string-backed error so
//! the rest of the crate stays dependency-free. It implements
//! `std::error::Error`, so the `pjrt` implementation can still wrap it
//! with `anyhow::Context`.

/// Error of the artifact registry / runtime facade.
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub(crate) fn msg(s: impl Into<String>) -> Self {
        RuntimeError(s.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used by the registry and the stub runtime.
pub type RuntimeResult<T> = std::result::Result<T, RuntimeError>;
