//! The real PJRT/XLA executor (compiled with `--features pjrt`).
//!
//! Needs the vendored `xla` and `anyhow` crates — see the Cargo.toml
//! header for how to enable them. Without the feature the sibling
//! [`super`] stub provides the same API surface.

use super::artifacts::{ArtifactMeta, Registry};
use crate::sketch::{CwsParams, MinhashParams, SketchSet, VerticalSet};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A PJRT CPU client plus the artifact registry.
pub struct Runtime {
    client: xla::PjRtClient,
    registry: Registry,
}

impl Runtime {
    /// Loads `meta.json` from `dir` and connects the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let registry = Registry::load(dir).with_context(|| {
            format!("loading artifact registry from {dir:?} (run `make artifacts`)")
        })?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, registry })
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    fn compile(&self, meta: &ArtifactMeta) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", meta.name))
    }

    /// Compiles the sketching executable for a dataset config.
    pub fn sketcher(&self, dataset: &str) -> Result<Sketcher> {
        let meta = self
            .registry
            .find(&format!("sketch_{dataset}"))
            .with_context(|| format!("no sketch artifact for dataset {dataset}"))?
            .clone();
        let exe = self.compile(&meta)?;
        Ok(Sketcher { exe, meta })
    }

    /// Compiles the Hamming-scan executable for a dataset config.
    pub fn scanner(&self, dataset: &str) -> Result<HammingScanner> {
        let meta = self
            .registry
            .find(&format!("hamming_{dataset}"))
            .with_context(|| format!("no hamming artifact for dataset {dataset}"))?
            .clone();
        let exe = self.compile(&meta)?;
        Ok(HammingScanner { exe, meta })
    }
}

/// Executes the sketch pipeline artifact over feature batches.
pub struct Sketcher {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl Sketcher {
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Runs one padded batch; `x` is row-major `batch × d`. Returns the
    /// flat `batch × l` i32 character matrix.
    fn run_batch(&self, x: &[f32], params: &[xla::Literal]) -> Result<Vec<i32>> {
        let (batch, d) = (self.meta.batch, self.meta.d);
        assert_eq!(x.len(), batch * d);
        let x_lit = xla::Literal::vec1(x).reshape(&[batch as i64, d as i64])?;
        let mut args = vec![x_lit];
        args.extend(params.iter().map(clone_literal));
        let results = self.exe.execute::<xla::Literal>(&args)?;
        let out = results[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Sketches `n` minhash fingerprints (dense 0/1 rows, row-major
    /// `n × d`), looping over padded batches.
    pub fn sketch_minhash(&self, x: &[f32], n: usize, p: &MinhashParams) -> Result<SketchSet> {
        if self.meta.kind != "sketch_minhash" {
            bail!("artifact {} is not a minhash sketcher", self.meta.name);
        }
        assert_eq!((p.l, p.d), (self.meta.l, self.meta.d), "params mismatch");
        let h_i32: Vec<i32> = p.hashes.iter().map(|&v| v as i32).collect();
        let h_lit = xla::Literal::vec1(&h_i32)
            .reshape(&[self.meta.l as i64, self.meta.d as i64])?;
        self.batched_sketch(x, n, p.b, &[h_lit])
    }

    /// Sketches `n` CWS weight vectors (row-major `n × d`).
    pub fn sketch_cws(&self, x: &[f32], n: usize, p: &CwsParams) -> Result<SketchSet> {
        if self.meta.kind != "sketch_cws" {
            bail!("artifact {} is not a CWS sketcher", self.meta.name);
        }
        assert_eq!((p.l, p.d), (self.meta.l, self.meta.d), "params mismatch");
        let dims = [self.meta.l as i64, self.meta.d as i64];
        let r = xla::Literal::vec1(&p.r).reshape(&dims)?;
        let logc = xla::Literal::vec1(&p.logc).reshape(&dims)?;
        let beta = xla::Literal::vec1(&p.beta).reshape(&dims)?;
        self.batched_sketch(x, n, p.b, &[r, logc, beta])
    }

    fn batched_sketch(
        &self,
        x: &[f32],
        n: usize,
        b: usize,
        params: &[xla::Literal],
    ) -> Result<SketchSet> {
        let (batch, d, l) = (self.meta.batch, self.meta.d, self.meta.l);
        assert_eq!(x.len(), n * d, "features must be n×d");
        let mut out = SketchSet::zeros(b, l, n);
        let mut padded = vec![0f32; batch * d];
        let mut i = 0usize;
        while i < n {
            let take = batch.min(n - i);
            padded[..take * d].copy_from_slice(&x[i * d..(i + take) * d]);
            padded[take * d..].fill(0.0);
            let chars = self.run_batch(&padded, params)?;
            for row in 0..take {
                for pos in 0..l {
                    out.set_char(i + row, pos, (chars[row * l + pos] & 0xFF) as u8);
                }
            }
            i += take;
        }
        Ok(out)
    }
}

/// Executes the vertical Hamming scan artifact.
pub struct HammingScanner {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl HammingScanner {
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Distances of every sketch in `db` to query `q`, computed on the
    /// XLA side in `scan_batch`-sized chunks.
    pub fn distances(&self, db: &VerticalSet, q: &[u8]) -> Result<Vec<i32>> {
        let (b, l, w, batch) = (self.meta.b, self.meta.l, self.meta.w, self.meta.batch);
        assert_eq!((db.b(), db.l()), (b, l), "database/artifact mismatch");
        let n = db.n();

        // query planes → i32 words
        let qp = db.pack_query(q);
        let mut q_words = vec![0i32; b * w];
        for (k, &plane) in qp.iter().enumerate() {
            for wi in 0..w {
                q_words[k * w + wi] = ((plane >> (32 * wi)) & 0xFFFF_FFFF) as u32 as i32;
            }
        }
        let q_lit = xla::Literal::vec1(&q_words).reshape(&[b as i64, w as i64])?;

        let mut out = Vec::with_capacity(n);
        let mut planes = vec![0i32; b * batch * w];
        let mut i = 0usize;
        while i < n {
            let take = batch.min(n - i);
            planes.fill(0);
            for row in 0..take {
                for k in 0..b {
                    let field = db.plane_field(k, i + row);
                    for wi in 0..w {
                        planes[k * batch * w + row * w + wi] =
                            ((field >> (32 * wi)) & 0xFFFF_FFFF) as u32 as i32;
                    }
                }
            }
            let p_lit = xla::Literal::vec1(&planes)
                .reshape(&[b as i64, batch as i64, w as i64])?;
            let results = self.exe.execute::<xla::Literal>(&[p_lit, clone_literal(&q_lit)])?;
            let dist = results[0][0].to_literal_sync()?.to_tuple1()?.to_vec::<i32>()?;
            out.extend_from_slice(&dist[..take]);
            i += take;
        }
        Ok(out)
    }

    /// Threshold search via the XLA scan (the baseline `search` shape).
    pub fn search(&self, db: &VerticalSet, q: &[u8], tau: usize) -> Result<Vec<u32>> {
        let d = self.distances(db, q)?;
        Ok(d.iter()
            .enumerate()
            .filter(|(_, &x)| x as usize <= tau)
            .map(|(i, _)| i as u32)
            .collect())
    }
}

/// The `xla` crate's `Literal` lacks `Clone`; for the small f32/i32
/// parameter tensors used here a deep copy through the element vector is
/// sufficient (and off the hot path).
fn clone_literal(lit: &xla::Literal) -> xla::Literal {
    let shape = lit.array_shape().expect("literal array shape");
    let dims = shape.dims().to_vec();
    match shape.element_type() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().expect("f32 literal");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().expect("i32 literal");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        other => panic!("clone_literal: unsupported element type {other:?}"),
    }
}
