//! 0-bit consistent weighted sampling (Li, KDD 2015).
//!
//! Maps a non-negative weighted vector `x ∈ R_{>=0}^D` to an `L`-character
//! sketch approximating the min-max kernel. For each hash `ℓ` and active
//! dimension `j` (`x_j > 0`), with fixed random `r ~ Gamma(2,1)`,
//! `c ~ Gamma(2,1)`, `β ~ U(0,1)`:
//!
//! ```text
//! t_j   = floor( ln x_j / r_j + β_j )
//! ln a_j = ln c_j − r_j · (t_j + 1 − β_j)
//! i*    = argmin_j a_j          (first index on ties)
//! char  = i* mod 2^b            ("0-bit": discard (i*, t_{i*}) bookkeeping)
//! ```
//!
//! The random tensors (`r`, `ln c`, `β`) are generated here (f32) and fed
//! to both this native implementation and the JAX/Pallas artifact. The
//! prelude is all-f32; libm vs XLA may differ in the last ulp, so the
//! cross-implementation test allows a tiny per-character mismatch rate
//! (`< 0.5%`), while this module's own tests are exact.

use crate::sketch::SketchSet;
use crate::util::pool::par_chunks;
use crate::util::rng::Rng;

/// Random CWS parameter tensors, each row-major `l × d`.
#[derive(Debug, Clone)]
pub struct CwsParams {
    pub l: usize,
    pub b: usize,
    pub d: usize,
    /// `r ~ Gamma(2,1)` (f32).
    pub r: Vec<f32>,
    /// `ln c`, `c ~ Gamma(2,1)` (f32).
    pub logc: Vec<f32>,
    /// `β ~ U[0,1)` (f32).
    pub beta: Vec<f32>,
}

impl CwsParams {
    /// Generates parameter tensors deterministically from `seed`.
    pub fn generate(l: usize, b: usize, d: usize, seed: u64) -> Self {
        assert!(matches!(b, 1 | 2 | 4 | 8));
        let mut rng = Rng::new(seed ^ 0x0c77_73u64); // "cws"
        let n = l * d;
        let mut r = Vec::with_capacity(n);
        let mut logc = Vec::with_capacity(n);
        let mut beta = Vec::with_capacity(n);
        for _ in 0..n {
            r.push(rng.gamma(2.0) as f32);
            logc.push((rng.gamma(2.0) as f32).ln());
            beta.push(rng.f32());
        }
        CwsParams { l, b, d, r, logc, beta }
    }

    /// Sketches one dense non-negative vector. Inactive dimensions
    /// (`x_j <= 0`) are excluded from the argmin; an all-zero vector maps
    /// to the all-zero sketch.
    pub fn sketch_dense(&self, x: &[f32]) -> Vec<u8> {
        debug_assert_eq!(x.len(), self.d);
        let mask = (1u32 << self.b) - 1;
        // Precompute ln x once per vector (shared across the L hashes).
        let lnx: Vec<f32> = x.iter().map(|&v| if v > 0.0 { v.ln() } else { 0.0 }).collect();
        (0..self.l)
            .map(|l| {
                let base = l * self.d;
                let mut best = f32::INFINITY;
                let mut best_j = 0u32;
                for j in 0..self.d {
                    if x[j] <= 0.0 {
                        continue;
                    }
                    let r = self.r[base + j];
                    let beta = self.beta[base + j];
                    let t = (lnx[j] / r + beta).floor();
                    let ln_a = self.logc[base + j] - r * (t + 1.0 - beta);
                    if ln_a < best {
                        best = ln_a;
                        best_j = j as u32;
                    }
                }
                (best_j & mask) as u8
            })
            .collect()
    }

    /// Batch-sketches dense vectors (row-major `n × d`) in parallel.
    pub fn sketch_batch(&self, xs: &[f32], n: usize, threads: usize) -> SketchSet {
        assert_eq!(xs.len(), n * self.d);
        let mut out = SketchSet::zeros(self.b, self.l, n);
        let rows: std::sync::Mutex<Vec<(usize, Vec<u8>)>> =
            std::sync::Mutex::new(Vec::with_capacity(n));
        par_chunks(n, threads, |range| {
            let mut local = Vec::with_capacity(range.len());
            for i in range {
                local.push((i, self.sketch_dense(&xs[i * self.d..(i + 1) * self.d])));
            }
            rows.lock().unwrap().extend(local);
        });
        for (i, row) in rows.into_inner().unwrap() {
            for (p, &c) in row.iter().enumerate() {
                out.set_char(i, p, c);
            }
        }
        out
    }
}

/// Min-max kernel (generalized Jaccard) between two non-negative vectors:
/// `Σ min(x_i, y_i) / Σ max(x_i, y_i)`.
pub fn minmax_kernel(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let (mut num, mut den) = (0f64, 0f64);
    for (&a, &b) in x.iter().zip(y) {
        num += a.min(b) as f64;
        den += a.max(b) as f64;
    }
    if den == 0.0 {
        1.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = CwsParams::generate(8, 4, 64, 5);
        let b = CwsParams::generate(8, 4, 64, 5);
        assert_eq!(a.r, b.r);
        let x: Vec<f32> = (0..64).map(|i| (i % 7) as f32).collect();
        assert_eq!(a.sketch_dense(&x), b.sketch_dense(&x));
    }

    #[test]
    fn chars_in_alphabet() {
        let p = CwsParams::generate(32, 2, 100, 6);
        let x: Vec<f32> = (0..100).map(|i| (i as f32).sqrt()).collect();
        for c in p.sketch_dense(&x) {
            assert!(c < 4);
        }
    }

    #[test]
    fn scale_invariance() {
        // CWS is scale-invariant in distribution; for *fixed* params the
        // argmin can shift slightly, but identical vectors must collide.
        let p = CwsParams::generate(64, 4, 128, 8);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..128).map(|_| rng.f32() + 0.01).collect();
        assert_eq!(p.sketch_dense(&x), p.sketch_dense(&x));
    }

    #[test]
    fn collision_tracks_minmax_kernel() {
        let d = 256usize;
        let l = 768usize;
        let p = CwsParams::generate(l, 8, d, 21);
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        // y = x with perturbation → known min-max similarity.
        let y: Vec<f32> = x.iter().map(|&v| (v + 0.15 * rng.f32()).max(0.0)).collect();
        let k = minmax_kernel(&x, &y);
        let sx = p.sketch_dense(&x);
        let sy = p.sketch_dense(&y);
        let coll = sx.iter().zip(&sy).filter(|(a, b)| a == b).count() as f64 / l as f64;
        // 0-bit CWS collision ≈ K + (1-K)/2^b; with b=8 the floor is tiny.
        assert!(
            (coll - k).abs() < 0.07,
            "minmax={k:.3} collision={coll:.3}"
        );
    }

    #[test]
    fn inactive_dims_ignored() {
        let d = 32;
        let p = CwsParams::generate(16, 4, d, 9);
        let mut x = vec![0f32; d];
        x[3] = 2.0;
        x[9] = 1.0;
        // only dims 3 and 9 can win the argmin
        for c in p.sketch_dense(&x) {
            assert!(c == 3 % 16 || c == 9 % 16, "char {c}");
        }
    }

    #[test]
    fn all_zero_vector_sketches_to_zero() {
        let p = CwsParams::generate(8, 2, 16, 10);
        assert_eq!(p.sketch_dense(&vec![0f32; 16]), vec![0u8; 8]);
    }

    #[test]
    fn batch_matches_single() {
        let d = 64;
        let p = CwsParams::generate(12, 2, d, 12);
        let mut rng = Rng::new(13);
        let n = 40;
        let xs: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
        let batch = p.sketch_batch(&xs, n, 4);
        for i in 0..n {
            assert_eq!(batch.row(i), p.sketch_dense(&xs[i * d..(i + 1) * d]), "i={i}");
        }
    }

    #[test]
    fn minmax_kernel_basics() {
        assert_eq!(minmax_kernel(&[1.0, 2.0], &[1.0, 2.0]), 1.0);
        assert_eq!(minmax_kernel(&[1.0, 0.0], &[0.0, 1.0]), 0.0);
        assert_eq!(minmax_kernel(&[0.0], &[0.0]), 1.0);
        assert!((minmax_kernel(&[2.0, 1.0], &[1.0, 1.0]) - 2.0 / 3.0).abs() < 1e-9);
    }
}
