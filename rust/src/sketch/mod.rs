//! Sketch storage and similarity-preserving hashing.
//!
//! A *b-bit sketch* (§II of the paper) is a length-`L` string over
//! `Σ = [0, 2^b)`. This module provides:
//!
//! * [`SketchSet`] — packed horizontal storage (b-bit chars, MSB-first
//!   within words, so word-sequence order == lexicographic order).
//! * [`VerticalSet`] — the bit-plane ("vertical") layout of Zhang et al.
//!   enabling bit-parallel Hamming distance (§V-C of the paper).
//! * [`hamming`] — naive, horizontal-SWAR and vertical Hamming kernels.
//! * [`minhash`] / [`cws`] — native Rust implementations of b-bit minwise
//!   hashing (Li & König) and 0-bit consistent weighted sampling (Li),
//!   bit-compatible with the JAX/Pallas AOT artifacts (the same random
//!   parameter tensors are fed to both).

pub mod cws;
pub mod hamming;
pub mod minhash;
pub mod plane_store;
pub mod types;
pub mod vertical;

pub use cws::CwsParams;
pub use minhash::MinhashParams;
pub use types::SketchSet;
pub use vertical::VerticalSet;
