//! Vertical (bit-plane) sketch layout.
//!
//! `plane k` of a sketch holds bit `k` of each of its `L` characters,
//! packed LSB-first into an `L`-bit field (`L <= 64`). Verification in the
//! multi-index approach and the sparse layer of bST both use this layout
//! for bit-parallel Hamming distance (§V-C).
//!
//! Storage is the flat [`super::plane_store::PlaneStore`] — `n · L` bits
//! per plane plus one padding word (the same asymptotic space as the
//! horizontal layout) with branch-free reads on the verification path.

use super::plane_store::PlaneStore;
use super::SketchSet;
use crate::store::{ByteReader, ByteWriter, Persist, StoreError};
use crate::util::HeapSize;

/// A sketch database in vertical format, supporting random access by id.
#[derive(Debug, Clone)]
pub struct VerticalSet {
    store: PlaneStore,
}

impl VerticalSet {
    /// Converts a horizontal [`SketchSet`] (requires `L <= 64`).
    pub fn from_horizontal(set: &SketchSet) -> Self {
        assert!(set.l() <= 64, "vertical layout requires L <= 64");
        let (b, l, n) = (set.b(), set.l(), set.n());
        let store = PlaneStore::from_fn(b, l, n, |k, i| {
            let mut field = 0u64;
            for p in 0..l {
                field |= (((set.get_char(i, p) >> k) & 1) as u64) << p;
            }
            field
        });
        VerticalSet { store }
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.store.b()
    }

    #[inline]
    pub fn l(&self) -> usize {
        self.store.width()
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.store.n()
    }

    /// The `b` plane words of sketch `i` (materialized on the stack).
    #[inline]
    pub fn planes_of(&self, i: usize) -> Vec<u64> {
        (0..self.b()).map(|k| self.store.field(k, i)).collect()
    }

    /// Packs a raw query row into plane words, reusing the caller's buffer
    /// (the per-query scratch on the verification hot path).
    pub fn pack_query_into(&self, q: &[u8], out: &mut Vec<u64>) {
        out.clear();
        self.pack_query_append(q, out);
    }

    /// Packs a raw query row into plane words *appended* to `out` —
    /// block execution packs a whole query block back to back into one
    /// flat `m·b` buffer this way.
    pub fn pack_query_append(&self, q: &[u8], out: &mut Vec<u64>) {
        assert_eq!(q.len(), self.l());
        for k in 0..self.b() {
            let mut field = 0u64;
            for (p, &c) in q.iter().enumerate() {
                field |= (((c >> k) & 1) as u64) << p;
            }
            out.push(field);
        }
    }

    /// Allocating convenience wrapper around [`Self::pack_query_into`].
    pub fn pack_query(&self, q: &[u8]) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.b());
        self.pack_query_into(q, &mut out);
        out
    }

    /// Hamming distance between sketch `i` and pre-packed query planes.
    #[inline]
    pub fn ham(&self, i: usize, q_planes: &[u64]) -> usize {
        self.store.ham(i, q_planes)
    }

    /// `Some(dist)` iff `ham(i, q) <= tau` — the verification hot path.
    #[inline]
    pub fn ham_leq(&self, i: usize, q_planes: &[u64], tau: usize) -> Option<usize> {
        self.store.ham_leq(i, q_planes, tau)
    }

    /// Streaming range-verification kernel — see
    /// [`PlaneStore::ham_range_leq`] for the contract.
    #[inline]
    pub fn ham_range_leq<F>(&self, lo: usize, hi: usize, q_planes: &[u64], tau0: usize, sink: F)
    where
        F: FnMut(usize, Option<usize>) -> Option<usize>,
    {
        self.store.ham_range_leq(lo, hi, q_planes, tau0, sink)
    }

    /// Batched candidate-verification kernel — see
    /// [`PlaneStore::ham_many_leq`] for the contract.
    #[inline]
    pub fn ham_many_leq<F>(&self, ids: &[u32], q_planes: &[u64], tau0: usize, sink: F)
    where
        F: FnMut(u32, Option<usize>) -> Option<usize>,
    {
        self.store.ham_many_leq(ids, q_planes, tau0, sink)
    }

    /// Multi-query streaming range kernel (block execution) — see
    /// [`PlaneStore::ham_range_leq_multi`] for the block contract.
    #[inline]
    pub fn ham_range_leq_multi<F>(
        &self,
        lo: usize,
        hi: usize,
        qs: &[u64],
        taus0: &[usize],
        live0: u64,
        sink: F,
    ) where
        F: FnMut(usize, usize, Option<usize>) -> Option<usize>,
    {
        self.store.ham_range_leq_multi(lo, hi, qs, taus0, live0, sink)
    }

    /// Multi-query batched candidate kernel (block execution) — see
    /// [`PlaneStore::ham_many_leq_multi`] for the block contract.
    #[inline]
    pub fn ham_many_leq_multi<F>(
        &self,
        ids: &[u32],
        qs: &[u64],
        taus0: &[usize],
        live0: u64,
        sink: F,
    ) where
        F: FnMut(usize, u32, Option<usize>) -> Option<usize>,
    {
        self.store.ham_many_leq_multi(ids, qs, taus0, live0, sink)
    }

    /// Full linear scan: ids of all sketches within `tau` of `q`.
    pub fn scan(&self, q: &[u8], tau: usize) -> Vec<u32> {
        let qp = self.pack_query(q);
        let mut out = Vec::new();
        self.store.ham_range_leq(0, self.n(), &qp, tau, |i, verdict| {
            if verdict.is_some() {
                out.push(i as u32);
            }
            Some(tau)
        });
        out
    }

    /// Distance histogram of the whole database against `q` (diagnostics).
    pub fn distance_histogram(&self, q: &[u8]) -> Vec<usize> {
        let qp = self.pack_query(q);
        let mut hist = vec![0usize; self.l() + 1];
        for i in 0..self.n() {
            hist[self.ham(i, &qp)] += 1;
        }
        hist
    }

    /// Plane field of sketch `i`, plane `k` (for the XLA runtime, which
    /// ships planes to the Hamming-scan artifact).
    #[inline]
    pub fn plane_field(&self, k: usize, i: usize) -> u64 {
        self.store.field(k, i)
    }
}

impl Persist for VerticalSet {
    fn write_into(&self, w: &mut ByteWriter) {
        self.store.write_into(w);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        Ok(VerticalSet { store: PlaneStore::read_from(r)? })
    }
}

impl HeapSize for VerticalSet {
    fn heap_bytes(&self) -> usize {
        self.store.heap_bytes()
    }
}

// Re-export the free-function kernels for callers holding raw plane words.
pub use super::hamming::{ham_vertical as ham_planes, ham_vertical_leq as ham_planes_leq};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::hamming::ham_chars;
    use crate::util::Rng;

    fn random_rows(b: usize, l: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect()
    }

    #[test]
    fn roundtrip_against_horizontal() {
        for &(b, l) in &[(2usize, 16usize), (4, 32), (8, 64), (1, 64), (2, 5)] {
            let rows = random_rows(b, l, 64, (b * l) as u64);
            let set = SketchSet::from_rows(b, l, &rows);
            let vert = VerticalSet::from_horizontal(&set);
            for (i, row) in rows.iter().enumerate() {
                // reconstruct chars from planes
                let planes = vert.planes_of(i);
                for p in 0..l {
                    let mut c = 0u8;
                    for (k, &plane) in planes.iter().enumerate() {
                        c |= (((plane >> p) & 1) as u8) << k;
                    }
                    assert_eq!(c, row[p], "b={b} l={l} i={i} p={p}");
                }
            }
        }
    }

    #[test]
    fn ham_matches_naive() {
        let rows = random_rows(4, 32, 80, 31);
        let set = SketchSet::from_rows(4, 32, &rows);
        let vert = VerticalSet::from_horizontal(&set);
        for i in 0..80 {
            let qp = vert.pack_query(&rows[i]);
            for j in 0..80 {
                assert_eq!(vert.ham(j, &qp), ham_chars(&rows[j], &rows[i]));
            }
        }
    }

    #[test]
    fn scan_finds_exactly_neighbors() {
        let rows = random_rows(2, 16, 300, 33);
        let set = SketchSet::from_rows(2, 16, &rows);
        let vert = VerticalSet::from_horizontal(&set);
        let q = &rows[5];
        for tau in 0..6 {
            let got = vert.scan(q, tau);
            let expect: Vec<u32> = (0..300)
                .filter(|&j| ham_chars(&rows[j], q) <= tau)
                .map(|j| j as u32)
                .collect();
            assert_eq!(got, expect, "tau={tau}");
        }
    }

    #[test]
    fn histogram_sums_to_n() {
        let rows = random_rows(2, 16, 100, 35);
        let set = SketchSet::from_rows(2, 16, &rows);
        let vert = VerticalSet::from_horizontal(&set);
        let hist = vert.distance_histogram(&rows[0]);
        assert_eq!(hist.iter().sum::<usize>(), 100);
        assert!(hist[0] >= 1); // itself
    }

    #[test]
    fn space_matches_horizontal() {
        let rows = random_rows(4, 32, 1000, 37);
        let set = SketchSet::from_rows(4, 32, &rows);
        let vert = VerticalSet::from_horizontal(&set);
        // both are n*L*b bits plus per-plane padding slack
        let raw_bits = 1000 * 32 * 4;
        assert!(vert.heap_bytes() * 8 >= raw_bits);
        assert!((vert.heap_bytes() as f64) < raw_bits as f64 / 8.0 * 1.4);
        let _ = set;
    }
}
