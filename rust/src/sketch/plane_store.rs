//! Flat bit-plane storage — the verification hot path.
//!
//! `b` planes of `n` fixed-width fields (`width <= 64` bits) in ONE
//! contiguous word array, **interleaved per item**: all `b` plane fields
//! of item `i` are adjacent (`b·width` bits starting at `i·b·width`), so
//! one verification touches one-or-two cache lines regardless of `b`
//! (a plane-separated layout costs `b` scattered lines — measured 40%
//! slower for b=8; EXPERIMENTS.md §Perf). Reads are branch-free
//! two-word fetches thanks to tail padding:
//!
//! ```text
//! field(k, i) = ((w0 >> o) | (w1 << (63-o) << 1)) & mask
//! ```

use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError};
use crate::util::HeapSize;

/// `b` planes × `n` fields of `width` bits.
#[derive(Debug, Clone)]
pub struct PlaneStore {
    b: usize,
    width: usize,
    n: usize,
    words: Vec<u64>,
    mask: u64,
}

impl PlaneStore {
    /// Builds from a field generator: `f(k, i)` returns field `i` of
    /// plane `k` (low `width` bits).
    pub fn from_fn(b: usize, width: usize, n: usize, mut f: impl FnMut(usize, usize) -> u64) -> Self {
        assert!(width <= 64);
        let total_bits = n * b * width;
        // +2 padding words: the branch-free read touches `words[idx + 1]`
        // even for a field ending exactly at the last payload word (and
        // covers the width = 0 degenerate case).
        let n_words = total_bits.div_ceil(64) + 2;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let mut words = vec![0u64; n_words];
        let item_bits = b * width;
        for i in 0..n {
            for k in 0..b {
                let bit = i * item_bits + k * width;
                let (w, o) = (bit / 64, bit % 64);
                let v = f(k, i) & mask;
                words[w] |= v << o;
                if o + width > 64 {
                    words[w + 1] |= v >> (64 - o);
                }
            }
        }
        PlaneStore { b, width, n, words, mask }
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.b
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Field `i` of plane `k`.
    #[inline]
    pub fn field(&self, k: usize, i: usize) -> u64 {
        debug_assert!(k < self.b && i < self.n);
        let bit = i * self.b * self.width + k * self.width;
        let idx = bit >> 6;
        let o = bit & 63;
        let w0 = self.words[idx];
        let w1 = self.words[idx + 1]; // padding keeps this in-bounds
        ((w0 >> o) | ((w1 << (63 - o)) << 1)) & self.mask
    }

    /// Hamming distance between item `i` and pre-packed query fields
    /// (`q[k]` = plane-k field): XOR planes, OR-fold, popcount. All of
    /// item `i`'s fields are adjacent, so the loop walks 1–2 cache lines.
    #[inline]
    pub fn ham(&self, i: usize, q: &[u64]) -> usize {
        debug_assert_eq!(q.len(), self.b);
        if self.width == 64 {
            // word-aligned fast path: no shifts at all
            let base = i * self.b;
            let mut acc = 0u64;
            for (k, &qk) in q.iter().enumerate() {
                acc |= self.words[base + k] ^ qk;
            }
            return acc.count_ones() as usize;
        }
        let mut bit = i * self.b * self.width;
        let mut acc = 0u64;
        for &qk in q {
            let idx = bit >> 6;
            let o = bit & 63;
            let w0 = self.words[idx];
            let w1 = self.words[idx + 1];
            acc |= ((w0 >> o) | ((w1 << (63 - o)) << 1)) ^ qk;
            bit += self.width;
        }
        (acc & self.mask).count_ones() as usize
    }

    /// `Some(d)` iff `ham(i, q) <= tau`.
    #[inline]
    pub fn ham_leq(&self, i: usize, q: &[u64], tau: usize) -> Option<usize> {
        let d = self.ham(i, q);
        (d <= tau).then_some(d)
    }
}

impl Persist for PlaneStore {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.width);
        w.put_usize(self.n);
        w.put_u64s(&self.words);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let width = r.get_usize()?;
        let n = r.get_usize()?;
        let words = r.get_u64s()?;
        ensure(width <= 64, || format!("PlaneStore: width {width} > 64"))?;
        let total_bits = n
            .checked_mul(b)
            .and_then(|x| x.checked_mul(width))
            .ok_or_else(|| StoreError::Corrupt("PlaneStore: dimensions overflow".into()))?;
        ensure(words.len() == total_bits.div_ceil(64) + 2, || {
            format!(
                "PlaneStore: {} words for {total_bits} payload bits (+2 padding)",
                words.len()
            )
        })?;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        Ok(PlaneStore { b, width, n, words, mask })
    }
}

impl HeapSize for PlaneStore {
    fn heap_bytes(&self) -> usize {
        self.words.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn field_roundtrip_random_widths() {
        let mut rng = Rng::new(1);
        for &width in &[1usize, 5, 16, 21, 32, 33, 63, 64] {
            let (b, n) = (3usize, 200usize);
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            for k in 0..b {
                for i in 0..n {
                    assert_eq!(ps.field(k, i), vals[k * n + i], "w={width} k={k} i={i}");
                }
            }
        }
    }

    #[test]
    fn ham_matches_reference() {
        let mut rng = Rng::new(2);
        for &(b, width) in &[(1usize, 16usize), (2, 16), (4, 32), (8, 64), (2, 21)] {
            let n = 100;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();
            for i in 0..n {
                let mut acc = 0u64;
                for k in 0..b {
                    acc |= vals[k * n + i] ^ q[k];
                }
                let expect = (acc & mask).count_ones() as usize;
                assert_eq!(ps.ham(i, &q), expect, "b={b} w={width} i={i}");
                assert_eq!(ps.ham_leq(i, &q, expect), Some(expect));
                if expect > 0 {
                    assert_eq!(ps.ham_leq(i, &q, expect - 1), None);
                }
            }
        }
    }

    #[test]
    fn zero_width_is_rejected_gracefully() {
        // width 0 is never used (ls == L handled by suffix_len 0 checks
        // upstream) but from_fn must not panic for n = 0 fields.
        let ps = PlaneStore::from_fn(2, 8, 0, |_, _| 0);
        assert_eq!(ps.n(), 0);
    }
}
