//! Flat bit-plane storage — the verification hot path.
//!
//! `b` planes of `n` fixed-width fields (`width <= 64` bits) in ONE
//! contiguous word array, **interleaved per item**: all `b` plane fields
//! of item `i` are adjacent (`b·width` bits starting at `i·b·width`), so
//! one verification touches one-or-two cache lines regardless of `b`
//! (a plane-separated layout costs `b` scattered lines — measured 40%
//! slower for b=8; EXPERIMENTS.md §Perf). Reads are branch-free
//! two-word fetches thanks to tail padding:
//!
//! ```text
//! field(k, i) = ((w0 >> o) | (w1 << (63-o) << 1)) & mask
//! ```
//!
//! # Verification kernels
//!
//! Beyond the per-item accessors, the store exposes streaming kernels
//! that exploit the interleaved layout — consecutive items occupy
//! consecutive words, so a scan over a contiguous range walks `words`
//! strictly left-to-right:
//!
//! * [`PlaneStore::range_scan`] / [`RangeHam`] — a cursor over items
//!   `[lo, hi)` carrying a rolling bit offset. Each `next_leq(tau)`
//!   advances one item with sequential loads; no per-item
//!   `i·b·width` re-derivation, no random `field()` extraction.
//! * [`PlaneStore::ham_range_leq`] — loop driver over a cursor with a
//!   per-item sink.
//! * [`PlaneStore::ham_many_leq`] — batched verification of a scattered
//!   candidate list: query-side setup (fast-path dispatch, one-word
//!   query packing) is hoisted out of the loop, and each item still
//!   costs only its own one-or-two cache lines.
//! * [`PlaneStore::ham_range_leq_multi`] / [`PlaneStore::ham_many_leq_multi`]
//!   — the block-execution twins: one pass evaluates every live query of
//!   a block (at most [`MAX_BLOCK`]) against each item, staging the
//!   item's plane words once and folding per query in registers.
//!   Per-query early exit rides a live-query bitmask: a query whose sink
//!   returns `None` is dropped from the mask and sees no further items;
//!   the pass finishes the moment the mask empties. Verdicts are
//!   bit-identical to the serial kernels at the same live thresholds,
//!   fast paths included.
//!
//! **Contract** (shared by all three):
//!
//! * Items are verified in the order given (ascending for ranges, list
//!   order for candidate batches); the sink sees every verified item
//!   exactly once.
//! * The verdict is `Some(d)` iff the exact distance `d <= tau`, where
//!   `tau` is the threshold *live at that item* — sinks return the
//!   threshold for the next item (adaptive collectors keep tightening
//!   mid-scan) or `None` to stop the scan early.
//! * An over-threshold item yields `None` without a distance: for
//!   `b > 1` the kernels bail out of the plane loop as soon as the
//!   running OR-accumulator's popcount (a lower bound — OR only grows)
//!   exceeds `tau`, so hopeless items never touch all planes.
//! * Fast paths: `width == 64` (word-aligned fields, shift-free) and
//!   `b·width == 64` (one word per item: single load, log₂(b)
//!   shift-OR lane fold). Both produce bit-identical verdicts to the
//!   generic path.
//!
//! The kernels change the *access pattern only* — the word layout (and
//! therefore the snapshot encoding) is untouched.

use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, Words};
use crate::util::HeapSize;

/// Widest query block the multi-query kernels accept: the live set is a
/// single `u64` bitmask, so a block never exceeds 64 queries.
pub const MAX_BLOCK: usize = 64;

/// Most planes the multi-query kernels stage per item in their stack
/// buffer (`b <= 8` everywhere sketches exist; wider stores fall back to
/// per-query streaming reads).
const MAX_ITEM_PLANES: usize = 8;

/// All-ones mask over the low `m` query slots (`m <= 64`).
#[inline]
pub fn live_mask(m: usize) -> u64 {
    if m >= 64 {
        u64::MAX
    } else {
        (1u64 << m) - 1
    }
}

/// Register-only verification fold over pre-fetched item plane words:
/// `Some(d)` iff the masked Hamming distance `d <= tau`, with the same
/// between-plane lower-bound early exit (and therefore bit-identical
/// verdicts) as the per-item kernels. With `mask == u64::MAX` this is
/// exactly the `width == 64` aligned path.
#[inline(always)]
fn fold_leq(item: &[u64], q: &[u64], mask: u64, tau: usize) -> Option<usize> {
    debug_assert_eq!(item.len(), q.len());
    let mut acc = 0u64;
    for (k, (&w, &qk)) in item.iter().zip(q).enumerate() {
        if k > 0 && (acc & mask).count_ones() as usize > tau {
            return None;
        }
        acc |= w ^ qk;
    }
    let d = (acc & mask).count_ones() as usize;
    (d <= tau).then_some(d)
}

/// One-word verification fold (`b·width == 64`, `width < 64`): XOR the
/// item word against the pre-packed query word, then the halving lane
/// fold — the multi-query twin of [`PlaneStore::ham_leq_word`].
#[inline(always)]
fn fold_word_leq(w: u64, q_word: u64, width: usize, mask: u64, tau: usize) -> Option<usize> {
    let mut f = w ^ q_word;
    let mut step = 32usize;
    while step >= width {
        f |= f >> step;
        step >>= 1;
    }
    let d = (f & mask).count_ones() as usize;
    (d <= tau).then_some(d)
}

/// `b` planes × `n` fields of `width` bits.
#[derive(Debug, Clone)]
pub struct PlaneStore {
    b: usize,
    width: usize,
    n: usize,
    /// Owned when built or appended to (delta buffers), borrowed from the
    /// snapshot mapping when loaded zero-copy.
    words: Words,
    mask: u64,
}

impl PlaneStore {
    /// Builds from a field generator: `f(k, i)` returns field `i` of
    /// plane `k` (low `width` bits).
    pub fn from_fn(
        b: usize,
        width: usize,
        n: usize,
        mut f: impl FnMut(usize, usize) -> u64,
    ) -> Self {
        assert!(width <= 64);
        let total_bits = n * b * width;
        // +2 padding words: the branch-free read touches `words[idx + 1]`
        // even for a field ending exactly at the last payload word (and
        // covers the width = 0 degenerate case).
        let n_words = total_bits.div_ceil(64) + 2;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let mut words = vec![0u64; n_words];
        let item_bits = b * width;
        for i in 0..n {
            for k in 0..b {
                let bit = i * item_bits + k * width;
                let (w, o) = (bit / 64, bit % 64);
                let v = f(k, i) & mask;
                words[w] |= v << o;
                if o + width > 64 {
                    words[w + 1] |= v >> (64 - o);
                }
            }
        }
        PlaneStore { b, width, n, words: words.into(), mask }
    }

    /// An empty, appendable store (the delta-segment buffer): items are
    /// added one at a time with [`PlaneStore::push_fields`] and become
    /// immediately searchable through the range kernels.
    pub fn with_dims(b: usize, width: usize) -> Self {
        Self::from_fn(b, width, 0, |_, _| 0)
    }

    /// Appends one item (its `b` plane fields, low `width` bits each) at
    /// index `n`. The tail-padding invariant (`total_bits.div_ceil(64) +
    /// 2` words) is preserved, so the branch-free reads and the streaming
    /// kernels — and the snapshot layout — see exactly the store that
    /// [`PlaneStore::from_fn`] would have built.
    pub fn push_fields(&mut self, fields: &[u64]) {
        assert_eq!(fields.len(), self.b, "push_fields: expected {} planes", self.b);
        let item_bits = self.b * self.width;
        let mut bit = self.n * item_bits;
        let need = (bit + item_bits).div_ceil(64) + 2;
        let width = self.width;
        let mask = self.mask;
        let words = self.words.to_mut();
        if words.len() < need {
            words.resize(need, 0);
        }
        for &f in fields {
            let v = f & mask;
            let (w, o) = (bit / 64, bit % 64);
            words[w] |= v << o;
            if o + width > 64 {
                words[w + 1] |= v >> (64 - o);
            }
            bit += width;
        }
        self.n += 1;
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.b
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Field `i` of plane `k`.
    #[inline]
    pub fn field(&self, k: usize, i: usize) -> u64 {
        debug_assert!(k < self.b && i < self.n);
        let bit = i * self.b * self.width + k * self.width;
        let idx = bit >> 6;
        let o = bit & 63;
        let w0 = self.words[idx];
        let w1 = self.words[idx + 1]; // padding keeps this in-bounds
        ((w0 >> o) | ((w1 << (63 - o)) << 1)) & self.mask
    }

    /// Hamming distance between item `i` and pre-packed query fields
    /// (`q[k]` = plane-k field): XOR planes, OR-fold, popcount. All of
    /// item `i`'s fields are adjacent, so the loop walks 1–2 cache lines.
    #[inline]
    pub fn ham(&self, i: usize, q: &[u64]) -> usize {
        debug_assert_eq!(q.len(), self.b);
        if self.width == 64 {
            // word-aligned fast path: no shifts at all
            let base = i * self.b;
            let mut acc = 0u64;
            for (k, &qk) in q.iter().enumerate() {
                acc |= self.words[base + k] ^ qk;
            }
            return acc.count_ones() as usize;
        }
        let mut bit = i * self.b * self.width;
        let mut acc = 0u64;
        for &qk in q {
            let idx = bit >> 6;
            let o = bit & 63;
            let w0 = self.words[idx];
            let w1 = self.words[idx + 1];
            acc |= ((w0 >> o) | ((w1 << (63 - o)) << 1)) ^ qk;
            bit += self.width;
        }
        (acc & self.mask).count_ones() as usize
    }

    /// `Some(d)` iff `ham(i, q) <= tau`, with an incremental lower-bound
    /// early exit for `b > 1`: between planes, if the popcount of the
    /// OR-accumulator (which only grows) already exceeds `tau`, the
    /// remaining planes are never fetched.
    #[inline]
    pub fn ham_leq(&self, i: usize, q: &[u64], tau: usize) -> Option<usize> {
        debug_assert!(i < self.n);
        debug_assert_eq!(q.len(), self.b);
        if self.width == 64 {
            self.ham_leq_aligned(i, q, tau)
        } else {
            self.ham_leq_stream(i * self.b * self.width, q, tau)
        }
    }

    /// Word-aligned per-item verification (`width == 64`): no shifts.
    #[inline(always)]
    fn ham_leq_aligned(&self, i: usize, q: &[u64], tau: usize) -> Option<usize> {
        let base = i * self.b;
        let mut acc = 0u64;
        for (k, &qk) in q.iter().enumerate() {
            if k > 0 && acc.count_ones() as usize > tau {
                return None;
            }
            acc |= self.words[base + k] ^ qk;
        }
        let d = acc.count_ones() as usize;
        (d <= tau).then_some(d)
    }

    /// Generic per-item verification at a pre-computed bit offset: the
    /// two-word fetch per plane, with the incremental early exit. `acc`
    /// carries garbage above `width` bits (neighboring fields), so every
    /// popcount masks first.
    #[inline(always)]
    fn ham_leq_stream(&self, mut bit: usize, q: &[u64], tau: usize) -> Option<usize> {
        let mut acc = 0u64;
        for (k, &qk) in q.iter().enumerate() {
            if k > 0 && (acc & self.mask).count_ones() as usize > tau {
                return None;
            }
            let idx = bit >> 6;
            let o = bit & 63;
            let w0 = self.words[idx];
            let w1 = self.words[idx + 1]; // padding keeps this in-bounds
            acc |= ((w0 >> o) | ((w1 << (63 - o)) << 1)) ^ qk;
            bit += self.width;
        }
        let d = (acc & self.mask).count_ones() as usize;
        (d <= tau).then_some(d)
    }

    /// One-word-per-item verification (`b·width == 64`, `width < 64`):
    /// single load, XOR against the pre-packed query word, then an
    /// OR-fold of the `b` lanes down to the low `width` bits. `width`
    /// divides 64 here, so it is a power of two and the halving fold is
    /// exact.
    #[inline(always)]
    fn ham_leq_word(&self, i: usize, q_word: u64, tau: usize) -> Option<usize> {
        let mut f = self.words[i] ^ q_word;
        let mut step = 32usize;
        while step >= self.width {
            f |= f >> step;
            step >>= 1;
        }
        let d = (f & self.mask).count_ones() as usize;
        (d <= tau).then_some(d)
    }

    /// Packs the `b` query plane fields into the one-word item layout
    /// (`q[k]` at bits `[k·width, (k+1)·width)`), for the
    /// `b·width == 64` fast path.
    #[inline]
    fn pack_item_word(&self, q: &[u64]) -> u64 {
        let mut w = 0u64;
        for (k, &qk) in q.iter().enumerate() {
            w |= (qk & self.mask) << (k * self.width);
        }
        w
    }

    /// Opens a streaming verification cursor over items `[lo, hi)` —
    /// see the module docs for the kernel contract.
    #[inline]
    pub fn range_scan<'a>(&'a self, lo: usize, hi: usize, q: &'a [u64]) -> RangeHam<'a> {
        assert!(lo <= hi && hi <= self.n, "range {lo}..{hi} out of 0..{}", self.n);
        debug_assert_eq!(q.len(), self.b);
        let item_bits = self.b * self.width;
        let q_word = if self.width < 64 && item_bits == 64 {
            self.pack_item_word(q)
        } else {
            0
        };
        RangeHam { store: self, q, q_word, item_bits, i: lo, hi, bit: lo * item_bits }
    }

    /// Streaming range kernel: verifies items `lo..hi` in ascending
    /// order. `sink(i, verdict)` is called once per item and returns the
    /// threshold for the next item (`None` stops the scan). The first
    /// item is verified against `tau0`. See the module docs.
    pub fn ham_range_leq<F>(&self, lo: usize, hi: usize, q: &[u64], tau0: usize, mut sink: F)
    where
        F: FnMut(usize, Option<usize>) -> Option<usize>,
    {
        let mut cur = self.range_scan(lo, hi, q);
        let mut tau = tau0;
        for i in lo..hi {
            match sink(i, cur.next_leq(tau)) {
                Some(t) => tau = t,
                None => return,
            }
        }
    }

    /// Batched candidate kernel: verifies the (possibly duplicate-heavy,
    /// typically near-sorted) id list in order. Same sink contract as
    /// [`Self::ham_range_leq`]; the per-query setup (fast-path dispatch,
    /// query-word packing) is hoisted out of the per-candidate loop.
    pub fn ham_many_leq<F>(&self, ids: &[u32], q: &[u64], tau0: usize, mut sink: F)
    where
        F: FnMut(u32, Option<usize>) -> Option<usize>,
    {
        debug_assert_eq!(q.len(), self.b);
        debug_assert!(ids.iter().all(|&id| (id as usize) < self.n));
        let mut tau = tau0;
        if self.width == 64 {
            for &id in ids {
                match sink(id, self.ham_leq_aligned(id as usize, q, tau)) {
                    Some(t) => tau = t,
                    None => return,
                }
            }
            return;
        }
        let item_bits = self.b * self.width;
        if item_bits == 64 {
            let qw = self.pack_item_word(q);
            for &id in ids {
                match sink(id, self.ham_leq_word(id as usize, qw, tau)) {
                    Some(t) => tau = t,
                    None => return,
                }
            }
            return;
        }
        for &id in ids {
            match sink(id, self.ham_leq_stream(id as usize * item_bits, q, tau)) {
                Some(t) => tau = t,
                None => return,
            }
        }
    }

    /// Fetches all `b` plane words of the item starting at bit offset
    /// `bit` into `out` (unmasked — the folds mask at popcount time,
    /// exactly like the streaming per-item path).
    #[inline(always)]
    fn load_item_planes(&self, mut bit: usize, out: &mut [u64]) {
        for slot in out.iter_mut() {
            let idx = bit >> 6;
            let o = bit & 63;
            let w0 = self.words[idx];
            let w1 = self.words[idx + 1]; // padding keeps this in-bounds
            *slot = (w0 >> o) | ((w1 << (63 - o)) << 1);
            bit += self.width;
        }
    }

    /// Multi-query streaming range kernel: verifies items `lo..hi` in
    /// ascending order against a *block* of `m = taus0.len()` queries in
    /// one pass — each item's plane words are fetched once and folded
    /// against every live query in registers, so the memory-traffic bill
    /// is paid once per item instead of once per (item, query).
    ///
    /// `qs` holds the packed query planes back to back (`m·b` words,
    /// query `j` at `qs[j·b .. (j+1)·b]`). `live0` selects the initially
    /// live queries (bit `j` = query `j`; clamped to the low `m` bits).
    ///
    /// `sink(j, i, verdict)` is invoked once per (live query, item) pair
    /// — queries in ascending `j` within each item — and returns query
    /// `j`'s threshold for the next item, or `None` to drop query `j`
    /// from the block's live mask (it sees no further items). The pass
    /// finishes as soon as the mask empties. Verdicts are bit-identical
    /// to the serial kernels at the same live threshold, fast paths
    /// (`width == 64`, `b·width == 64`) included.
    pub fn ham_range_leq_multi<F>(
        &self,
        lo: usize,
        hi: usize,
        qs: &[u64],
        taus0: &[usize],
        live0: u64,
        mut sink: F,
    ) where
        F: FnMut(usize, usize, Option<usize>) -> Option<usize>,
    {
        assert!(lo <= hi && hi <= self.n, "range {lo}..{hi} out of 0..{}", self.n);
        let b = self.b;
        let m = taus0.len();
        assert!(m <= MAX_BLOCK, "block of {m} queries exceeds MAX_BLOCK");
        assert_eq!(qs.len(), m * b, "expected {m} x {b} packed query planes");
        let mut taus = [0usize; MAX_BLOCK];
        taus[..m].copy_from_slice(taus0);
        let mut live = live0 & live_mask(m);
        if live == 0 {
            return;
        }

        if self.width == 64 {
            for i in lo..hi {
                let item = &self.words[i * b..(i + 1) * b];
                let mut rem = live;
                while rem != 0 {
                    let j = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let verdict = fold_leq(item, &qs[j * b..(j + 1) * b], u64::MAX, taus[j]);
                    match sink(j, i, verdict) {
                        Some(t) => taus[j] = t,
                        None => live &= !(1u64 << j),
                    }
                }
                if live == 0 {
                    return;
                }
            }
            return;
        }
        let item_bits = b * self.width;
        if item_bits == 64 {
            // Hoisted per-query setup: one packed query word per slot.
            let mut qw = [0u64; MAX_BLOCK];
            let mut rem = live;
            while rem != 0 {
                let j = rem.trailing_zeros() as usize;
                rem &= rem - 1;
                qw[j] = self.pack_item_word(&qs[j * b..(j + 1) * b]);
            }
            for i in lo..hi {
                let w = self.words[i];
                let mut rem = live;
                while rem != 0 {
                    let j = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let verdict = fold_word_leq(w, qw[j], self.width, self.mask, taus[j]);
                    match sink(j, i, verdict) {
                        Some(t) => taus[j] = t,
                        None => live &= !(1u64 << j),
                    }
                }
                if live == 0 {
                    return;
                }
            }
            return;
        }
        // Generic path: rolling bit cursor, each item's planes staged
        // once in a stack buffer and folded per live query.
        let mut bit = lo * item_bits;
        if b <= MAX_ITEM_PLANES {
            let mut item = [0u64; MAX_ITEM_PLANES];
            for i in lo..hi {
                self.load_item_planes(bit, &mut item[..b]);
                bit += item_bits;
                let mut rem = live;
                while rem != 0 {
                    let j = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let verdict =
                        fold_leq(&item[..b], &qs[j * b..(j + 1) * b], self.mask, taus[j]);
                    match sink(j, i, verdict) {
                        Some(t) => taus[j] = t,
                        None => live &= !(1u64 << j),
                    }
                }
                if live == 0 {
                    return;
                }
            }
        } else {
            // b > 8 never occurs for sketches; keep correctness anyway
            // with per-query streaming reads (no shared staging).
            for i in lo..hi {
                let mut rem = live;
                while rem != 0 {
                    let j = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let verdict =
                        self.ham_leq_stream(bit, &qs[j * b..(j + 1) * b], taus[j]);
                    match sink(j, i, verdict) {
                        Some(t) => taus[j] = t,
                        None => live &= !(1u64 << j),
                    }
                }
                bit += item_bits;
                if live == 0 {
                    return;
                }
            }
        }
    }

    /// Multi-query batched candidate kernel: verifies the (possibly
    /// duplicate-heavy) id list in order against a block of queries,
    /// fetching each candidate's plane words once. Same block contract
    /// as [`Self::ham_range_leq_multi`] — `sink(j, id, verdict)` with
    /// per-query live thresholds and the drop-on-`None` live mask.
    pub fn ham_many_leq_multi<F>(
        &self,
        ids: &[u32],
        qs: &[u64],
        taus0: &[usize],
        live0: u64,
        mut sink: F,
    ) where
        F: FnMut(usize, u32, Option<usize>) -> Option<usize>,
    {
        debug_assert!(ids.iter().all(|&id| (id as usize) < self.n));
        let b = self.b;
        let m = taus0.len();
        assert!(m <= MAX_BLOCK, "block of {m} queries exceeds MAX_BLOCK");
        assert_eq!(qs.len(), m * b, "expected {m} x {b} packed query planes");
        let mut taus = [0usize; MAX_BLOCK];
        taus[..m].copy_from_slice(taus0);
        let mut live = live0 & live_mask(m);
        if live == 0 {
            return;
        }

        if self.width == 64 {
            for &id in ids {
                let item = &self.words[id as usize * b..(id as usize + 1) * b];
                let mut rem = live;
                while rem != 0 {
                    let j = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let verdict = fold_leq(item, &qs[j * b..(j + 1) * b], u64::MAX, taus[j]);
                    match sink(j, id, verdict) {
                        Some(t) => taus[j] = t,
                        None => live &= !(1u64 << j),
                    }
                }
                if live == 0 {
                    return;
                }
            }
            return;
        }
        let item_bits = b * self.width;
        if item_bits == 64 {
            let mut qw = [0u64; MAX_BLOCK];
            let mut rem = live;
            while rem != 0 {
                let j = rem.trailing_zeros() as usize;
                rem &= rem - 1;
                qw[j] = self.pack_item_word(&qs[j * b..(j + 1) * b]);
            }
            for &id in ids {
                let w = self.words[id as usize];
                let mut rem = live;
                while rem != 0 {
                    let j = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let verdict = fold_word_leq(w, qw[j], self.width, self.mask, taus[j]);
                    match sink(j, id, verdict) {
                        Some(t) => taus[j] = t,
                        None => live &= !(1u64 << j),
                    }
                }
                if live == 0 {
                    return;
                }
            }
            return;
        }
        let mut item = [0u64; MAX_ITEM_PLANES];
        for &id in ids {
            let bit = id as usize * item_bits;
            let mut rem = live;
            if b <= MAX_ITEM_PLANES {
                self.load_item_planes(bit, &mut item[..b]);
            }
            while rem != 0 {
                let j = rem.trailing_zeros() as usize;
                rem &= rem - 1;
                let verdict = if b <= MAX_ITEM_PLANES {
                    fold_leq(&item[..b], &qs[j * b..(j + 1) * b], self.mask, taus[j])
                } else {
                    self.ham_leq_stream(bit, &qs[j * b..(j + 1) * b], taus[j])
                };
                match sink(j, id, verdict) {
                    Some(t) => taus[j] = t,
                    None => live &= !(1u64 << j),
                }
            }
            if live == 0 {
                return;
            }
        }
    }
}

/// Streaming verification cursor over a contiguous item range, created
/// by [`PlaneStore::range_scan`]. Carries a rolling bit offset so every
/// `next_leq` issues sequential loads; the fast-path dispatch and the
/// one-word query packing happen once at construction.
pub struct RangeHam<'a> {
    store: &'a PlaneStore,
    q: &'a [u64],
    /// Pre-packed one-word query (`b·width == 64` fast path only).
    q_word: u64,
    /// Bits per item (`b·width`).
    item_bits: usize,
    /// Next item to verify.
    i: usize,
    /// Exclusive range end.
    hi: usize,
    /// Rolling bit cursor (`i · item_bits`).
    bit: usize,
}

impl RangeHam<'_> {
    /// Items not yet verified.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.hi - self.i
    }

    /// Verifies the next item against the live threshold `tau` and
    /// advances: `Some(d)` iff its exact distance `d <= tau` (over-
    /// threshold items bail early without a full distance — see the
    /// module docs). Must not be called past the range end.
    #[inline]
    pub fn next_leq(&mut self, tau: usize) -> Option<usize> {
        debug_assert!(self.i < self.hi, "range cursor exhausted");
        let s = self.store;
        let i = self.i;
        self.i += 1;
        if s.width == 64 {
            return s.ham_leq_aligned(i, self.q, tau);
        }
        let bit = self.bit;
        self.bit += self.item_bits;
        if self.item_bits == 64 {
            s.ham_leq_word(i, self.q_word, tau)
        } else {
            s.ham_leq_stream(bit, self.q, tau)
        }
    }
}

impl Persist for PlaneStore {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.width);
        w.put_usize(self.n);
        w.put_u64s(&self.words);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let width = r.get_usize()?;
        let n = r.get_usize()?;
        let words = r.get_u64s_ref()?;
        ensure(width <= 64, || format!("PlaneStore: width {width} > 64"))?;
        let total_bits = n
            .checked_mul(b)
            .and_then(|x| x.checked_mul(width))
            .ok_or_else(|| StoreError::Corrupt("PlaneStore: dimensions overflow".into()))?;
        ensure(words.len() == total_bits.div_ceil(64) + 2, || {
            format!(
                "PlaneStore: {} words for {total_bits} payload bits (+2 padding)",
                words.len()
            )
        })?;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        Ok(PlaneStore { b, width, n, words, mask })
    }
}

impl HeapSize for PlaneStore {
    fn heap_bytes(&self) -> usize {
        self.words.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn field_roundtrip_random_widths() {
        let mut rng = Rng::new(1);
        for &width in &[1usize, 5, 16, 21, 32, 33, 63, 64] {
            let (b, n) = (3usize, 200usize);
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            for k in 0..b {
                for i in 0..n {
                    assert_eq!(ps.field(k, i), vals[k * n + i], "w={width} k={k} i={i}");
                }
            }
        }
    }

    #[test]
    fn ham_matches_reference() {
        let mut rng = Rng::new(2);
        for &(b, width) in &[(1usize, 16usize), (2, 16), (4, 32), (8, 64), (2, 21)] {
            let n = 100;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();
            for i in 0..n {
                let mut acc = 0u64;
                for k in 0..b {
                    acc |= vals[k * n + i] ^ q[k];
                }
                let expect = (acc & mask).count_ones() as usize;
                assert_eq!(ps.ham(i, &q), expect, "b={b} w={width} i={i}");
                assert_eq!(ps.ham_leq(i, &q, expect), Some(expect));
                if expect > 0 {
                    assert_eq!(ps.ham_leq(i, &q, expect - 1), None);
                }
            }
        }
    }

    #[test]
    fn zero_width_is_rejected_gracefully() {
        // width 0 is never used (ls == L handled by suffix_len 0 checks
        // upstream) but from_fn must not panic for n = 0 fields.
        let ps = PlaneStore::from_fn(2, 8, 0, |_, _| 0);
        assert_eq!(ps.n(), 0);
    }

    /// Shapes covering every kernel dispatch: generic streaming,
    /// `b·width == 64` one-word, and `width == 64` aligned.
    const KERNEL_SHAPES: &[(usize, usize)] =
        &[(1, 16), (2, 16), (2, 32), (4, 16), (4, 32), (8, 8), (8, 64), (2, 21), (3, 13)];

    #[test]
    fn range_kernel_matches_per_item() {
        let mut rng = Rng::new(5);
        for &(b, width) in KERNEL_SHAPES {
            let n = 150;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();
            for tau in [0usize, 1, width / 2, width] {
                let (lo, hi) = (n / 5, n - n / 7);
                let mut expect_i = lo;
                ps.ham_range_leq(lo, hi, &q, tau, |i, verdict| {
                    assert_eq!(i, expect_i);
                    expect_i += 1;
                    let d = ps.ham(i, &q);
                    assert_eq!(verdict, (d <= tau).then_some(d), "b={b} w={width} i={i} tau={tau}");
                    assert_eq!(verdict, ps.ham_leq(i, &q, tau));
                    Some(tau)
                });
                assert_eq!(expect_i, hi, "b={b} w={width}: kernel must cover the range");
            }
        }
    }

    #[test]
    fn batch_kernel_matches_per_item_with_duplicates() {
        let mut rng = Rng::new(6);
        for &(b, width) in KERNEL_SHAPES {
            let n = 120;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();
            // duplicate-heavy, unsorted candidate list
            let ids: Vec<u32> = (0..3 * n).map(|_| rng.below(n as u64) as u32).collect();
            let tau = width / 3;
            let mut seen = 0usize;
            ps.ham_many_leq(&ids, &q, tau, |id, verdict| {
                assert_eq!(id, ids[seen]);
                seen += 1;
                let d = ps.ham(id as usize, &q);
                assert_eq!(verdict, (d <= tau).then_some(d), "b={b} w={width} id={id}");
                Some(tau)
            });
            assert_eq!(seen, ids.len());
        }
    }

    #[test]
    fn kernels_honor_live_tau_and_early_stop() {
        let mut rng = Rng::new(7);
        let (b, width, n) = (4usize, 32usize, 100usize);
        let mask = (1u64 << width) - 1;
        let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
        let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
        let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();

        // tau shrinks every 10 items; verdicts must track the live value.
        let mut tau = width;
        ps.ham_range_leq(0, n, &q, tau, |i, verdict| {
            let d = ps.ham(i, &q);
            assert_eq!(verdict, (d <= tau).then_some(d), "i={i} live tau={tau}");
            if i % 10 == 9 {
                tau = tau.saturating_sub(3);
            }
            Some(tau)
        });

        // a None sink return stops the scan immediately.
        let mut calls = 0usize;
        ps.ham_range_leq(0, n, &q, width, |_, _| {
            calls += 1;
            (calls < 7).then_some(width)
        });
        assert_eq!(calls, 7);
        let ids: Vec<u32> = (0..n as u32).collect();
        calls = 0;
        ps.ham_many_leq(&ids, &q, width, |_, _| {
            calls += 1;
            (calls < 5).then_some(width)
        });
        assert_eq!(calls, 5);
    }

    #[test]
    fn push_fields_matches_from_fn() {
        let mut rng = Rng::new(9);
        for &(b, width) in KERNEL_SHAPES {
            let n = 77;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let built = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let mut grown = PlaneStore::with_dims(b, width);
            assert_eq!(grown.n(), 0);
            let mut item = vec![0u64; b];
            for i in 0..n {
                for (k, f) in item.iter_mut().enumerate() {
                    *f = vals[k * n + i];
                }
                grown.push_fields(&item);
            }
            assert_eq!(grown.n(), n);
            // Bit-identical to the one-shot construction: same fields,
            // same words, same snapshot payload.
            for k in 0..b {
                for i in 0..n {
                    assert_eq!(grown.field(k, i), built.field(k, i), "b={b} w={width}");
                }
            }
            assert_eq!(grown.words, built.words, "b={b} w={width}");
            assert_eq!(
                crate::store::to_payload(&grown),
                crate::store::to_payload(&built),
                "b={b} w={width}"
            );
            // ...and the streaming kernels see the appended items.
            let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();
            let tau = width / 2;
            let mut ok = 0usize;
            grown.ham_range_leq(0, n, &q, tau, |i, verdict| {
                assert_eq!(verdict, built.ham_leq(i, &q, tau));
                ok += 1;
                Some(tau)
            });
            assert_eq!(ok, n);
        }
    }

    #[test]
    fn multi_range_kernel_matches_serial_per_query() {
        let mut rng = Rng::new(21);
        for &(b, width) in KERNEL_SHAPES {
            let n = 130;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let m = 5usize;
            let qs: Vec<u64> = (0..m * b).map(|_| rng.next_u64() & mask).collect();
            let taus: Vec<usize> = (0..m).map(|j| j * width / 4).collect();
            let (lo, hi) = (n / 6, n - n / 9);

            // Serial oracle: one pass per query, verdicts recorded.
            let mut expect: Vec<Vec<Option<usize>>> = Vec::new();
            for j in 0..m {
                let mut row = Vec::new();
                ps.ham_range_leq(lo, hi, &qs[j * b..(j + 1) * b], taus[j], |_, v| {
                    row.push(v);
                    Some(taus[j])
                });
                expect.push(row);
            }

            let mut got: Vec<Vec<Option<usize>>> = vec![Vec::new(); m];
            let mut expect_i = lo;
            let mut expect_j = 0usize;
            ps.ham_range_leq_multi(lo, hi, &qs, &taus, u64::MAX, |j, i, v| {
                // queries ascend within each item, items ascend
                assert_eq!(i, expect_i, "b={b} w={width}");
                assert_eq!(j, expect_j, "b={b} w={width}");
                expect_j += 1;
                if expect_j == m {
                    expect_j = 0;
                    expect_i += 1;
                }
                got[j].push(v);
                Some(taus[j])
            });
            assert_eq!(expect_i, hi, "b={b} w={width}: block pass must cover the range");
            assert_eq!(got, expect, "b={b} w={width}");
        }
    }

    #[test]
    fn multi_batch_kernel_matches_serial_per_query() {
        let mut rng = Rng::new(22);
        for &(b, width) in KERNEL_SHAPES {
            let n = 90;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let m = 4usize;
            let qs: Vec<u64> = (0..m * b).map(|_| rng.next_u64() & mask).collect();
            let taus: Vec<usize> = (0..m).map(|j| (j + 1) * width / 3).collect();
            // duplicate-heavy, unsorted candidate list
            let ids: Vec<u32> = (0..2 * n).map(|_| rng.below(n as u64) as u32).collect();

            let mut expect: Vec<Vec<Option<usize>>> = Vec::new();
            for j in 0..m {
                let mut row = Vec::new();
                ps.ham_many_leq(&ids, &qs[j * b..(j + 1) * b], taus[j], |_, v| {
                    row.push(v);
                    Some(taus[j])
                });
                expect.push(row);
            }

            let mut got: Vec<Vec<Option<usize>>> = vec![Vec::new(); m];
            let mut seen = 0usize;
            ps.ham_many_leq_multi(&ids, &qs, &taus, u64::MAX, |j, id, v| {
                assert_eq!(id, ids[seen / m], "b={b} w={width}");
                seen += 1;
                got[j].push(v);
                Some(taus[j])
            });
            assert_eq!(seen, m * ids.len());
            assert_eq!(got, expect, "b={b} w={width}");
        }
    }

    #[test]
    fn multi_kernels_track_live_taus_drop_queries_and_early_stop() {
        let mut rng = Rng::new(23);
        for &(b, width) in &[(2usize, 16usize), (4, 16), (8, 8), (2, 21)] {
            let n = 80;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let m = 3usize;
            let qs: Vec<u64> = (0..m * b).map(|_| rng.next_u64() & mask).collect();

            // Per-query live tau schedules: query j's tau shrinks every
            // (5 + j) items; verdicts must match serial under the same
            // schedule.
            let taus0 = vec![width; m];
            let mut expect: Vec<Vec<Option<usize>>> = Vec::new();
            for j in 0..m {
                let mut tau = width;
                let mut row = Vec::new();
                let mut step = 0usize;
                ps.ham_range_leq(0, n, &qs[j * b..(j + 1) * b], tau, |_, v| {
                    row.push(v);
                    step += 1;
                    if step % (5 + j) == 0 {
                        tau = tau.saturating_sub(2);
                    }
                    Some(tau)
                });
                expect.push(row);
            }
            let mut live_taus = vec![width; m];
            let mut steps = vec![0usize; m];
            let mut got: Vec<Vec<Option<usize>>> = vec![Vec::new(); m];
            ps.ham_range_leq_multi(0, n, &qs, &taus0, u64::MAX, |j, _i, v| {
                got[j].push(v);
                steps[j] += 1;
                if steps[j] % (5 + j) == 0 {
                    live_taus[j] = live_taus[j].saturating_sub(2);
                }
                Some(live_taus[j])
            });
            assert_eq!(got, expect, "b={b} w={width} live-tau schedule");

            // Dropping: query j sees exactly (j+1)*7 items then leaves
            // the mask; once all are dropped the pass stops entirely.
            let mut counts = vec![0usize; m];
            ps.ham_range_leq_multi(0, n, &qs, &taus0, u64::MAX, |j, _i, _v| {
                counts[j] += 1;
                (counts[j] < (j + 1) * 7).then_some(width)
            });
            for (j, &c) in counts.iter().enumerate() {
                assert_eq!(c, (j + 1) * 7, "b={b} w={width} query {j} drop point");
            }

            // live0 subset: excluded queries get zero callbacks; the
            // included one matches a constant-tau serial pass exactly.
            let mut expect_j1: Vec<Option<usize>> = Vec::new();
            ps.ham_range_leq(0, n, &qs[b..2 * b], width, |_, v| {
                expect_j1.push(v);
                Some(width)
            });
            let mut got_j1: Vec<Option<usize>> = Vec::new();
            ps.ham_range_leq_multi(0, n, &qs, &taus0, 0b010, |j, _i, v| {
                assert_eq!(j, 1, "only query 1 is live");
                got_j1.push(v);
                Some(width)
            });
            assert_eq!(got_j1, expect_j1, "b={b} w={width}");

            // empty mask: no callbacks at all.
            ps.ham_range_leq_multi(0, n, &qs, &taus0, 0, |_, _, _| {
                panic!("no query is live");
            });
            ps.ham_many_leq_multi(&[0, 1, 2], &qs, &taus0, 0, |_, _, _| {
                panic!("no query is live");
            });
        }
    }

    #[test]
    fn multi_batch_kernel_drops_and_subsets() {
        let mut rng = Rng::new(24);
        let (b, width, n) = (4usize, 16usize, 60usize);
        let mask = (1u64 << width) - 1;
        let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
        let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
        let m = 3usize;
        let qs: Vec<u64> = (0..m * b).map(|_| rng.next_u64() & mask).collect();
        let taus = vec![width / 2; m];
        let ids: Vec<u32> = (0..n as u32).collect();

        let mut counts = vec![0usize; m];
        ps.ham_many_leq_multi(&ids, &qs, &taus, u64::MAX, |j, _id, _v| {
            counts[j] += 1;
            (counts[j] < 4 + j).then_some(taus[j])
        });
        for (j, &c) in counts.iter().enumerate() {
            assert_eq!(c, 4 + j, "query {j} drop point");
        }

        // subset mask: only query 2 runs, and matches serial.
        let mut expect = Vec::new();
        ps.ham_many_leq(&ids, &qs[2 * b..3 * b], taus[2], |_, v| {
            expect.push(v);
            Some(taus[2])
        });
        let mut got = Vec::new();
        ps.ham_many_leq_multi(&ids, &qs, &taus, 0b100, |j, _id, v| {
            assert_eq!(j, 2);
            got.push(v);
            Some(taus[2])
        });
        assert_eq!(got, expect);
    }

    #[test]
    fn cursor_streams_the_whole_range() {
        let mut rng = Rng::new(8);
        for &(b, width) in &[(2usize, 32usize), (8, 64), (4, 11)] {
            let n = 90;
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let vals: Vec<u64> = (0..b * n).map(|_| rng.next_u64() & mask).collect();
            let ps = PlaneStore::from_fn(b, width, n, |k, i| vals[k * n + i]);
            let q: Vec<u64> = (0..b).map(|_| rng.next_u64() & mask).collect();
            let mut cur = ps.range_scan(10, n, &q);
            assert_eq!(cur.remaining(), n - 10);
            for i in 10..n {
                let tau = i % (width + 1);
                let got = cur.next_leq(tau);
                let d = ps.ham(i, &q);
                assert_eq!(got, (d <= tau).then_some(d), "b={b} w={width} i={i}");
            }
            assert_eq!(cur.remaining(), 0);
        }
    }
}
