//! Hamming distance kernels.
//!
//! Three implementations, fastest last (§V-C of the paper):
//!
//! 1. naive character-by-character — `O(L)`;
//! 2. horizontal SWAR over packed words — `O(b · ⌈Lb/64⌉)` word ops;
//! 3. vertical (bit-plane) — `O(b · ⌈L/64⌉)` word ops: XOR the planes,
//!    OR-accumulate, popcount. The paper measured >10× over naive for
//!    `L = 32, b = 4`; bench `hamming` reproduces the comparison.

/// Naive Hamming distance over raw character rows.
#[inline]
pub fn ham_chars(a: &[u8], b: &[u8]) -> usize {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Per-lane "nonzero" mask collapse: given `x = a ^ b` with `b`-bit lanes,
/// returns a word with bit set at each lane's LSB iff the lane is nonzero.
#[inline]
fn lane_nonzero(x: u64, b: usize) -> u64 {
    match b {
        1 => x,
        2 => (x | (x >> 1)) & 0x5555_5555_5555_5555,
        4 => {
            let t = x | (x >> 1);
            let t = t | (t >> 2);
            t & 0x1111_1111_1111_1111
        }
        8 => {
            let t = x | (x >> 1);
            let t = t | (t >> 2);
            let t = t | (t >> 4);
            t & 0x0101_0101_0101_0101
        }
        _ => unreachable!("b must be 1,2,4,8"),
    }
}

/// Horizontal Hamming distance between two packed sketches (same layout as
/// [`super::SketchSet`]): XOR words, collapse each b-bit lane to one bit,
/// popcount. Padding lanes (beyond the sketch length) are zero in both
/// inputs, so they never contribute.
#[inline]
pub fn ham_horizontal(a: &[u64], b: &[u64], bits: usize) -> usize {
    debug_assert_eq!(a.len(), b.len());
    let mut total = 0usize;
    for (&x, &y) in a.iter().zip(b) {
        total += lane_nonzero(x ^ y, bits).count_ones() as usize;
    }
    total
}

/// Vertical Hamming distance for `L <= 64`: `planes[k]` holds bit `k` of
/// every character packed into one word per sketch.
///
/// `bits_or = OR_k (a_planes[k] ^ q_planes[k])` has one set bit per
/// mismatching position; `popcnt` finishes the job (Zhang et al.'s trick).
#[inline]
pub fn ham_vertical(a_planes: &[u64], q_planes: &[u64]) -> usize {
    debug_assert_eq!(a_planes.len(), q_planes.len());
    let mut acc = 0u64;
    for (&x, &y) in a_planes.iter().zip(q_planes) {
        acc |= x ^ y;
    }
    acc.count_ones() as usize
}

/// Char-row Hamming with early exit: `Some(d)` iff `d <= tau`, bailing
/// out the moment the running mismatch count exceeds `tau` — the same
/// incremental lower-bound discipline the word kernels use, for the raw
/// character fallback (`L > 64` delta rows, where no vertical layout
/// exists).
#[inline]
pub fn ham_chars_leq(a: &[u8], q: &[u8], tau: usize) -> Option<usize> {
    debug_assert_eq!(a.len(), q.len());
    let mut d = 0usize;
    for (x, y) in a.iter().zip(q) {
        if x != y {
            d += 1;
            if d > tau {
                return None;
            }
        }
    }
    Some(d)
}

/// Vertical Hamming with early-exit threshold: returns `None` if the
/// distance exceeds `tau`. For `b ∈ {4, 8}` the running popcount of the
/// OR-accumulator — a lower bound on the final distance, since OR only
/// grows — is checked between planes, so over-threshold items bail
/// before touching all planes (previously `tau` was only applied after
/// the full fold).
#[inline]
pub fn ham_vertical_leq(a_planes: &[u64], q_planes: &[u64], tau: usize) -> Option<usize> {
    debug_assert_eq!(a_planes.len(), q_planes.len());
    let b = a_planes.len();
    let mut acc = 0u64;
    if b >= 4 {
        for (k, (&x, &y)) in a_planes.iter().zip(q_planes).enumerate() {
            if k > 0 && acc.count_ones() as usize > tau {
                return None;
            }
            acc |= x ^ y;
        }
    } else {
        for (&x, &y) in a_planes.iter().zip(q_planes) {
            acc |= x ^ y;
        }
    }
    let d = acc.count_ones() as usize;
    (d <= tau).then_some(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{SketchSet, VerticalSet};
    use crate::util::Rng;

    #[test]
    fn lane_nonzero_counts() {
        // b=2: chars 0..4 packed; differences in lanes 0 and 2
        let a = 0b00_01_10_11u64;
        let b = 0b00_11_10_00u64;
        assert_eq!(lane_nonzero(a ^ b, 2).count_ones(), 2);
        // b=8
        let a = 0x00_FF_01_00_00_00_00_AAu64;
        let b = 0x00_FF_02_00_01_00_00_AAu64;
        assert_eq!(lane_nonzero(a ^ b, 8).count_ones(), 2);
    }

    #[test]
    fn horizontal_matches_naive() {
        let mut rng = Rng::new(21);
        for &b in &[1usize, 2, 4, 8] {
            for &l in &[1usize, 7, 16, 32, 63, 64] {
                if l * b > 64 * 8 {
                    continue;
                }
                let rows: Vec<Vec<u8>> = (0..30)
                    .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
                    .collect();
                let set = SketchSet::from_rows(b, l, &rows);
                for i in 0..rows.len() {
                    for j in 0..rows.len() {
                        let q = set.pack_row(&rows[j]);
                        assert_eq!(
                            set.ham_packed(i, &q),
                            ham_chars(&rows[i], &rows[j]),
                            "b={b} l={l} i={i} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn vertical_matches_naive() {
        let mut rng = Rng::new(23);
        for &b in &[1usize, 2, 4, 8] {
            let l = 33.min(64);
            let rows: Vec<Vec<u8>> = (0..40)
                .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
                .collect();
            let set = SketchSet::from_rows(b, l, &rows);
            let vert = VerticalSet::from_horizontal(&set);
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    let qp = vert.pack_query(&rows[j]);
                    assert_eq!(
                        ham_vertical(&vert.planes_of(i), &qp),
                        ham_chars(&rows[i], &rows[j]),
                        "b={b} i={i} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn chars_leq_agrees_with_naive_for_every_tau() {
        let mut rng = Rng::new(31);
        for _ in 0..100 {
            let l = 1 + rng.below_usize(100);
            let a: Vec<u8> = (0..l).map(|_| rng.below(4) as u8).collect();
            let q: Vec<u8> = (0..l).map(|_| rng.below(4) as u8).collect();
            let d = ham_chars(&a, &q);
            for tau in [0usize, d.saturating_sub(1), d, d + 1, l] {
                assert_eq!(
                    ham_chars_leq(&a, &q, tau),
                    (d <= tau).then_some(d),
                    "d={d} tau={tau}"
                );
            }
        }
    }

    #[test]
    fn vertical_leq_thresholds() {
        let a = [0b1010u64, 0b0110u64];
        let q = [0b1010u64, 0b0000u64];
        // mismatches where planes differ: plane1 differs at positions 1,2
        let d = ham_vertical(&a, &q);
        assert_eq!(ham_vertical_leq(&a, &q, d), Some(d));
        assert_eq!(ham_vertical_leq(&a, &q, d.saturating_sub(1)), None);
    }

    #[test]
    fn vertical_leq_early_exit_agrees_with_full_fold() {
        // b = 4 and 8 take the incremental-lower-bound path; the verdict
        // must match the full fold for every tau.
        let mut rng = Rng::new(29);
        for &b in &[4usize, 8] {
            for _ in 0..200 {
                let a: Vec<u64> = (0..b).map(|_| rng.next_u64()).collect();
                let q: Vec<u64> = (0..b).map(|_| rng.next_u64()).collect();
                let d = ham_vertical(&a, &q);
                for tau in [0usize, d.saturating_sub(1), d, d + 1, 64] {
                    assert_eq!(
                        ham_vertical_leq(&a, &q, tau),
                        (d <= tau).then_some(d),
                        "b={b} d={d} tau={tau}"
                    );
                }
            }
        }
    }
}
