//! Packed horizontal sketch storage.

use crate::store::{ensure, ByteReader, ByteWriter, Persist, StoreError, Words};
use crate::util::{ceil_div, HeapSize};

/// A database of `n` b-bit sketches of length `l`, packed at `b` bits per
/// character.
///
/// Characters are packed **MSB-first** within each 64-bit word: character
/// `p` of a sketch lives in word `p / cpw` at shift `(cpw - 1 - p%cpw) * b`
/// (`cpw = 64 / b` characters per word). With this layout, comparing the
/// word sequences of two sketches as big-endian-style `u64` tuples is
/// exactly lexicographic comparison of the character strings — the trie
/// builder sorts on raw words.
#[derive(Debug, Clone)]
pub struct SketchSet {
    /// Bits per character (1, 2, 4, or 8).
    b: usize,
    /// Characters per sketch.
    l: usize,
    /// Number of sketches.
    n: usize,
    /// Words per sketch.
    wps: usize,
    /// Packed data, `n * wps` words — owned when built or mutated, borrowed
    /// from the snapshot mapping when loaded zero-copy.
    words: Words,
}

impl SketchSet {
    /// Creates an empty set for `n` sketches (all characters zero).
    pub fn zeros(b: usize, l: usize, n: usize) -> Self {
        assert!(matches!(b, 1 | 2 | 4 | 8), "b must be one of 1,2,4,8");
        assert!(l >= 1 && l * b <= 64 * 64, "unsupported sketch length");
        let wps = ceil_div(l * b, 64);
        SketchSet { b, l, n, wps, words: vec![0; n * wps].into() }
    }

    /// Builds from explicit character rows (mainly for tests/examples).
    pub fn from_rows(b: usize, l: usize, rows: &[Vec<u8>]) -> Self {
        let mut set = Self::zeros(b, l, rows.len());
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), l, "row {i} has wrong length");
            for (p, &c) in row.iter().enumerate() {
                set.set_char(i, p, c);
            }
        }
        set
    }

    /// Builds by calling `f(i, p)` for every sketch `i`, position `p`.
    pub fn from_fn(b: usize, l: usize, n: usize, mut f: impl FnMut(usize, usize) -> u8) -> Self {
        let mut set = Self::zeros(b, l, n);
        for i in 0..n {
            for p in 0..l {
                set.set_char(i, p, f(i, p));
            }
        }
        set
    }

    #[inline]
    pub fn b(&self) -> usize {
        self.b
    }

    #[inline]
    pub fn l(&self) -> usize {
        self.l
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Alphabet size `2^b`.
    #[inline]
    pub fn sigma(&self) -> usize {
        1 << self.b
    }

    /// Words per sketch.
    #[inline]
    pub fn words_per_sketch(&self) -> usize {
        self.wps
    }

    /// Characters per word.
    #[inline]
    fn cpw(&self) -> usize {
        64 / self.b
    }

    #[inline]
    fn shift(&self, p: usize) -> usize {
        let slot = p % self.cpw();
        (self.cpw() - 1 - slot) * self.b
    }

    /// Character `p` of sketch `i`.
    #[inline]
    pub fn get_char(&self, i: usize, p: usize) -> u8 {
        debug_assert!(i < self.n && p < self.l);
        let w = self.words[i * self.wps + p / self.cpw()];
        ((w >> self.shift(p)) as usize & (self.sigma() - 1)) as u8
    }

    /// Sets character `p` of sketch `i` to `c`.
    #[inline]
    pub fn set_char(&mut self, i: usize, p: usize, c: u8) {
        debug_assert!(i < self.n && p < self.l);
        debug_assert!((c as usize) < self.sigma(), "char {c} out of alphabet");
        let idx = i * self.wps + p / self.cpw();
        let sh = self.shift(p);
        let mask = (self.sigma() as u64 - 1) << sh;
        let words = self.words.to_mut();
        words[idx] = (words[idx] & !mask) | ((c as u64) << sh);
    }

    /// The packed words of sketch `i`.
    #[inline]
    pub fn sketch_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.wps..(i + 1) * self.wps]
    }

    /// All characters of sketch `i` as a vector.
    pub fn row(&self, i: usize) -> Vec<u8> {
        (0..self.l).map(|p| self.get_char(i, p)).collect()
    }

    /// Lexicographic comparison of sketches `i` and `j` (via packed words).
    #[inline]
    pub fn cmp_sketches(&self, i: usize, j: usize) -> std::cmp::Ordering {
        self.sketch_words(i).cmp(self.sketch_words(j))
    }

    /// Length of the longest common prefix (in characters) of sketches
    /// `i` and `j`, computed word-at-a-time.
    pub fn lcp(&self, i: usize, j: usize) -> usize {
        let (a, b) = (self.sketch_words(i), self.sketch_words(j));
        for w in 0..self.wps {
            if a[w] != b[w] {
                let diff = a[w] ^ b[w];
                // Characters are MSB-first: leading equal bits = equal chars.
                let eq_bits = diff.leading_zeros() as usize;
                let eq_chars_in_word = eq_bits / self.b;
                return (w * self.cpw() + eq_chars_in_word).min(self.l);
            }
        }
        self.l
    }

    /// Returns the identity permutation sorted so that
    /// `perm[0] <= perm[1] <= ...` in lexicographic sketch order.
    pub fn sorted_permutation(&self) -> Vec<u32> {
        let mut perm: Vec<u32> = (0..self.n as u32).collect();
        perm.sort_unstable_by(|&a, &b| self.cmp_sketches(a as usize, b as usize));
        perm
    }

    /// Hamming distance between sketch `i` and a raw query row, naive
    /// character-wise (the baseline the paper's §V-C compares against).
    pub fn ham_naive(&self, i: usize, q: &[u8]) -> usize {
        debug_assert_eq!(q.len(), self.l);
        (0..self.l).filter(|&p| self.get_char(i, p) != q[p]).count()
    }

    /// Packs a raw query row into sketch words (same layout as rows).
    pub fn pack_row(&self, q: &[u8]) -> Vec<u64> {
        assert_eq!(q.len(), self.l);
        let mut words = vec![0u64; self.wps];
        for (p, &c) in q.iter().enumerate() {
            debug_assert!((c as usize) < self.sigma());
            words[p / self.cpw()] |= (c as u64) << self.shift(p);
        }
        words
    }

    /// Horizontal SWAR Hamming distance between packed words (see
    /// [`hamming::ham_horizontal`]).
    #[inline]
    pub fn ham_packed(&self, i: usize, q_words: &[u64]) -> usize {
        super::hamming::ham_horizontal(self.sketch_words(i), q_words, self.b)
    }

    /// Extracts the sub-sketches `[lo, hi)` of every sketch into a new set
    /// (used by the multi-index approach to form blocks).
    pub fn slice_block(&self, lo: usize, hi: usize) -> SketchSet {
        assert!(lo < hi && hi <= self.l);
        SketchSet::from_fn(self.b, hi - lo, self.n, |i, p| self.get_char(i, lo + p))
    }

    /// Raw words (serialization).
    pub fn raw_words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuilds from raw parts (deserialization).
    pub fn from_raw(b: usize, l: usize, n: usize, words: Vec<u64>) -> Self {
        let wps = ceil_div(l * b, 64);
        assert_eq!(words.len(), n * wps);
        SketchSet { b, l, n, wps, words: words.into() }
    }
}

impl Persist for SketchSet {
    fn write_into(&self, w: &mut ByteWriter) {
        w.put_usize(self.b);
        w.put_usize(self.l);
        w.put_usize(self.n);
        w.put_u64s(&self.words);
    }

    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
        let b = r.get_usize()?;
        let l = r.get_usize()?;
        let n = r.get_usize()?;
        let words = r.get_u64s_ref()?;
        ensure(matches!(b, 1 | 2 | 4 | 8), || format!("SketchSet: invalid b {b}"))?;
        ensure(l >= 1 && l.checked_mul(b).map_or(false, |x| x <= 64 * 64), || {
            format!("SketchSet: unsupported length L={l} (b={b})")
        })?;
        let wps = ceil_div(l * b, 64);
        let need = n
            .checked_mul(wps)
            .ok_or_else(|| StoreError::Corrupt("SketchSet: n*wps overflows".into()))?;
        ensure(words.len() == need, || {
            format!("SketchSet: {} words != n*wps = {need}", words.len())
        })?;
        Ok(SketchSet { b, l, n, wps, words })
    }
}

impl HeapSize for SketchSet {
    fn heap_bytes(&self) -> usize {
        self.words.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_set(b: usize, l: usize, n: usize, seed: u64) -> (SketchSet, Vec<Vec<u8>>) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..l).map(|_| rng.below(1 << b) as u8).collect())
            .collect();
        (SketchSet::from_rows(b, l, &rows), rows)
    }

    #[test]
    fn get_set_roundtrip_all_b() {
        for &b in &[1usize, 2, 4, 8] {
            let l = 130 / b; // force multi-word
            let (set, rows) = random_set(b, l, 50, b as u64);
            for i in 0..50 {
                for p in 0..l {
                    assert_eq!(set.get_char(i, p), rows[i][p], "b={b} i={i} p={p}");
                }
                assert_eq!(set.row(i), rows[i]);
            }
        }
    }

    #[test]
    fn word_order_is_lex_order() {
        for &b in &[2usize, 4, 8] {
            let (set, rows) = random_set(b, 19, 200, 7 + b as u64);
            for i in 0..200 {
                for j in 0..200 {
                    assert_eq!(
                        set.cmp_sketches(i, j),
                        rows[i].cmp(&rows[j]),
                        "b={b} i={i} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn lcp_matches_naive() {
        let (set, rows) = random_set(2, 33, 100, 9);
        for i in 0..100 {
            for j in 0..100 {
                let naive = rows[i]
                    .iter()
                    .zip(&rows[j])
                    .take_while(|(a, b)| a == b)
                    .count();
                assert_eq!(set.lcp(i, j), naive, "i={i} j={j}");
            }
        }
    }

    #[test]
    fn sorted_permutation_sorts() {
        let (set, rows) = random_set(4, 9, 300, 11);
        let perm = set.sorted_permutation();
        for w in perm.windows(2) {
            assert!(rows[w[0] as usize] <= rows[w[1] as usize]);
        }
    }

    #[test]
    fn pack_row_matches_internal_layout() {
        let (set, rows) = random_set(4, 21, 20, 13);
        for i in 0..20 {
            assert_eq!(set.pack_row(&rows[i]), set.sketch_words(i).to_vec());
        }
    }

    #[test]
    fn slice_block_extracts_substring() {
        let (set, rows) = random_set(2, 32, 40, 15);
        let block = set.slice_block(10, 25);
        assert_eq!(block.l(), 15);
        for i in 0..40 {
            assert_eq!(block.row(i), rows[i][10..25].to_vec());
        }
    }

    #[test]
    fn ham_naive_counts_mismatches() {
        let rows = vec![vec![0u8, 1, 2, 3], vec![0, 1, 2, 3]];
        let set = SketchSet::from_rows(2, 4, &rows);
        assert_eq!(set.ham_naive(0, &[0, 1, 2, 3]), 0);
        assert_eq!(set.ham_naive(0, &[1, 1, 2, 0]), 2);
        assert_eq!(set.ham_naive(0, &[3, 3, 3, 0]), 4);
    }

    #[test]
    fn persist_roundtrip_and_validation() {
        for &b in &[1usize, 2, 4, 8] {
            let l = 96 / b;
            let (set, _) = random_set(b, l, 40, 19 + b as u64);
            let bytes = crate::store::to_payload(&set);
            let got: SketchSet =
                crate::store::from_payload(&mut crate::store::ByteReader::new(&bytes)).unwrap();
            assert_eq!(got.b(), set.b());
            assert_eq!(got.l(), set.l());
            assert_eq!(got.n(), set.n());
            assert_eq!(got.raw_words(), set.raw_words());
        }
        // invalid b and word-count mismatch are rejected
        let (set, _) = random_set(2, 8, 10, 23);
        let mut w = crate::store::ByteWriter::new();
        w.put_usize(3); // b = 3 is not a supported width
        w.put_usize(set.l());
        w.put_usize(set.n());
        w.put_u64s(set.raw_words());
        assert!(crate::store::from_payload::<SketchSet>(
            &mut crate::store::ByteReader::new(&w.into_bytes())
        )
        .is_err());
        let mut w = crate::store::ByteWriter::new();
        w.put_usize(set.b());
        w.put_usize(set.l());
        w.put_usize(set.n() + 1); // declares more rows than words carry
        w.put_u64s(set.raw_words());
        assert!(crate::store::from_payload::<SketchSet>(
            &mut crate::store::ByteReader::new(&w.into_bytes())
        )
        .is_err());
    }

    #[test]
    fn raw_roundtrip() {
        let (set, _) = random_set(8, 8, 30, 17);
        let rebuilt =
            SketchSet::from_raw(set.b(), set.l(), set.n(), set.raw_words().to_vec());
        for i in 0..30 {
            assert_eq!(set.row(i), rebuilt.row(i));
        }
    }
}
