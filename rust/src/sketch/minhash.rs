//! b-bit minwise hashing (Li & König, WWW 2010).
//!
//! Maps a binary set fingerprint `x ⊆ [0, D)` to an `L`-character sketch:
//! character `ℓ` is the lowest `b` bits of `min_{j ∈ x} H_ℓ(j)` for an
//! independent random hash `H_ℓ`. Collision probability per character
//! approximates the Jaccard similarity (plus the 2^-b collision floor).
//!
//! The hash tables `H` are explicit `u32` tensors generated here and fed
//! to **both** this native implementation and the JAX/Pallas AOT artifact,
//! so the two produce bit-identical sketches (integer min has no rounding).

use crate::sketch::SketchSet;
use crate::util::pool::par_chunks;
use crate::util::rng::Rng;

/// Random projection tables for minhash: `l × d` independent u32 hashes.
#[derive(Debug, Clone)]
pub struct MinhashParams {
    /// Sketch length (number of hash functions).
    pub l: usize,
    /// Bits kept per character.
    pub b: usize,
    /// Input dimensionality.
    pub d: usize,
    /// Row-major `l × d` hash values.
    pub hashes: Vec<u32>,
}

impl MinhashParams {
    /// Generates parameter tables deterministically from `seed`.
    ///
    /// Hash values are confined to `[0, 2^31)` so the XLA artifact can
    /// take the min in `i32` with the same ordering (bit-identical
    /// sketches across the native and AOT paths).
    pub fn generate(l: usize, b: usize, d: usize, seed: u64) -> Self {
        assert!(matches!(b, 1 | 2 | 4 | 8));
        let mut rng = Rng::new(seed ^ 0x6d68_6173_68u64); // "mhash"
        let hashes = (0..l * d).map(|_| rng.next_u32() >> 1).collect();
        MinhashParams { l, b, d, hashes }
    }

    /// Sketches one set fingerprint given as a list of present indices.
    /// An empty set maps to the all-`(2^b - 1)` sketch (min of nothing is
    /// `u32::MAX`); generators never emit empty sets.
    pub fn sketch_set(&self, present: &[u32]) -> Vec<u8> {
        let mask = (1u32 << self.b) - 1;
        (0..self.l)
            .map(|l| {
                let row = &self.hashes[l * self.d..(l + 1) * self.d];
                let mut m = u32::MAX;
                for &j in present {
                    let h = row[j as usize];
                    if h < m {
                        m = h;
                    }
                }
                (m & mask) as u8
            })
            .collect()
    }

    /// Sketches a dense 0/1 vector (the layout the XLA artifact consumes).
    pub fn sketch_dense(&self, x: &[f32]) -> Vec<u8> {
        debug_assert_eq!(x.len(), self.d);
        let present: Vec<u32> = (0..self.d as u32)
            .filter(|&j| x[j as usize] > 0.0)
            .collect();
        self.sketch_set(&present)
    }

    /// Batch-sketches `sets` (lists of present indices) in parallel into a
    /// [`SketchSet`].
    pub fn sketch_batch(&self, sets: &[Vec<u32>], threads: usize) -> SketchSet {
        let n = sets.len();
        let mut out = SketchSet::zeros(self.b, self.l, n);
        // SAFETY-free parallelism: compute rows into a buffer, then write.
        let rows: std::sync::Mutex<Vec<(usize, Vec<u8>)>> =
            std::sync::Mutex::new(Vec::with_capacity(n));
        par_chunks(n, threads, |range| {
            let mut local = Vec::with_capacity(range.len());
            for i in range {
                local.push((i, self.sketch_set(&sets[i])));
            }
            rows.lock().unwrap().extend(local);
        });
        for (i, row) in rows.into_inner().unwrap() {
            for (p, &c) in row.iter().enumerate() {
                out.set_char(i, p, c);
            }
        }
        out
    }

    /// Flattens hash tables to the f32 buffer layout the runtime feeds to
    /// the AOT artifact (values preserved exactly: u32 reinterpreted via
    /// `as f32` would lose precision, so artifacts take u32 directly; this
    /// helper exists for byte serialization).
    pub fn hashes_le_bytes(&self) -> Vec<u8> {
        self.hashes.iter().flat_map(|h| h.to_le_bytes()).collect()
    }
}

/// Exact Jaccard similarity of two sets given as sorted index lists.
pub fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let p1 = MinhashParams::generate(16, 2, 256, 42);
        let p2 = MinhashParams::generate(16, 2, 256, 42);
        assert_eq!(p1.hashes, p2.hashes);
        let s = vec![3u32, 17, 200];
        assert_eq!(p1.sketch_set(&s), p2.sketch_set(&s));
    }

    #[test]
    fn identical_sets_identical_sketches() {
        let p = MinhashParams::generate(32, 2, 512, 1);
        let a = vec![1u32, 5, 9, 100, 300];
        assert_eq!(p.sketch_set(&a), p.sketch_set(&a));
    }

    #[test]
    fn chars_in_alphabet() {
        let p = MinhashParams::generate(64, 4, 128, 2);
        let s: Vec<u32> = (0..64).collect();
        for c in p.sketch_set(&s) {
            assert!(c < 16);
        }
    }

    #[test]
    fn collision_rate_tracks_jaccard() {
        // Sketch collision probability per char ≈ J + (1-J)/2^b.
        let d = 2000usize;
        let l = 512usize;
        let b = 2usize;
        let p = MinhashParams::generate(l, b, d, 7);
        let mut rng = Rng::new(99);
        // Build two sets with controlled overlap.
        let base: Vec<u32> = rng.sample_indices(d, 400).into_iter().map(|x| x as u32).collect();
        let mut a = base[..300].to_vec();
        let mut bset = base[100..400].to_vec();
        a.sort();
        bset.sort();
        let j = jaccard(&a, &bset);
        let sa = p.sketch_set(&a);
        let sb = p.sketch_set(&bset);
        let coll =
            sa.iter().zip(&sb).filter(|(x, y)| x == y).count() as f64 / l as f64;
        let expect = j + (1.0 - j) / (1u32 << b) as f64;
        assert!(
            (coll - expect).abs() < 0.08,
            "jaccard={j:.3} collision={coll:.3} expected≈{expect:.3}"
        );
    }

    #[test]
    fn dense_equals_sparse() {
        let d = 300;
        let p = MinhashParams::generate(8, 8, d, 3);
        let present = vec![4u32, 77, 150, 299];
        let mut dense = vec![0f32; d];
        for &j in &present {
            dense[j as usize] = 1.0;
        }
        assert_eq!(p.sketch_set(&present), p.sketch_dense(&dense));
    }

    #[test]
    fn batch_matches_single() {
        let p = MinhashParams::generate(16, 2, 128, 5);
        let mut rng = Rng::new(11);
        let sets: Vec<Vec<u32>> = (0..50)
            .map(|_| {
                let k = 1 + rng.below_usize(30);
                let mut s: Vec<u32> =
                    rng.sample_indices(128, k).into_iter().map(|x| x as u32).collect();
                s.sort();
                s
            })
            .collect();
        let batch = p.sketch_batch(&sets, 4);
        for (i, s) in sets.iter().enumerate() {
            assert_eq!(batch.row(i), p.sketch_set(s), "i={i}");
        }
    }

    #[test]
    fn jaccard_basics() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 0.0);
        assert_eq!(jaccard(&[], &[]), 1.0);
        assert!((jaccard(&[1, 2, 3, 4], &[3, 4, 5, 6]) - 1.0 / 3.0).abs() < 1e-12);
    }
}
