//! `bst` — the command-line entry point.
//!
//! ```text
//! bst eval <table1|table2|table3|table4|fig7|fig8|msweep|all> [--datasets a,b]
//!          [--scale F] [--queries N] [--sih-cap S] [--mem-cap-gib G]
//!          [--seed S] [--threads T]
//! bst bench [--out BENCH_prN.json] [--datasets a,b] [--scale F] [--queries N]
//! bst sketch --dataset D [--scale F] [--out FILE] [--xla]   # ingestion
//! bst build  --in FILE [--index si-bst|mi-bst|...]          # index stats
//!            [--save SNAP --shards S]                       # engine snapshot
//! bst insert --index SNAP --in NEW.bin --save OUT.snap      # write path
//!            [--merge]
//! bst query  --in FILE | --index SNAP [--mmap]
//!            --q 0,1,2,... [--tau T] [--topk K] [--stats]
//! bst serve  --dataset D | --index SNAP [--mmap] | --follow HOST:PORT
//!            [--addr A] [--shards S] [--scale F]
//! bst info                                                  # build info
//! ```

use bst::cli::Args;
use bst::coordinator::engine::{Engine, QueryResult, QuerySpec, ShardIndexKind};
use bst::coordinator::{replica, server, ServeConfig};
use bst::data::{self, Dataset};
use bst::eval::{bench, cost, tables, EvalOpts};
use bst::index::SearchIndex;
use bst::trie::bst::BstConfig;
use bst::trie::SketchTrie;
use std::path::Path;
use std::sync::Arc;

fn main() {
    // Deterministic fault injection for crash drills: no-op unless the
    // binary was built with `--features failpoints` AND BST_FAILPOINTS
    // is set (e.g. `wal.sync=error@25;shard.worker=panic@100+1`). See
    // util::failpoint.
    bst::util::failpoint::init_from_env();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "eval" => cmd_eval(&args),
        "bench" => cmd_bench(&args),
        "sketch" => cmd_sketch(&args),
        "build" => cmd_build(&args),
        "insert" => cmd_insert(&args),
        "query" => cmd_query(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            print!("{}", HELP);
            0
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
bst — b-bit sketch trie: scalable similarity search on integer sketches

USAGE:
  bst eval <exp>      regenerate a paper experiment
                      (table1 table2 table3 table4 fig7 fig8 msweep
                       pruning topk all)
                      [--datasets review,cp,sift,gist] [--scale F]
                      [--queries N] [--sih-cap SECS] [--mem-cap-gib G]
                      [--seed S] [--threads T]
  bst bench           perf-trajectory point: bST vs linear per-query
                      latency (p50/p99 us, Mq/s) as Markdown + JSON
                      [--out BENCH_prN.json] [--datasets a,b] [--scale F]
                      [--queries N] [--seed S] [--threads T]
  bst sketch          generate + sketch a synthetic dataset
                      --dataset D [--scale F] [--out FILE] [--xla]
  bst build           build an index over saved sketches, print stats
                      --in FILE [--index si-bst|mi-bst|sih|mih|hmsearch]
                      [--save SNAP] (write an engine snapshot; si-bst|mi-bst)
                      [--shards N] (snapshot shard count, default 1)
  bst insert          append saved sketches into an engine snapshot
                      --index SNAP --in NEW.bin --save OUT.snap
                      [--merge] (fold deltas into fresh immutable segments)
  bst query           one-off query against saved sketches or a snapshot
                      --in FILE | --index SNAP (serve-from-snapshot)
                      --q c0,c1,... [--tau T]
                      [--topk K] (k nearest)  [--stats] (traversal stats)
                      [--mmap] (map the snapshot read-only and serve the
                       immutable segments zero-copy; owned load is the
                       default and the fallback if mapping fails)
  bst serve           start the sharded TCP query service
                      --dataset D [--scale F] | --index SNAP (cold start)
                      [--addr A] [--shards N]
                      [--index-kind si-bst|mi-bst] [--max-batch N] [--max-delay-us U]
                      [--merge-threshold N] (delta rows before background merge)
                      [--block-width N] (multi-query block size, default 8;
                       1 = serial per-query execution)
                      [--mmap] (serve snapshots zero-copy from a read-only
                       mapping — applies to the --index cold start and to
                       reload ops; writes still land in owned deltas)
                      [--wal PATH] (per-server write-ahead log: inserts and
                       deletes are logged + fsync'd before they are
                       acknowledged, and replayed past the snapshot's
                       high-water mark on the next start; a `save` op
                       rotates the log)
                      [--wal-sync always|batch|off] (fsync policy for WAL
                       appends; `always` — the default — survives kill -9
                       and power loss, `batch` syncs once per batch,
                       `off` leaves durability to the page cache)
                      [--wal-group-window auto|0|USECS] (group commit
                       under `always`: concurrent writers share one
                       fsync and ack on a durability watermark. `auto`
                       — the default — coalesces whenever writers queue
                       behind an in-flight fsync; a microsecond value
                       makes the group leader wait that long for more
                       writers; `0` disables grouping, restoring the
                       fsync-per-record path)
                      [--max-request-bytes N] (largest accepted request
                       line, default 16777216; longer lines get an error
                       reply and the connection keeps serving)
                      [--follow HOST:PORT] (read replica: bootstrap from
                       the primary's snapshot over the wire, then tail
                       its WAL and apply records as they ship; serves
                       every read op, rejects writes with a read_only
                       error; mutually exclusive with --wal)
                      [--follow-poll-ms N] (replication poll interval
                       once caught up, default 200)
  bst info            print build/runtime information
";

fn eval_opts(args: &Args) -> EvalOpts {
    let mut o = EvalOpts {
        scale: args.get_f64("scale", 1.0),
        queries: args.get_usize("queries", 200),
        sih_cap_secs: args.get_f64("sih-cap", 2.0),
        mem_cap_gib: args.get_f64("mem-cap-gib", 8.0),
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    if let Some(t) = args.get("threads") {
        o.threads = t.parse().unwrap_or(o.threads);
    }
    o
}

fn parse_datasets(args: &Args) -> Vec<Dataset> {
    match args.get("datasets") {
        None => Dataset::ALL.to_vec(),
        Some(spec) => spec
            .split(',')
            .filter_map(|s| {
                let d = Dataset::parse(s.trim());
                if d.is_none() {
                    eprintln!("warning: unknown dataset '{s}'");
                }
                d
            })
            .collect(),
    }
}

fn cmd_eval(args: &Args) -> i32 {
    let exp = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let opts = eval_opts(args);
    let datasets = parse_datasets(args);
    eprintln!(
        "# eval {exp}: datasets={:?} scale={} queries={} threads={}",
        datasets.iter().map(|d| d.name()).collect::<Vec<_>>(),
        opts.scale,
        opts.queries,
        opts.threads
    );
    let out = match exp {
        "table1" | "datasets" => tables::table1(&opts),
        "table2" => tables::table2(&opts, &datasets),
        "table3" => tables::table3(&opts, &datasets),
        "table4" => tables::table4(&opts, &datasets),
        "fig7" => tables::fig7(&opts, &datasets),
        "fig8" => cost::fig8(),
        "msweep" => tables::msweep(&opts, &datasets),
        "pruning" => tables::pruning(&opts, &datasets),
        "topk" => tables::topk(&opts, &datasets),
        "all" => {
            let mut s = String::new();
            s.push_str(&tables::table1(&opts));
            s.push('\n');
            s.push_str(&tables::table2(&opts, &datasets));
            s.push('\n');
            s.push_str(&tables::table3(&opts, &datasets));
            s.push_str(&tables::table4(&opts, &datasets));
            s.push('\n');
            s.push_str(&tables::fig7(&opts, &datasets));
            s.push_str(&cost::fig8());
            s
        }
        other => {
            eprintln!("unknown experiment '{other}'");
            return 2;
        }
    };
    println!("{out}");
    0
}

fn cmd_bench(args: &Args) -> i32 {
    let opts = eval_opts(args);
    let datasets = parse_datasets(args);
    eprintln!(
        "# bench: datasets={:?} scale={} queries={}",
        datasets.iter().map(|d| d.name()).collect::<Vec<_>>(),
        opts.scale,
        opts.queries
    );
    let (md, payload) = bench::bench(&opts, &datasets);
    println!("{md}");
    if let Some(path) = args.get("out") {
        if let Err(e) = std::fs::write(path, payload.to_string() + "\n") {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    0
}

fn cmd_sketch(args: &Args) -> i32 {
    let Some(ds) = args.get("dataset").and_then(Dataset::parse) else {
        eprintln!("--dataset review|cp|sift|gist required");
        return 2;
    };
    let opts = eval_opts(args);
    let cfg = data::GenConfig::for_dataset(ds, opts.scale, opts.seed, opts.threads);
    eprintln!("generating {} items for {}...", cfg.n, ds.name());

    let sketches = if args.has("xla") {
        // ingestion through the PJRT runtime (Layer 2/1 artifacts)
        let rt = match bst::runtime::Runtime::load(Path::new("artifacts")) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("runtime error: {e:#}");
                return 1;
            }
        };
        let sk = rt.sketcher(ds.name()).expect("sketcher");
        eprintln!("sketching via XLA artifact {} ...", sk.meta().name);
        if ds.uses_minhash() {
            let sets = data::generate_sets(ds, &cfg);
            let params =
                bst::sketch::MinhashParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);
            let d = ds.dim();
            let mut x = vec![0f32; cfg.n * d];
            for (i, s) in sets.iter().enumerate() {
                for &j in s {
                    x[i * d + j as usize] = 1.0;
                }
            }
            sk.sketch_minhash(&x, cfg.n, &params).expect("sketch")
        } else {
            let feats = data::generate_dense(ds, &cfg);
            let params = bst::sketch::CwsParams::generate(ds.l(), ds.b(), ds.dim(), cfg.seed);
            sk.sketch_cws(&feats, cfg.n, &params).expect("sketch")
        }
    } else {
        data::generate_workload(ds, &cfg).sketches
    };

    let out = args.get_or("out", "sketches.bin");
    if let Err(e) = data::io::save_sketches(&sketches, Path::new(out)) {
        eprintln!("save failed: {e}");
        return 1;
    }
    eprintln!(
        "wrote {} sketches (b={}, L={}) to {out}",
        sketches.n(),
        sketches.b(),
        sketches.l()
    );
    0
}

fn load_input(args: &Args) -> Option<bst::SketchSet> {
    let path = args.get_or("in", "sketches.bin");
    match data::io::load_sketches(Path::new(path)) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("loading {path}: {e}");
            None
        }
    }
}

fn cmd_build(args: &Args) -> i32 {
    let Some(set) = load_input(args) else { return 1 };
    let kind = args.get_or("index", "si-bst");

    // --save SNAP: build a sharded engine and write a serve-from-snapshot
    // container (loadable by `bst query/serve --index SNAP` and the
    // server's `reload` op).
    if let Some(save_path) = args.get("save") {
        let engine_kind = match kind {
            "si-bst" => ShardIndexKind::Bst(BstConfig::default()),
            "mi-bst" => ShardIndexKind::MultiBst(args.get_usize("m", 2)),
            other => {
                eprintln!("--save supports --index si-bst|mi-bst, got '{other}'");
                return 2;
            }
        };
        let shards = args.get_usize("shards", 1);
        let t = bst::util::timer::Timer::start();
        let engine = Engine::build(&set, shards, &engine_kind);
        let build_ms = t.elapsed_ms();
        if let Err(e) = engine.save(Path::new(save_path)) {
            eprintln!("saving snapshot {save_path}: {e}");
            return 1;
        }
        let disk = std::fs::metadata(save_path).map(|m| m.len()).unwrap_or(0);
        println!(
            "snapshot={save_path} index={kind} n={} L={} b={} shards={} \
             build_ms={build_ms:.0} heap_mib={:.1} disk_mib={:.1}",
            set.n(),
            set.l(),
            set.b(),
            engine.n_shards(),
            engine.heap_bytes() as f64 / (1024.0 * 1024.0),
            disk as f64 / (1024.0 * 1024.0),
        );
        return 0;
    }

    let t = bst::util::timer::Timer::start();
    let (name, bytes, extra): (String, usize, String) = match kind {
        "si-bst" => {
            let idx = bst::index::SingleBst::build(&set, BstConfig::default());
            let d = idx.trie().describe();
            (idx.name(), idx.heap_bytes(), d)
        }
        "mi-bst" => {
            let m = args.get_usize("m", 2);
            let idx = bst::index::MultiBst::build(&set, m);
            (SearchIndex::name(&idx), SearchIndex::heap_bytes(&idx), String::new())
        }
        "sih" => {
            let idx = bst::index::Sih::build(&set);
            (SearchIndex::name(&idx), SearchIndex::heap_bytes(&idx), String::new())
        }
        "mih" => {
            let m = args.get_usize("m", 2);
            let idx = bst::index::Mih::build(&set, m);
            (SearchIndex::name(&idx), SearchIndex::heap_bytes(&idx), String::new())
        }
        "hmsearch" => {
            let tau = args.get_usize("tau", 2);
            let idx = bst::index::HmSearch::build(&set, tau);
            (SearchIndex::name(&idx), SearchIndex::heap_bytes(&idx), String::new())
        }
        "louds" => {
            let idx = bst::index::SingleLouds::build(&set);
            let d = idx.trie().describe();
            (idx.name(), idx.heap_bytes(), d)
        }
        "fst" => {
            let idx = bst::index::SingleFst::build(&set);
            let d = idx.trie().describe();
            (idx.name(), idx.heap_bytes(), d)
        }
        other => {
            eprintln!("unknown index '{other}'");
            return 2;
        }
    };
    println!(
        "index={name} n={} L={} b={} build_ms={:.0} size_mib={:.1} {extra}",
        set.n(),
        set.l(),
        set.b(),
        t.elapsed_ms(),
        bytes as f64 / (1024.0 * 1024.0)
    );
    0
}

/// `bst insert`: the CLI write path — load a snapshot, append a second
/// sketch file into the delta segments, optionally force-merge, and save
/// the mutated engine. Cold-starting the result answers byte-identically
/// to a from-scratch build of the concatenated data (CI proves it).
fn cmd_insert(args: &Args) -> i32 {
    let Some(snap) = args.get("index") else {
        eprintln!("--index SNAP required");
        return 2;
    };
    let Some(save_path) = args.get("save") else {
        eprintln!("--save OUT.snap required");
        return 2;
    };
    let Some(set) = load_input(args) else { return 1 };
    let engine = match Engine::load(Path::new(snap)) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("loading snapshot {snap}: {e}");
            return 1;
        }
    };
    if set.l() != engine.l() || set.b() != engine.b() {
        eprintln!(
            "sketch shape b={} L={} does not match the snapshot's b={} L={}",
            set.b(),
            set.l(),
            engine.b(),
            engine.l()
        );
        return 2;
    }
    let t = bst::util::timer::Timer::start();
    let rows: Vec<Vec<u8>> = (0..set.n()).map(|i| set.row(i)).collect();
    let range = match engine.insert_batch(&rows) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("insert failed: {e}");
            return 1;
        }
    };
    let insert_ms = t.elapsed_ms();
    let mut merged = 0usize;
    if args.has("merge") {
        let summary = engine.merge();
        merged = summary.merged;
        if summary.skipped > 0 {
            eprintln!(
                "warning: {} legacy shard(s) kept their deltas (v1 snapshot without raw rows)",
                summary.skipped
            );
        }
    }
    if let Err(e) = engine.save(Path::new(save_path)) {
        eprintln!("saving snapshot {save_path}: {e}");
        return 1;
    }
    let disk = std::fs::metadata(save_path).map(|m| m.len()).unwrap_or(0);
    println!(
        "snapshot={save_path} inserted={} first_id={} n={} shards={} merged={merged} \
         insert_ms={insert_ms:.0} disk_mib={:.1}",
        rows.len(),
        range.start,
        engine.n(),
        engine.n_shards(),
        disk as f64 / (1024.0 * 1024.0),
    );
    0
}

fn cmd_query(args: &Args) -> i32 {
    let Some(qspec) = args.get("q") else {
        eprintln!("--q c0,c1,... required");
        return 2;
    };
    let q: Vec<u8> = qspec
        .split(',')
        .filter_map(|c| c.trim().parse().ok())
        .collect();

    // --index SNAP: serve the query from a saved engine snapshot (no
    // sketches needed, no rebuild).
    if let Some(snap) = args.get("index") {
        return query_snapshot(args, snap, &q);
    }

    let Some(set) = load_input(args) else { return 1 };
    if q.len() != set.l() {
        eprintln!("query must have L={} characters", set.l());
        return 2;
    }
    use bst::query::{CollectIds, QueryCtx, StatsObserver};
    use bst::util::json::Json;
    let idx = bst::index::SingleBst::build(&set, BstConfig::default());

    // --topk K: k nearest neighbors (radius --tau, default: unbounded).
    if let Some(spec) = args.get("topk") {
        let Ok(k) = spec.parse::<usize>() else {
            eprintln!("--topk must be a non-negative integer, got '{spec}'");
            return 2;
        };
        let tau = args.get_usize("tau", set.l());
        let t = bst::util::timer::Timer::start();
        let hits = idx.top_k(&q, k, tau);
        let us = t.elapsed_us();
        println!(
            "{}",
            Json::obj(vec![
                ("ids", Json::Arr(hits.iter().map(|&(id, _)| Json::Num(id as f64)).collect())),
                ("dists", Json::Arr(hits.iter().map(|&(_, d)| Json::Num(d as f64)).collect())),
                ("latency_us", Json::num(us)),
            ])
        );
        return 0;
    }

    let tau = args.get_usize("tau", 2);
    let t = bst::util::timer::Timer::start();
    let mut hits = Vec::new();
    let stats = {
        let mut ctx = QueryCtx::new();
        let mut obs = StatsObserver::new(CollectIds::new(tau, &mut hits));
        idx.trie().run(&q, &mut ctx, &mut obs);
        obs.stats
    };
    let us = t.elapsed_us();
    hits.sort();
    let mut fields = vec![
        ("ids", Json::ids(&hits)),
        ("latency_us", Json::num(us)),
    ];
    if args.has("stats") {
        fields.push(("visited", Json::num(stats.visited as f64)));
        fields.push(("pruned", Json::num(stats.pruned as f64)));
        fields.push(("emitted", Json::num(stats.emitted as f64)));
    }
    println!("{}", Json::obj(fields));
    0
}

/// `bst query --index SNAP`: answers from a loaded engine snapshot —
/// the cold-start path (no sketches on hand, no reconstruction).
fn query_snapshot(args: &Args, snap: &str, q: &[u8]) -> i32 {
    use bst::util::json::Json;
    let engine = match Engine::load_with(Path::new(snap), args.has("mmap")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("loading snapshot {snap}: {e}");
            return 1;
        }
    };
    if q.len() != engine.l() {
        eprintln!("query must have L={} characters", engine.l());
        return 2;
    }
    if let Some(spec) = args.get("topk") {
        let Ok(k) = spec.parse::<usize>() else {
            eprintln!("--topk must be a non-negative integer, got '{spec}'");
            return 2;
        };
        let tau = args.get_usize("tau", engine.l());
        let t = bst::util::timer::Timer::start();
        let hits = match engine.query(&QuerySpec::top_k(q, k, tau)) {
            QueryResult::TopK(h) => h,
            _ => Vec::new(),
        };
        let us = t.elapsed_us();
        println!(
            "{}",
            Json::obj(vec![
                ("ids", Json::Arr(hits.iter().map(|&(id, _)| Json::Num(id as f64)).collect())),
                ("dists", Json::Arr(hits.iter().map(|&(_, d)| Json::Num(d as f64)).collect())),
                ("latency_us", Json::num(us)),
            ])
        );
        return 0;
    }
    let tau = args.get_usize("tau", 2);
    let t = bst::util::timer::Timer::start();
    let mut hits = match engine.query(&QuerySpec::ids(q, tau)) {
        QueryResult::Ids(h) => h,
        _ => Vec::new(),
    };
    let us = t.elapsed_us();
    hits.sort();
    println!(
        "{}",
        Json::obj(vec![("ids", Json::ids(&hits)), ("latency_us", Json::num(us))])
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(wal_sync) = bst::store::WalSync::parse(args.get_or("wal-sync", "always")) else {
        eprintln!("--wal-sync must be always|batch|off");
        return 2;
    };
    let wal_group_window = match args.get_or("wal-group-window", "auto") {
        "auto" => None,
        v => match v.parse::<u64>() {
            Ok(us) => Some(us),
            Err(_) => {
                eprintln!("--wal-group-window must be `auto`, `0` (off) or microseconds");
                return 2;
            }
        },
    };
    let serve_cfg = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        shards: args.get_usize("shards", 4),
        max_batch: args.get_usize("max-batch", 32),
        max_delay_us: args.get_u64("max-delay-us", 200),
        default_tau: args.get_usize("tau", 2),
        merge_threshold: args
            .get_usize("merge-threshold", Engine::DEFAULT_MERGE_THRESHOLD),
        block_width: args.get_usize("block-width", 8),
        mmap: args.has("mmap"),
        wal: args.get("wal").map(std::path::PathBuf::from),
        wal_sync,
        wal_group_window,
        max_request_bytes: args.get_usize("max-request-bytes", 16 << 20),
        follow: args.get("follow").map(|s| s.to_string()),
        follow_poll_ms: args.get_u64("follow-poll-ms", 200),
        follow_cursor: None,
    };

    // Follower mode: no local dataset or snapshot — the engine is
    // bootstrapped from the primary over the wire, and the replication
    // tail inside the server keeps it current.
    if let Some(primary) = serve_cfg.follow.clone() {
        if serve_cfg.wal.is_some() {
            eprintln!(
                "--follow and --wal are mutually exclusive \
                 (a follower's durability is its primary's)"
            );
            return 2;
        }
        let local = replica::default_local_snapshot();
        eprintln!("bootstrapping from primary {primary}...");
        let t = bst::util::timer::Timer::start();
        let boot = match replica::bootstrap(&primary, &local, serve_cfg.mmap) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("follower bootstrap failed: {e}");
                return 1;
            }
        };
        let Some(cursor) = boot.cursor else {
            eprintln!(
                "primary {primary} serves without --wal: nothing to tail, \
                 refusing to serve a frozen snapshot"
            );
            return 2;
        };
        eprintln!(
            "bootstrapped in {:.0} ms: n={} shards={}, tailing from {}:{}",
            t.elapsed_ms(),
            boot.engine.n(),
            boot.engine.n_shards(),
            cursor.seq,
            cursor.off
        );
        let mut cfg = serve_cfg;
        cfg.follow_cursor = Some(cursor);
        return run_server(Arc::new(boot.engine), cfg);
    }

    // `--index` doubles as the historical kind selector (si-bst/mi-bst)
    // and the snapshot path; `--index-kind` is the unambiguous spelling.
    // Anything else must name an existing snapshot file — a typo'd kind
    // must fail loudly here, not fall through to a default index or a
    // confusing io error.
    let index_arg = args.get("index");
    let snapshot = index_arg.filter(|v| !matches!(*v, "si-bst" | "mi-bst"));
    if let Some(snap) = snapshot {
        if !Path::new(snap).is_file() {
            eprintln!(
                "--index '{snap}' is neither a known index kind (si-bst|mi-bst) \
                 nor an existing snapshot file"
            );
            return 2;
        }
    }
    let kind_name = args
        .get("index-kind")
        .or_else(|| index_arg.filter(|v| matches!(*v, "si-bst" | "mi-bst")))
        .unwrap_or("si-bst");

    let engine = if let Some(snap) = snapshot {
        // Cold start: serve directly from the snapshot — no dataset
        // generation, no sketching, no index construction.
        let t = bst::util::timer::Timer::start();
        match Engine::load_with(Path::new(snap), serve_cfg.mmap) {
            Ok(e) => {
                eprintln!(
                    "loaded snapshot {snap} in {:.0} ms (n={}, shards={}, mode={})",
                    t.elapsed_ms(),
                    e.n(),
                    e.n_shards(),
                    if serve_cfg.mmap { "mapped" } else { "owned" }
                );
                Arc::new(e)
            }
            Err(e) => {
                eprintln!("loading snapshot {snap}: {e}");
                return 1;
            }
        }
    } else {
        let Some(ds) = args.get("dataset").and_then(Dataset::parse) else {
            eprintln!("--dataset review|cp|sift|gist (or --index SNAP) required");
            return 2;
        };
        let opts = eval_opts(args);
        let cfg = data::GenConfig::for_dataset(ds, opts.scale, opts.seed, opts.threads);
        eprintln!("building workload for {} (n={})...", ds.name(), cfg.n);
        let w = data::generate_workload(ds, &cfg);
        let kind = match kind_name {
            "mi-bst" => ShardIndexKind::MultiBst(args.get_usize("m", 2)),
            _ => ShardIndexKind::Bst(BstConfig::default()),
        };
        eprintln!("building {} shards...", serve_cfg.shards);
        Arc::new(Engine::build(&w.sketches, serve_cfg.shards, &kind))
    };
    // Attach the WAL before the listener exists: tail records from a
    // crashed run replay into the engine first, so the very first
    // connection already sees every write that was ever acknowledged.
    if let Some(wal) = serve_cfg.wal.clone() {
        match engine.attach_wal_with(&wal, serve_cfg.wal_sync, serve_cfg.wal_group_window) {
            Ok(rep) => eprintln!(
                "wal {} attached (sync={}, group={}): {} segment(s), replayed {} insert + {} \
                 delete record(s), skipped {}, truncated {} torn byte(s)",
                wal.display(),
                serve_cfg.wal_sync.as_str(),
                match (serve_cfg.wal_sync, serve_cfg.wal_group_window) {
                    (bst::store::WalSync::Always, None) => "auto".to_string(),
                    (bst::store::WalSync::Always, Some(0)) => "off".to_string(),
                    (bst::store::WalSync::Always, Some(us)) => format!("{us}us"),
                    _ => "n/a".to_string(),
                },
                rep.segments,
                rep.replayed_inserts,
                rep.replayed_deletes,
                rep.skipped_records,
                rep.truncated_bytes
            ),
            Err(e) => {
                eprintln!("attaching wal {}: {e}", wal.display());
                return 1;
            }
        }
    }
    eprintln!(
        "engine ready: n={} shards={} index_mib={:.1}",
        engine.n(),
        engine.n_shards(),
        engine.heap_bytes() as f64 / (1024.0 * 1024.0)
    );
    run_server(engine, serve_cfg)
}

/// Binds the listener and blocks forever (ctrl-c to stop).
fn run_server(engine: Arc<Engine>, cfg: ServeConfig) -> i32 {
    match server::serve(engine, cfg) {
        Ok(handle) => {
            eprintln!("listening on {}", handle.addr);
            // Block forever (ctrl-c to stop); the handle joins on drop.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind failed: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("bst {} — b-bit sketch trie", env!("CARGO_PKG_VERSION"));
    println!("artifacts: {}", Path::new("artifacts/meta.json").exists());
    match bst::runtime::Runtime::load(Path::new("artifacts")) {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            for a in rt.registry().all() {
                println!("  artifact {} kind={} batch={}", a.name, a.kind, a.batch);
            }
        }
        Err(e) => println!("runtime unavailable: {e}"),
    }
    0
}
