//! Index persistence: the versioned snapshot container and the
//! [`Persist`] trait threaded through every layer.
//!
//! The paper's structures are succinct — flat word arrays with small
//! directories — which makes them ideal for a load-without-rebuild
//! snapshot: serialization is a field-by-field dump and loading is a
//! validated parse, never a reconstruction. Saving an engine writes one
//! [`container::Snapshot`] with a `meta` section plus one `shard.N`
//! section per shard; `Engine::load` restores the workers without
//! touching `SortedSketches::build` or re-deriving any rank/select
//! directory (the directories themselves are part of the payload).
//!
//! * [`container`] — the file format: magic, format version, 8-byte
//!   aligned sections with per-section lengths and FNV-1a checksums.
//! * [`bytes`] — checked little-endian cursors used inside sections,
//!   plus the owned/mapped dual representation ([`Bytes`], [`PodVec`])
//!   behind zero-copy serving.
//! * [`mmap`] — dependency-free read-only file mapping
//!   ([`Snapshot::open_mapped`] serves sections straight from the page
//!   cache; see the mapped-serving contract in [`container`]).
//! * [`wal`] — the per-engine write-ahead log: every acknowledged
//!   insert/delete is appended (and fsync'd per `--wal-sync`) before
//!   the engine replies, and replayed past the snapshot's id high-water
//!   mark on load. See the durability contract in that module.
//! * [`Persist`] — `write_into` / `read_from` implemented by every
//!   persistent structure ([`crate::bits::BitVec`], [`crate::bits::RsBitVec`],
//!   [`crate::bits::IntVec`], the sketch stores, all four tries, all six
//!   indexes, and the engine's shard wrapper). `read_from` validates
//!   structural invariants and returns [`StoreError`] — never panics —
//!   on truncated, corrupt or inconsistent input.

pub mod bytes;
pub mod container;
pub mod mmap;
pub mod wal;

pub use bytes::{
    mapped_borrow_fallbacks, ByteReader, ByteWriter, Bytes, Pod, PodVec, U32s, Words,
};
pub use container::{
    Snapshot, SnapshotBuilder, SnapshotStreamWriter, FORMAT_VERSION, FORMAT_VERSION_V1,
    FORMAT_VERSION_V2, MAGIC,
};
pub use mmap::Mmap;
pub use wal::{GroupCommit, GroupOutcome, Wal, WalCursor, WalRecord, WalSync};

use std::fmt;

/// Errors produced while writing or (far more commonly) reading snapshots.
#[derive(Debug)]
pub enum StoreError {
    Io(std::io::Error),
    /// The file does not start with the snapshot magic.
    BadMagic(u64),
    /// The container is a snapshot, but of a format version this build
    /// does not understand.
    UnsupportedVersion(u32),
    /// A required section is absent.
    MissingSection(String),
    /// Anything structurally wrong: truncation, checksum mismatch,
    /// impossible lengths, violated invariants.
    Corrupt(String),
}

impl StoreError {
    pub(crate) fn corrupt(msg: String) -> Self {
        StoreError::Corrupt(msg)
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "snapshot io error: {e}"),
            StoreError::BadMagic(m) => {
                write!(f, "bad magic {m:#018x}: not a bst snapshot file")
            }
            StoreError::UnsupportedVersion(v) => {
                write!(
                    f,
                    "unsupported snapshot format version {v} (this build reads {}..={})",
                    container::FORMAT_VERSION_V1,
                    container::FORMAT_VERSION
                )
            }
            StoreError::MissingSection(s) => write!(f, "snapshot is missing section '{s}'"),
            StoreError::Corrupt(msg) => write!(f, "corrupt snapshot: {msg}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

/// Stable binary serialization of one structure.
///
/// Implementations enumerate their fields into a [`ByteWriter`] in a fixed
/// order and parse them back with full validation: any input that
/// `write_into` could not have produced must yield `Err`, not a panic and
/// not a structurally inconsistent value. Construction-only state (query
/// scratch, epoch arrays, mutex-pooled buffers) is *not* serialized — it
/// is rebuilt cheaply on load.
pub trait Persist: Sized {
    /// Appends this structure's stable byte layout to `w`.
    fn write_into(&self, w: &mut ByteWriter);

    /// Parses a structure previously written by [`Persist::write_into`].
    fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError>;
}

/// Serializes one structure into a standalone section payload.
pub fn to_payload<T: Persist>(x: &T) -> Vec<u8> {
    let mut w = ByteWriter::new();
    x.write_into(&mut w);
    w.into_bytes()
}

/// [`to_payload`] in the legacy pre-v3 (unpadded) layout — for
/// constructing version-1/2 containers in compatibility tests.
pub fn to_payload_legacy<T: Persist>(x: &T) -> Vec<u8> {
    let mut w = ByteWriter::legacy();
    x.write_into(&mut w);
    w.into_bytes()
}

/// Parses a structure from a full section payload, requiring the payload
/// to be consumed exactly.
pub fn from_payload<T: Persist>(payload: &mut ByteReader<'_>) -> Result<T, StoreError> {
    let x = T::read_from(payload)?;
    payload.expect_end()?;
    Ok(x)
}

/// Serialized size in bytes of one structure (the eval tables report this
/// next to `heap_bytes` as the on-disk cost).
pub fn persisted_bytes<T: Persist>(x: &T) -> usize {
    let mut w = ByteWriter::new();
    x.write_into(&mut w);
    w.len()
}

/// Fsyncs the directory containing `path`, making renames and creates
/// in it durable (crash-atomic snapshot saves and WAL rotation both
/// need the directory entry on disk, not just the file contents). On
/// non-unix targets directory handles cannot be fsync'd; the data
/// fsyncs still hold.
pub(crate) fn sync_parent_dir(path: &std::path::Path) -> Result<(), StoreError> {
    let dir = path
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .unwrap_or(std::path::Path::new("."));
    #[cfg(unix)]
    {
        std::fs::File::open(dir)?.sync_all()?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

/// Shared validation helper: errors unless `cond` holds.
pub(crate) fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), StoreError> {
    if cond {
        Ok(())
    } else {
        Err(StoreError::Corrupt(msg()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Pair {
        a: u64,
        b: Vec<u32>,
    }

    impl Persist for Pair {
        fn write_into(&self, w: &mut ByteWriter) {
            w.put_u64(self.a);
            w.put_u32s(&self.b);
        }

        fn read_from(r: &mut ByteReader<'_>) -> Result<Self, StoreError> {
            let a = r.get_u64()?;
            let b = r.get_u32s()?;
            ensure(a as usize >= b.len(), || "a must bound b".into())?;
            Ok(Pair { a, b })
        }
    }

    #[test]
    fn payload_roundtrip() {
        let p = Pair { a: 10, b: vec![1, 2, 3] };
        let bytes = to_payload(&p);
        let got: Pair = from_payload(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(got, p);
        assert_eq!(persisted_bytes(&p), bytes.len());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let p = Pair { a: 10, b: vec![] };
        let mut bytes = to_payload(&p);
        bytes.push(0);
        assert!(from_payload::<Pair>(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn invariant_violation_rejected() {
        let p = Pair { a: 1, b: vec![1, 2, 3] };
        let bytes = to_payload(&p); // writer doesn't validate; reader must
        assert!(from_payload::<Pair>(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn error_display_is_informative() {
        let e = StoreError::MissingSection("shard.3".into());
        assert!(e.to_string().contains("shard.3"));
        let e = StoreError::UnsupportedVersion(9);
        assert!(e.to_string().contains('9'));
    }
}
